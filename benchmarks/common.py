"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows — ``derived`` is
the benchmark's headline metric (throughput, completion slots, Θ, ...).
"""
import sys
import time


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
