"""Figure 3: scalability spectrum for MRLS with R=36, f=1.

Region boundaries (P[D* <= k] = 1/2 thresholds, Appendix A) and the
expected average distance A(S) curve.  Paper landmarks: first boundary
~2K endpoints (D 2->4), next ~30K (D* 4->5), 100M endpoints at D=6.
"""
import sys

sys.path.insert(0, "src")

from repro.core import dstar_thresholds, mrls_design, mrls_expected_A
from benchmarks.common import emit, timed


def main(full: bool = True):
    print("# fig3: D* thresholds and expected A for MRLS(R=36, f=1)")
    th, us = timed(lambda: dstar_thresholds(36, 1.0, k_max=8))
    for k, s in th.items():
        emit(f"fig3.threshold_Dstar<={k}", us / len(th), f"S={s:.4g}")
    for S in (1_000, 2_000, 11_052, 30_000, 104_976, 1_000_000,
              10_000_000, 100_000_000):
        (n1, n2, u, d) = mrls_design(S, 36, 1.0)
        a, us = timed(lambda: mrls_expected_A(n1, n2, u, 36))
        emit(f"fig3.A@S={S}", us, f"A={a:.3f}|Theta={2.0 / a:.3f}")


if __name__ == "__main__":
    main()
