"""Shared simulator-benchmark driver for Figs. 5/6/7 — on ``repro.api``.

Each scenario is a :class:`NetworkSpec` + routing knobs; the whole
pipeline (topology build, table construction, simulator lifetime,
collective phase orchestration) runs through the declarative facade.
One :class:`SimulatorCache` per scenario keeps the ~7 experiments on the
same compiled simulator and performs the cache-clearing teardown that
this file used to do by hand (``del sim; jax.clear_caches()`` — ~25
simulator instances per suite OOM the host otherwise).

Default is a structurally-matched *scaled* family (radix 12 instead of
36 — same topology classes, same cost ratios, CPU-tractable); ``--full``
builds the paper's exact sizes (11K/16K/100K endpoints — hours of CPU;
used for the headline numbers in EXPERIMENTS.md §Repro).
"""
import sys

sys.path.insert(0, "src")

from repro.api import (Experiment, NetworkSpec, RouteSpec, SimulatorCache,
                       WorkloadSpec, run)
from benchmarks.common import emit, timed

PATTERNS = ("uniform", "rep", "rsp", "bu")


def run_scenario(name: str, net: NetworkSpec, policy: str, max_hops: int,
                 warm: int, measure: int, a2a_rounds: int,
                 allreduce_ranks: int, vec_packets: int = 16,
                 patterns=PATTERNS, pool=None, replicas: int = 1):
    """``replicas > 1`` runs every experiment as one vmapped batch over
    that many seeds (the paper's figures average random MRLS arbitration
    seeds); reported values are across-replica means."""
    route = RouteSpec(policy=policy, vcs=4, max_hops=max_hops, pool=pool)

    def exp(workload, **kw):
        return Experiment(network=net, route=route, workload=workload,
                          warm=warm, measure=measure, replicas=replicas, **kw)

    def slots_str(r):
        return f"{r.slots:.1f}" if isinstance(r.slots, float) else f"{r.slots}"

    with SimulatorCache() as cache:
        # throughput at max injection
        for pat in patterns:
            r, us = timed(lambda: run(exp(WorkloadSpec(pat, load=1.0)),
                                      cache=cache))
            emit(f"{name}.thpt.{pat}", us,
                 f"L={r.throughput:.3f}|hops={r.avg_hops:.2f}")
        # tail latency under mice/elephant at 0.5 load
        r, us = timed(lambda: run(
            exp(WorkloadSpec("mice_elephant", load=0.5), metric="latency"),
            cache=cache))
        emit(f"{name}.lat.mice_elephant", us,
             f"p50={r.latency['p50']}|p99={r.latency['p99']}"
             f"|p9999={r.latency['p9999']}")
        # All2All completion (device-side loop, exact completion slot)
        r, us = timed(lambda: run(
            exp(WorkloadSpec("all2all", rounds=a2a_rounds), max_slots=60_000),
            cache=cache))
        emit(f"{name}.all2all", us,
             f"slots={slots_str(r)}|completed={r.completed}")
        # Rabenseifner Allreduce (power-of-two ranks mapped onto endpoints)
        r = run(exp(WorkloadSpec("allreduce", ranks=allreduce_ranks,
                                 vec_packets=vec_packets),
                    max_slots=30_000), cache=cache)
        emit(f"{name}.allreduce", 0.0,
             f"slots={slots_str(r)}|completed={r.completed}")


def cli_replicas(argv, default: int = 4) -> int:
    """Shared ``--replicas N`` / ``--replicas=N`` parsing for fig drivers."""
    for i, arg in enumerate(argv):
        if arg == "--replicas":
            if i + 1 >= len(argv):
                raise SystemExit("--replicas requires a value")
            return int(argv[i + 1])
        if arg.startswith("--replicas="):
            return int(arg.split("=", 1)[1])
    return default
