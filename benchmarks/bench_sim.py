"""Shared simulator-benchmark driver for Figs. 5/6/7.

Default is a structurally-matched *scaled* family (radix 12 instead of 36 —
same topology classes, same cost ratios, CPU-tractable); ``--full`` builds
the paper's exact sizes (11K/16K/100K endpoints — hours of CPU; used for
the headline numbers in EXPERIMENTS.md §Repro).
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import build_tables
from repro.core.collectives import rabenseifner_phases
from repro.simulator.engine import Simulator, SimConfig, Traffic
from benchmarks.common import emit, timed

PATTERNS = ("uniform", "rep", "rsp", "bu")


def run_scenario(name: str, topo, policy: str, max_hops: int,
                 warm: int, measure: int, a2a_rounds: int,
                 allreduce_ranks: int, vec_packets: int = 16,
                 patterns=PATTERNS, pool=None):
    tables = build_tables(topo)
    sim = Simulator(tables, SimConfig(policy=policy, vcs=4,
                                      max_hops=max_hops, pool=pool))
    # throughput at max injection
    for pat in patterns:
        r, us = timed(lambda: sim.run_throughput(
            Traffic(pat, load=1.0), warm=warm, measure=measure))
        emit(f"{name}.thpt.{pat}", us,
             f"L={r['throughput']:.3f}|hops={r['avg_hops']:.2f}")
    # tail latency under mice/elephant at 0.5 load
    r, us = timed(lambda: sim.run_latency(
        Traffic("mice_elephant", load=0.5), warm=warm, measure=measure))
    emit(f"{name}.lat.mice_elephant", us,
         f"p50={r['p0.5']}|p99={r['p0.99']}|p9999={r['p0.9999']}")
    # All2All completion (chunk=16 -> 16-slot completion resolution)
    S = sim.S
    r, us = timed(lambda: sim.run_completion(
        Traffic("all2all", rounds=a2a_rounds), expected=S * a2a_rounds,
        chunk=16, max_slots=60_000))
    emit(f"{name}.all2all", us,
         f"slots={r['slots']}|completed={r['completed']}")
    # Rabenseifner Allreduce (power-of-two ranks mapped onto endpoints)
    n = allreduce_ranks
    total = 0
    ok = True
    for ph in rabenseifner_phases(n, vec_packets):
        tr = Traffic("phase", phase_packets=ph["packets"])
        st = sim.make_state(tr)
        partner = np.arange(sim.S, dtype=np.int32)
        partner[:n] = ph["partner"]
        st["partner"] = np.asarray(partner)
        expected = int((partner[:n] != np.arange(n)).sum()) * ph["packets"]
        res = sim.run_completion(tr, expected=expected, chunk=16,
                                 max_slots=30_000, state=st)
        ok &= res["completed"]
        total += res["slots"]
    emit(f"{name}.allreduce", 0.0, f"slots={total}|completed={ok}")
    # ~25 simulator instances per suite: drop compiled steps or the single
    # 35 GB host OOMs at the tail (observed: LLVM "Cannot allocate memory").
    del sim
    jax.clear_caches()
    return None
