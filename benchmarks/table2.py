"""Table 2: parameters of the evaluated topologies (exact, full size).

Rebuilds every row of the paper's Table 2 and reports Cost_links,
Cost_switches, diameter and Θ next to the paper's values.
"""
import sys

sys.path.insert(0, "src")

from repro.core import (mrls, oft, fat_tree, dragonfly, dragonfly_plus,
                        exact_metrics, mrls_design)
from benchmarks.common import emit, timed

# (builder, paper: cost_links, cost_switches, D, theta)
ROWS = [
    ("MRLS(36,11052)u18", lambda: mrls(614, 18, 18, seed=1), 1.0, 0.083, 4, 0.748),
    ("MRLS(36,11160)u21", lambda: mrls(744, 21, 15, seed=1), 1.4, 0.106, 4, 1.029),
    ("MRLS(36,11664)u24", lambda: mrls(972, 24, 12, seed=1), 2.0, 0.139, 4, 1.420),
    ("MRLS(36,104976)u18", lambda: mrls(5832, 18, 18, seed=1), 1.0, 0.083, 4, 0.527),
    ("MRLS(36,104976)u24", lambda: mrls(8748, 24, 12, seed=1), 2.0, 0.139, 4, 1.048),
    ("MRLS(36,104976)u27", lambda: mrls(11664, 27, 9, seed=1), 3.0, 0.194, 4, 1.561),
    ("MRLS(32,16640)u19", lambda: mrls(1280, 19, 13, seed=1), 1.462, 0.122, 4, 0.900),
    ("OFT(36,11052)", lambda: oft(17), 1.0, 0.083, 2, 1.0),
    ("FT(36,11664)", lambda: fat_tree(36, 2), 2.0, 0.139, 4, 1.0),
    ("FT(36,104976)50%", lambda: fat_tree(36, 3, a1=18), 3.0, 0.222, 6, 1.0),
    ("DF+(32,16640)", lambda: dragonfly_plus(65, 16, 16, 16, 16), 1.5, 0.127, 3, 1.0),
    ("DF(32,16512)", lambda: dragonfly(16, 8, 8), 1.5, 0.125, 3, 1.0),
]


def main(full: bool = True):
    print("# table2: name,us_per_call,"
          "S|C_links(got/paper)|C_sw(got/paper)|D(got/paper)|Theta(got/paper)")
    for name, build, cl, cs, D, th in ROWS:
        (topo, us) = timed(build)
        m = exact_metrics(topo)
        derived = (f"S={m.S}|C_l={m.cost_links:.3f}/{cl}|"
                   f"C_s={m.cost_switches:.3f}/{cs}|D={m.D}/{D}|"
                   f"Θ={m.theta:.3f}/{th}")
        emit(f"table2.{name}", us, derived)


if __name__ == "__main__":
    main()
