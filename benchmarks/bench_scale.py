"""Extreme-scale sweep: the paper's headline all2all comparison, benched.

Reproduces the regime of the paper's headline result (MRLS vs Fat-Tree vs
Dragonfly on a 100K-endpoint All2All) as a *benchmark*: for every
``(size, family)`` point of ``examples/specs/headline_a2a.json`` it
reports

* ``pattern_slots_per_sec`` — raw engine stepping (free-running all2all
  ``Traffic``, dense candidate tables at small sizes / blocked at scale);
* ``program_slots_per_sec`` — the same fabric stepping the *windowed
  all2all workload program* (``schedule="window"``, the scale scenario's
  execution mode);
* ``completion`` — one cold ``run_program`` to completion: simulated
  slots, wall seconds, per-phase progress — the headline metric itself;
* ``peak_rss_bytes`` + the :func:`repro.api.estimate_memory` prediction,
  so the estimator is cross-checked against reality at every scale point.

Method matches ``bench_step.py``: every (size, family) point runs in its
own subprocess (clean cold-start, honest ``ru_maxrss``).  The regression
gate (``--check``) is the **program/pattern slots-per-sec ratio** — the
two variants are timed with *interleaved* best-of reps inside the same
subprocess, so host-speed and background-load effects cancel out of the
ratio: it catches scheduler/blocked-table overhead regressions, while raw
step-speed regressions are ``bench_step.py``'s job.  Gate tolerance 20%
below the committed baseline's ratio, per (size, family).

Each record also carries ``compile_ram_multiplier`` — the measured
``(peak_rss - process baseline) / est_total`` ratio that
:mod:`repro.api.admission` reads back to predict real peak RSS before
compiling (only at-scale records, >= 1000 endpoints, feed predictions;
tiny points are baseline-dominated but recorded for completeness).

``--supervised`` runs every point's child under
:class:`repro.runtime.supervisor.Supervisor`: admission preflight
(predicted bytes vs host RAM), peak-RSS polling, wall-clock watchdog,
and retry-with-backoff.  The child then checkpoints its completion run
(``repro.runtime.resilient``) into a scratch directory, so a killed
worker *resumes* rather than restarts — ``--inject-kill S`` SIGKILLs the
first point's first attempt after ``S`` seconds to prove that path in
CI.  Points are salvaged individually: a failed (size, family) records
an ``error`` entry and the merged ``--out`` file is rewritten after
every point, so a crash late in a ladder keeps the finished points.

CI runs ``--sizes tiny`` against the committed ``BENCH_scale.json``; the
big sizes are driven by hand / nightly (``--sizes 1k,10k,50k,100k``).
Acceptance for ISSUE 5 was validated with ``--sizes 50k --families
mrls`` on the reference container (2 CPU cores): the 50400-endpoint MRLS
windowed all2all completes (22 slots, ~42 s wall for the cold completion
run) within host memory.  Measured peak RSS was ~6.5 GiB against the
estimator's ~0.6 GiB of *resident simulation data* — the difference is
XLA compile-time memory for the three step executables, which the
estimator deliberately does not model; recording both numbers side by
side is what keeps that gap visible per scale point.
"""
import json
import pathlib
import resource
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

SPEC_DEFAULT = _ROOT / "examples" / "specs" / "headline_a2a.json"
SIZES = ("tiny", "1k", "10k", "50k", "100k")
FAMILIES = ("mrls", "fat_tree", "dragonfly")
REGRESSION_TOLERANCE = 0.20

# timed slots / reps per size: enough slots to amortize dispatch (and at
# tiny, to hold the program/pattern gate ratio steady under CI noise),
# few enough that 100k stays minutes, not hours, on a CPU host
MEASURE = {"tiny": (1024, 7), "1k": (128, 3), "10k": (48, 2),
           "50k": (16, 2), "100k": (8, 2)}


def _experiments(spec_path):
    doc = json.loads(pathlib.Path(spec_path).read_text())
    return {d["name"]: d for d in doc["experiments"]}


def _find(spec_path, size: str, family: str) -> dict:
    exps = _experiments(spec_path)
    name = f"headline.{size}.{family}"
    if name not in exps:
        raise SystemExit(f"no experiment {name!r} in {spec_path}")
    return exps[name]


# ---------------------------------------------------------------------- #
# child: one measurement in a clean subprocess
# ---------------------------------------------------------------------- #
def _child(spec_path, size: str, family: str, ckpt_dir=None,
           result_out=None):
    import jax
    from repro.api import Experiment, estimate_memory
    from repro.api.runner import routing_tables
    from repro.simulator.engine import Simulator, Traffic
    from repro.workloads import build_collective_program, compile_program

    exp = Experiment.from_dict(_find(spec_path, size, family))
    est = estimate_memory(exp)
    t_build0 = time.perf_counter()
    tables = routing_tables(exp.network)
    sim = Simulator(tables, exp.route.to_sim_config(seed=exp.seed))
    build_s = time.perf_counter() - t_build0
    n_slots, reps = MEASURE[size]
    out = {"n_endpoints": sim.S, "n_switches": sim.N,
           "mask_layout": tables.mask_layout,
           "est_total_bytes": est["total_bytes"],
           "est_peak_bytes": est["peak_bytes"],
           "build_seconds": build_s}

    w = exp.workload
    cp = compile_program(
        build_collective_program("all2all", sim.S, rounds=w.rounds),
        schedule=w.schedule or "window", window=w.window)
    tr_pat = Traffic("all2all", rounds=1 << 30)   # injectors never idle
    tr_prog = sim.program_traffic(cp)
    st_pat = jax.block_until_ready(
        sim.run_chunk(sim.make_state(tr_pat, exp.seed), tr_pat, n_slots))
    st_prog = jax.block_until_ready(
        sim.run_chunk(sim.make_program_state(cp, exp.seed), tr_prog,
                      n_slots))
    # interleaved best-of reps: background-load swings hit pattern and
    # program alike, so their RATIO (the regression gate) stays steady
    # even on a noisy host
    best = {"pattern": float("inf"), "program": float("inf")}
    for _ in range(reps):
        t0 = time.perf_counter()
        st_pat = jax.block_until_ready(sim.run_chunk(st_pat, tr_pat,
                                                     n_slots))
        best["pattern"] = min(best["pattern"], time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_prog = jax.block_until_ready(sim.run_chunk(st_prog, tr_prog,
                                                      n_slots))
        best["program"] = min(best["program"], time.perf_counter() - t0)
    out["pattern_slots_per_sec"] = n_slots / best["pattern"]
    out["program_slots_per_sec"] = n_slots / best["program"]
    # the headline metric: one cold completion run (compile included in
    # wall_seconds — it is the honest cost of the scenario).  With a
    # --ckpt dir the run goes through the resumable driver: a supervised
    # retry picks up the latest snapshot instead of restarting, bitwise.
    t0 = time.perf_counter()
    if ckpt_dir:
        from repro.runtime.resilient import (ResilientConfig,
                                             run_program_resumable)
        r = run_program_resumable(sim, cp, ckpt=ckpt_dir, chunk=exp.chunk,
                                  max_slots=exp.max_slots, seed=exp.seed,
                                  config=ResilientConfig(every=1))
    else:
        r = sim.run_program(cp, chunk=exp.chunk, max_slots=exp.max_slots,
                            seed=exp.seed)
    out["completion"] = {
        "slots": int(r["slots"]), "completed": bool(r["completed"]),
        "pool_stall": int(r["pool_stall"]),
        "wall_seconds": time.perf_counter() - t0,
    }
    if ckpt_dir:
        out["completion"]["resumed_from"] = r["resumed_from"]
        out["completion"]["segments"] = r["segments"]
    out["peak_rss_bytes"] = resource.getrusage(
        resource.RUSAGE_SELF).ru_maxrss * 1024
    blob = json.dumps(out)
    if result_out:
        tmp = result_out + ".tmp"
        pathlib.Path(tmp).write_text(blob)
        pathlib.Path(tmp).rename(result_out)
    print(blob)


def _spawn(spec_path, size: str, family: str) -> dict:
    argv = [sys.executable, str(pathlib.Path(__file__).resolve()),
            "--child", "--sizes", size, "--families", family,
            "--spec", str(spec_path)]
    out = subprocess.run(argv, check=True, capture_output=True, text=True,
                         cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])


def _spawn_supervised(spec_path, size: str, family: str,
                      inject_kill=None) -> dict:
    """One point under the supervisor: admission preflight, RSS budget =
    host RAM, kill-and-resume retries against the child's checkpoint
    directory.  ``inject_kill`` SIGKILLs the first attempt after that
    many seconds (chaos for CI)."""
    import tempfile
    from repro.api import Experiment, estimate_memory
    from repro.api.admission import (compile_ram_multiplier, host_ram_bytes,
                                     predict_peak_rss)
    from repro.runtime.fault_tolerance import BackoffPolicy
    from repro.runtime.supervisor import Supervisor, SupervisorConfig

    exp = Experiment.from_dict(_find(spec_path, size, family))
    est = estimate_memory(exp)
    mult = compile_ram_multiplier(exp.network.family)
    predicted = predict_peak_rss(est["total_bytes"], mult)
    ram = host_ram_bytes()
    work = tempfile.mkdtemp(prefix=f"bench_scale_{size}_{family}_")
    result_path = str(pathlib.Path(work) / "result.json")
    ckpt = str(pathlib.Path(work) / "ckpt")
    argv = [sys.executable, str(pathlib.Path(__file__).resolve()),
            "--child", "--sizes", size, "--families", family,
            "--spec", str(spec_path), "--ckpt", ckpt,
            "--result-out", result_path]
    sup = Supervisor(SupervisorConfig(
        rss_budget_bytes=ram, max_retries=3, inject_kill_s=inject_kill,
        backoff=BackoffPolicy(base_s=0.5, cap_s=5.0)))
    res = sup.run(argv, cwd=str(_ROOT), predicted_bytes=predicted)
    if not res.ok:
        kinds = [a.killed or f"rc={a.returncode}" for a in res.attempts]
        raise RuntimeError(
            f"supervised {size}.{family} failed after "
            f"{len(res.attempts)} attempts ({', '.join(kinds)})")
    m = json.loads(pathlib.Path(result_path).read_text())
    m["supervised"] = res.to_dict()
    return m


# ---------------------------------------------------------------------- #
def _write_merged(out_path, doc):
    p = pathlib.Path(out_path)
    merged = json.loads(p.read_text()) if p.exists() else {}
    for size, fams in doc.items():
        merged.setdefault(size, {}).update(fams)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(merged, indent=1, sort_keys=True) + "\n")
    return p


def main(spec_path, sizes, families, out_path, check_path,
         supervised=False, inject_kill=None):
    from benchmarks.common import emit
    from repro.api.admission import BASELINE_RSS_BYTES
    doc = {}
    broken = []
    first_point = True
    for size in sizes:
        doc.setdefault(size, {})
        for family in families:
            try:
                if supervised:
                    m = _spawn_supervised(
                        spec_path, size, family,
                        inject_kill=inject_kill if first_point else None)
                else:
                    m = _spawn(spec_path, size, family)
            except Exception as e:
                # salvage: record the failure, keep every finished point
                print(f"POINT FAILED {size}.{family}: {e}",
                      file=sys.stderr)
                broken.append(f"{size}.{family}")
                doc[size][family] = {"error": str(e)}
                if out_path:
                    _write_merged(out_path, doc)
                first_point = False
                continue
            first_point = False
            rec = {
                "n_endpoints": m["n_endpoints"],
                "n_switches": m["n_switches"],
                "mask_layout": m["mask_layout"],
                "pattern_slots_per_sec": m["pattern_slots_per_sec"],
                "program_slots_per_sec": m["program_slots_per_sec"],
                "program_ratio": (m["program_slots_per_sec"]
                                  / m["pattern_slots_per_sec"]),
                "completion": m["completion"],
                "peak_rss_bytes": m["peak_rss_bytes"],
                "est_total_bytes": m["est_total_bytes"],
                "est_peak_bytes": m["est_peak_bytes"],
                # measured compile-RAM blowup: what admission control
                # reads back (baseline-dominated below ~1000 endpoints —
                # recorded anyway, the predictor filters by scale)
                "compile_ram_multiplier": (
                    max(m["peak_rss_bytes"] - BASELINE_RSS_BYTES, 0)
                    / m["est_total_bytes"]),
                "build_seconds": m["build_seconds"],
            }
            if "supervised" in m:
                rec["supervised"] = m["supervised"]
            doc[size][family] = rec
            if out_path:
                _write_merged(out_path, doc)   # salvage point by point
            emit(f"bench_scale.{size}.{family}.pattern",
                 1e6 / rec["pattern_slots_per_sec"],
                 f"{rec['pattern_slots_per_sec']:.1f} slots/s")
            emit(f"bench_scale.{size}.{family}.program",
                 1e6 / rec["program_slots_per_sec"],
                 f"{rec['program_slots_per_sec']:.1f} slots/s "
                 f"(ratio {rec['program_ratio']:.2f})")
            c = rec["completion"]
            emit(f"bench_scale.{size}.{family}.completion", 0.0,
                 f"{c['slots']} slots in {c['wall_seconds']:.1f}s "
                 f"completed={c['completed']} "
                 f"peak_rss={rec['peak_rss_bytes'] / 2**20:.0f}MiB "
                 f"(est {rec['est_peak_bytes'] / 2**20:.0f}MiB)"
                 + (f" retries={rec['supervised']['retries']}"
                    if "supervised" in rec else ""))

    if out_path:
        print(f"wrote {_write_merged(out_path, doc)}")

    if check_path:
        base = json.loads(pathlib.Path(check_path).read_text())
        failures = []
        for size, fams in doc.items():
            for family, rec in fams.items():
                if "error" in rec:
                    continue   # already in `broken`
                ref = base.get(size, {}).get(family)
                if ref is None:
                    print(f"no committed baseline for {size}.{family}; "
                          "skipping")
                    continue
                # same-machine ratio gate (host-speed independent); raw
                # step speed is bench_step's gate
                floor = (1 - REGRESSION_TOLERANCE) * ref["program_ratio"]
                ratio = rec["program_ratio"]
                status = "OK" if ratio >= floor else "REGRESSION"
                print(f"regression check [{status}] {size}.{family}: "
                      f"program/pattern ratio={ratio:.2f} vs committed "
                      f"{ref['program_ratio']:.2f} (floor {floor:.2f})")
                if ratio < floor:
                    failures.append(f"{size}.{family}")
        if failures:
            sys.exit(f"bench_scale regression in: {', '.join(failures)}")

    if broken:
        sys.exit(f"bench_scale points failed: {', '.join(broken)} "
                 "(finished points were salvaged to --out)")


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default):
        return argv[argv.index(flag) + 1] if flag in argv else default

    _spec = _opt("--spec", str(SPEC_DEFAULT))
    _sizes = _opt("--sizes", "tiny")
    _sizes = SIZES if _sizes == "all" else tuple(_sizes.split(","))
    _families = tuple(_opt("--families", ",".join(FAMILIES)).split(","))
    if "--child" in argv:
        _child(_spec, _sizes[0], _families[0],
               ckpt_dir=_opt("--ckpt", None),
               result_out=_opt("--result-out", None))
    else:
        _kill = _opt("--inject-kill", None)
        main(_spec, _sizes, _families, _opt("--out", None),
             _opt("--check", None),
             supervised="--supervised" in argv,
             inject_kill=float(_kill) if _kill is not None else None)
