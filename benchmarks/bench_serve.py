"""Open-loop serving-source overhead gate + committed SLO sweeps.

Two lanes, each in its own subprocess (clean cold-start wall clock, same
method as ``bench_collective.py``):

* ``bernoulli`` — closed-loop baseline: ``run_throughput`` over a
  uniform Bernoulli ``Traffic`` at the same load and slot count.
* ``arrival``   — the open-loop serving source: ``run_serving`` over
  ``Traffic("arrival", process="poisson")`` — per-endpoint request FIFOs,
  birth-slot latency, offered/delivered accounting.

Each child runs its driver once untimed (paying every jit compile) and
then reports the best of three timed runs, so the gated figure is
steady-state execution.  The gate is ``ratio = bernoulli_s / arrival_s`` — the arrival
source's slots/sec relative to plain Bernoulli injection on the same
fabric and machine.  Both lanes run on one host, so the ratio is
insensitive to CI host speed; ``--check BASELINE.json`` exits non-zero
if it regresses more than 20% below the committed baseline (i.e. the
serving source got disproportionately slower than the engine itself).

``--out`` merges the record into ``BENCH_serve.json`` under
``overhead.<fabric>``, preserving the committed ``sweeps`` section — the
MRLS-vs-Fat-Tree >= 1k-endpoint load-latency SLO curves produced by
``python -m repro.api serve-sweep examples/specs/serve_1k.json``
(``--attach-sweeps slo.json`` refreshes them from that command's
``--out`` file).
"""
import json
import pathlib
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

FABRICS = {
    # name -> mrls builder kwargs
    "tiny": {"n_leaves": 14, "u": 3, "d": 3, "seed": 0},
    "mrls1008": {"n_leaves": 168, "u": 6, "d": 6, "seed": 1},
}
LOAD = 0.3
WARM, MEASURE = 200, 4000
REGRESSION_TOLERANCE = 0.20


def _sim(fabric: str):
    from repro.core import build_tables, mrls
    from repro.simulator.engine import Simulator, SimConfig
    tables = build_tables(mrls(**FABRICS[fabric]))
    return Simulator(tables, SimConfig(policy="polarized", max_hops=8,
                                       pool=4096))


def phase_bernoulli(sim) -> dict:
    from repro.simulator.engine import Traffic
    r = sim.run_throughput(Traffic("uniform", load=LOAD), warm=WARM,
                           measure=MEASURE, seed=0)
    return {"throughput": float(r["throughput"])}


def phase_arrival(sim) -> dict:
    from repro.simulator.engine import Traffic
    r = sim.run_serving(Traffic("arrival", process="poisson", load=LOAD),
                        warm=WARM, measure=MEASURE, seed=0)
    return {"offered": float(r["offered"]),
            "delivered": float(r["delivered"])}


PHASES = {"bernoulli": phase_bernoulli, "arrival": phase_arrival}


def _child(phase: str, fabric: str):
    sim = _sim(fabric)
    t0 = time.perf_counter()
    PHASES[phase](sim)                       # pays tracing + compile
    compile_t = time.perf_counter() - t0
    best, out = None, None
    for _ in range(3):                       # steady-state, cache-hot
        t0 = time.perf_counter()
        out = PHASES[phase](sim)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    print(json.dumps({"t": best, "compile_t": compile_t, **out}))


def _spawn(phase: str, fabric: str) -> dict:
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()),
         "--phase", phase, "--fabric", fabric],
        check=True, capture_output=True, text=True, cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fabric: str, out_path, check_path, sweeps_path):
    from benchmarks.common import emit
    bern = _spawn("bernoulli", fabric)
    arr = _spawn("arrival", fabric)
    ratio = bern["t"] / arr["t"]
    record = {"load": LOAD, "slots": WARM + MEASURE,
              "bernoulli_s": bern["t"], "arrival_s": arr["t"],
              "bernoulli_compile_s": bern["compile_t"],
              "arrival_compile_s": arr["compile_t"],
              "ratio": ratio,
              "offered": arr["offered"], "delivered": arr["delivered"]}
    emit(f"bench_serve.{fabric}.bernoulli", bern["t"] * 1e6,
         f"tput={bern['throughput']:.3f}")
    emit(f"bench_serve.{fabric}.arrival", arr["t"] * 1e6,
         f"offered={arr['offered']:.3f} delivered={arr['delivered']:.3f}")
    emit(f"bench_serve.{fabric}.ratio", 0.0, f"{ratio:.2f}x of bernoulli")

    if out_path:
        doc = {}
        p = pathlib.Path(out_path)
        if p.exists():
            doc = json.loads(p.read_text())
        doc.setdefault("overhead", {})[fabric] = record
        if sweeps_path:
            doc["sweeps"] = json.loads(pathlib.Path(sweeps_path).read_text())
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
        print(f"wrote {p}")

    if check_path:
        base = json.loads(pathlib.Path(check_path).read_text())
        base = base.get("overhead", {}).get(fabric)
        if base is None:
            print(f"no committed baseline for fabric {fabric!r}; skipping "
                  "regression check")
        else:
            ref = base["ratio"]
            floor = (1 - REGRESSION_TOLERANCE) * ref
            status = "OK" if ratio >= floor else "REGRESSION"
            print(f"regression check [{status}]: ratio={ratio:.2f}x vs "
                  f"committed {ref:.2f}x (floor {floor:.2f}x)")
            if ratio < floor:
                sys.exit(1)


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default):
        return argv[argv.index(flag) + 1] if flag in argv else default
    _fabric = _opt("--fabric", "tiny")
    _phase = _opt("--phase", None)
    if _phase:
        _child(_phase, _fabric)
    else:
        main(_fabric, _opt("--out", None), _opt("--check", None),
             _opt("--attach-sweeps", None))
