"""Fault-injection gates: delta rebuild speed + degradation curves.

Two phases, each in its own subprocess (clean cold-start, same method as
``bench_serve.py``):

* ``rebuild`` — host routing-table repair cost after ~1% of links fail:
  ``RoutingTables.apply_failures`` (frontier-bounded delta, best of 3)
  vs a from-scratch ``build_tables`` (full BFS + mask pack, best of 3).
  The gated figure is ``ratio = full_s / delta_s`` — how much cheaper
  repairing the tables is than rebuilding them.  The acceptance floor
  at the 1k point is 5x; CI gates the tiny fabric against the committed
  baseline with the usual 20% tolerance.
* ``curve`` — end-to-end degradation sweep (``repro.api.degrade_sweep``):
  delivered throughput under ``policy="degraded"`` routing at
  0/1/2/5/10% of links down (one seeded ladder, failures landing in
  warmup).  The gated figure is throughput *retention* at the worst
  rate — the resilience headline.
* ``curve_hot`` — the same ladder past the saturation knee (uniform at
  loads 0.7 and 0.9): retention at load 0.5 mostly measures spare
  capacity absorbing the reroutes; at 0.9 the fabric has none, so the
  curve shows what degraded routing costs when every link matters.
* ``curve_tornado`` — the ladder under the adversarial ``tornado``
  permutation (leaf-level half-rotation, worst case for minimal paths)
  with failures armed: failures concentrate on already-hot inter-leaf
  links instead of averaging out.

``--out`` merges records into ``BENCH_faults.json`` under
``rebuild.<fabric>`` / ``curves.<fabric>`` / ``curves_hot.<fabric>`` /
``curves_tornado.<fabric>``, preserving committed sections; the
committed file carries the three-family 1k records (``mrls1k`` /
``fat_tree1k`` / ``dragonfly1k``) produced by running ``--fabric <name>
--out benchmarks/BENCH_faults.json`` for each.  ``--check
BASELINE.json`` exits non-zero when a gated figure (rebuild ratio,
retention at the worst rate for each curve family) falls more than 20%
below its committed value; sections absent from the baseline are
skipped, so gates arrive with their data.
"""
import json
import pathlib
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

# name -> (family, builder params)  [1k set matches headline_a2a.json]
FABRICS = {
    "tiny": ("mrls", {"n_leaves": 14, "u": 3, "d": 3, "seed": 0}),
    "mrls1k": ("mrls", {"n_leaves": 56, "u": 18, "d": 18, "seed": 1}),
    "fat_tree1k": ("fat_tree", {"radix": 16, "h": 2}),
    "dragonfly1k": ("dragonfly", {"a": 8, "p": 4, "h": 4}),
}
RATES = (0.0, 0.01, 0.02, 0.05, 0.10)
LOAD = 0.5
HOT_LOADS = (0.7, 0.9)      # past the saturation knee
TORNADO_LOAD = 0.5          # tornado saturates early; 0.5 is already hot
WARM, MEASURE = 200, 400
DOWN_SLOT = 10
REGRESSION_TOLERANCE = 0.20


def _network(fabric: str):
    from repro.api import NetworkSpec
    family, params = FABRICS[fabric]
    return NetworkSpec(family, params)


def phase_rebuild(fabric: str) -> dict:
    from repro.api import FailureSchedule
    from repro.api.registry import build_network
    from repro.core import build_tables, canonical_link_ids

    topo = build_network(_network(fabric))
    k = max(2, round(0.01 * len(canonical_link_ids(topo))))
    events = FailureSchedule.random_links(topo, k, down_slot=0,
                                          seed=0).events
    tables = build_tables(topo)

    full_best = None
    for _ in range(5):
        t0 = time.perf_counter()
        build_tables(topo)
        dt = time.perf_counter() - t0
        full_best = dt if full_best is None else min(full_best, dt)

    # the delta is microseconds-scale, so take the best of many reps to
    # shake allocator/cache noise out of the gated ratio
    delta_best, affected = None, 0
    for _ in range(20):
        t0 = time.perf_counter()
        delta = tables.apply_failures(down=events)
        dt = time.perf_counter() - t0
        delta_best = dt if delta_best is None else min(delta_best, dt)
        affected = delta.n_affected
        tables.apply_failures(up=events)             # restore, untimed

    return {"t": delta_best, "full_t": full_best,
            "ratio": full_best / delta_best, "links_down": k,
            "affected_leaves": affected, "n_leaves": int(topo.n_leaves)}


def _curve(fabric: str, pattern: str, load: float) -> dict:
    from repro.api import (DegradeSpec, Experiment, RouteSpec, WorkloadSpec,
                           degrade_sweep)

    base = Experiment(
        network=_network(fabric),
        route=RouteSpec(policy="degraded", max_hops=12),
        workload=WorkloadSpec(pattern, load=load),
        name=f"faults.{fabric}.{pattern}{load:g}", seed=0,
        warm=WARM, measure=MEASURE)
    t0 = time.perf_counter()
    rec = degrade_sweep(DegradeSpec(base=base, rates=tuple(RATES),
                                    down_slot=DOWN_SLOT, fail_seed=0))
    dt = time.perf_counter() - t0
    points = [{"rate": p["rate"], "n_links_down": p["n_links_down"],
               "delivered": p["delivered"], "retention": p["retention"],
               "p99": p["p99"]} for p in rec["points"]]
    return {"t": dt, "n_links": rec["n_links"], "points": points,
            "retention_worst": points[-1]["retention"]}


def phase_curve(fabric: str) -> dict:
    return _curve(fabric, "uniform", LOAD)


def phase_curve_hot(fabric: str) -> dict:
    return {"pattern": "uniform",
            "loads": {f"{load:g}": _curve(fabric, "uniform", load)
                      for load in HOT_LOADS}}


def phase_curve_tornado(fabric: str) -> dict:
    return {"pattern": "tornado", "load": TORNADO_LOAD,
            **_curve(fabric, "tornado", TORNADO_LOAD)}


PHASES = {"rebuild": phase_rebuild, "curve": phase_curve,
          "curve_hot": phase_curve_hot,
          "curve_tornado": phase_curve_tornado}


def _child(phase: str, fabric: str):
    print(json.dumps(PHASES[phase](fabric)))


def _spawn(phase: str, fabric: str) -> dict:
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()),
         "--phase", phase, "--fabric", fabric],
        check=True, capture_output=True, text=True, cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fabric: str, out_path, check_path):
    from benchmarks.common import emit
    reb = _spawn("rebuild", fabric)
    cur = _spawn("curve", fabric)
    hot = _spawn("curve_hot", fabric)
    tor = _spawn("curve_tornado", fabric)
    emit(f"bench_faults.{fabric}.rebuild_delta", reb["t"] * 1e6,
         f"{reb['ratio']:.1f}x faster than full "
         f"({reb['affected_leaves']}/{reb['n_leaves']} leaves)")
    emit(f"bench_faults.{fabric}.rebuild_full", reb["full_t"] * 1e6,
         f"{reb['links_down']} links down")
    emit(f"bench_faults.{fabric}.curve", cur["t"] * 1e6,
         f"retention@{RATES[-1]:g}={cur['retention_worst']:.3f}")
    for load, c in sorted(hot["loads"].items()):
        emit(f"bench_faults.{fabric}.curve_load{load}", c["t"] * 1e6,
             f"retention@{RATES[-1]:g}={c['retention_worst']:.3f}")
    emit(f"bench_faults.{fabric}.curve_tornado", tor["t"] * 1e6,
         f"retention@{RATES[-1]:g}={tor['retention_worst']:.3f}")

    if out_path:
        doc = {}
        p = pathlib.Path(out_path)
        if p.exists():
            doc = json.loads(p.read_text())
        meta = {"warm": WARM, "measure": MEASURE, "down_slot": DOWN_SLOT,
                "rates": list(RATES)}
        doc.setdefault("rebuild", {})[fabric] = reb
        doc.setdefault("curves", {})[fabric] = {"load": LOAD, **meta, **cur}
        doc.setdefault("curves_hot", {})[fabric] = {**meta, **hot}
        doc.setdefault("curves_tornado", {})[fabric] = {**meta, **tor}
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
        print(f"wrote {p}")

    if check_path:
        base = json.loads(pathlib.Path(check_path).read_text())
        failed = False
        ref = base.get("rebuild", {}).get(fabric)
        if ref is None:
            print(f"no committed rebuild baseline for {fabric!r}; skipping")
        else:
            floor = (1 - REGRESSION_TOLERANCE) * ref["ratio"]
            ok = reb["ratio"] >= floor
            print(f"regression check [{'OK' if ok else 'REGRESSION'}]: "
                  f"rebuild ratio={reb['ratio']:.1f}x vs committed "
                  f"{ref['ratio']:.1f}x (floor {floor:.1f}x)")
            failed |= not ok

        def _gate(label, got, ref):
            nonlocal failed
            if ref is None:
                print(f"no committed {label} baseline for {fabric!r}; "
                      "skipping")
                return
            floor = (1 - REGRESSION_TOLERANCE) * ref["retention_worst"]
            ok = got["retention_worst"] >= floor
            print(f"regression check [{'OK' if ok else 'REGRESSION'}]: "
                  f"{label} retention@{RATES[-1]:g}="
                  f"{got['retention_worst']:.3f} vs committed "
                  f"{ref['retention_worst']:.3f} (floor {floor:.3f})")
            failed |= not ok

        _gate("curve", cur, base.get("curves", {}).get(fabric))
        hot_ref = base.get("curves_hot", {}).get(fabric)
        for load, c in sorted(hot["loads"].items()):
            _gate(f"curve@load{load}", c,
                  (hot_ref or {}).get("loads", {}).get(load))
        _gate("curve_tornado", tor,
              base.get("curves_tornado", {}).get(fabric))
        if failed:
            sys.exit(1)


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default):
        return argv[argv.index(flag) + 1] if flag in argv else default
    _fabric = _opt("--fabric", "tiny")
    _phase = _opt("--phase", None)
    if _phase:
        _child(_phase, _fabric)
    else:
        main(_fabric, _opt("--out", None), _opt("--check", None))
