"""Collective-execution speedup demo (ISSUE 4 acceptance criterion).

Before/after wall clock of a replicas=8 Rabenseifner allreduce on the
1008-endpoint MRLS fabric.  Same method as ``bench_replicas.py`` /
``bench_step.py``: each variant runs in its own subprocess so every
timing is a clean cold-start wall clock.

* ``before`` — the pre-program host phase loop, emulated faithfully: one
  fresh batched ``Traffic("phase")`` state per Rabenseifner phase (host
  state build + transfer), one ``run_completion`` device loop per phase
  (a distinct compile per distinct ``phase_packets`` value), and a full
  host sync between phases.
* ``after``  — the device-resident program executor: the whole R-replica,
  P-phase schedule compiles once and runs as **one** ``lax.while_loop``
  with the phase counter, ejection targets, and exact per-phase
  completion slots on device (``Simulator.run_program``).

Both paths are bitwise-identical per phase (locked by
``tests/test_engine_parity.py``), so the comparison is pure execution
overhead.  Emits ``name,us_total,derived`` rows plus a machine-readable
``BENCH_collective.json`` (``--out``).  ``--check BASELINE.json`` exits
non-zero if the before/after speedup regresses more than 20% below the
committed baseline for the same fabric (the ratio compares two
measurements from one machine, so the gate is insensitive to CI host
speed).  Acceptance: after >= 1.5x before on the 1008-endpoint MRLS.
"""
import json
import pathlib
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

FABRICS = {
    # name -> (mrls builder kwargs, ranks, vec_packets)
    "tiny": ({"n_leaves": 14, "u": 3, "d": 3, "seed": 0}, 16, 8),
    "mrls1008": ({"n_leaves": 168, "u": 6, "d": 6, "seed": 1}, 512, 16),
}
REPLICAS = 8
CHUNK, MAX_SLOTS = 16, 20_000
REGRESSION_TOLERANCE = 0.20


def _sim(fabric: str):
    from repro.core import build_tables, mrls
    from repro.simulator.engine import Simulator, SimConfig
    params, ranks, vec = FABRICS[fabric]
    tables = build_tables(mrls(**params))
    return Simulator(tables, SimConfig(policy="polarized", max_hops=8)), \
        ranks, vec


def phase_before(fabric: str, replicas: int) -> dict:
    """Pre-program host loop, batched: per phase — fresh batch state,
    hand-patched partner table, one device completion loop, host sync."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.collectives import rabenseifner_phases
    from repro.simulator.engine import Traffic
    sim, ranks, vec = _sim(fabric)
    seeds = list(range(1, replicas + 1))
    total = np.zeros(replicas, np.int64)
    stall = np.zeros(replicas, np.int64)
    ok = np.ones(replicas, bool)
    for ph in rabenseifner_phases(ranks, vec):
        tr = Traffic("phase", phase_packets=ph["packets"])
        partner = np.arange(sim.S, dtype=np.int32)
        partner[:ranks] = ph["partner"]
        bst = sim.make_batch_state(tr, seeds)
        bst["partner"] = jnp.broadcast_to(jnp.asarray(partner),
                                          (replicas, sim.S))
        r = sim.run_completion(tr, expected=sim.S * ph["packets"],
                               chunk=CHUNK, max_slots=MAX_SLOTS, state=bst)
        ok &= np.asarray(r["completed"])
        total += np.asarray(r["slots"])
        stall += np.asarray(r["pool_stall"])
    assert ok.all()
    return {"slots": [int(x) for x in total]}


def phase_after(fabric: str, replicas: int) -> dict:
    """One compiled program run: all replicas, all phases, zero per-phase
    host round-trips."""
    from repro.workloads import compile_program, rabenseifner_program
    sim, ranks, vec = _sim(fabric)
    cp = compile_program(rabenseifner_program(sim.S, ranks, vec))
    r = sim.run_program(cp, chunk=CHUNK, max_slots=MAX_SLOTS,
                        seeds=list(range(1, replicas + 1)))
    assert bool(r["completed"].all())
    return {"slots": [int(x) for x in r["slots"]]}


PHASES = {"before": phase_before, "after": phase_after}


def _child(phase: str, fabric: str, replicas: int):
    t0 = time.perf_counter()
    out = PHASES[phase](fabric, replicas)
    print(json.dumps({"t": time.perf_counter() - t0, **out}))


def _spawn(phase: str, fabric: str, replicas: int) -> dict:
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()),
         "--phase", phase, "--fabric", fabric,
         "--replicas", str(replicas)],
        check=True, capture_output=True, text=True, cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fabric: str, replicas: int, out_path, check_path):
    from benchmarks.common import emit
    before = _spawn("before", fabric, replicas)
    after = _spawn("after", fabric, replicas)
    # the program path is the host loop, bitwise — any slot drift means
    # the benchmark is comparing different computations
    assert before["slots"] == after["slots"], (before, after)
    speedup = before["t"] / after["t"]
    record = {"replicas": replicas,
              "before_host_loop_s": before["t"],
              "after_program_s": after["t"],
              "speedup": speedup,
              "slots": after["slots"]}
    emit(f"bench_collective.{fabric}.before_host_loop", before["t"] * 1e6,
         f"slots={before['slots'][0]}")
    emit(f"bench_collective.{fabric}.after_program", after["t"] * 1e6,
         f"slots={after['slots'][0]}")
    emit(f"bench_collective.{fabric}.speedup", 0.0, f"{speedup:.2f}x")

    if out_path:
        doc = {}
        p = pathlib.Path(out_path)
        if p.exists():
            doc = json.loads(p.read_text())
        doc[fabric] = record
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
        print(f"wrote {p}")

    if check_path:
        base = json.loads(pathlib.Path(check_path).read_text()).get(fabric)
        if base is None:
            print(f"no committed baseline for fabric {fabric!r}; skipping "
                  "regression check")
        else:
            ref = base["speedup"]
            floor = (1 - REGRESSION_TOLERANCE) * ref
            status = "OK" if speedup >= floor else "REGRESSION"
            print(f"regression check [{status}]: speedup={speedup:.2f}x "
                  f"vs committed {ref:.2f}x (floor {floor:.2f}x)")
            if speedup < floor:
                sys.exit(1)


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default):
        return argv[argv.index(flag) + 1] if flag in argv else default
    _fabric = _opt("--fabric", "mrls1008")
    _replicas = int(_opt("--replicas", str(REPLICAS)))
    _phase = _opt("--phase", None)
    if _phase:
        _child(_phase, _fabric, _replicas)
    else:
        main(_fabric, _replicas, _opt("--out", None), _opt("--check", None))
