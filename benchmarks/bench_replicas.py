"""Batched-replica speedup demo (ISSUE 2 acceptance criterion).

Before/after timing on a 1008-endpoint MRLS all2all completion experiment.
Each variant runs in its own subprocess so every timing is a clean
cold-start wall clock (same-process ordering leaks allocator and cache
state between variants):

* ``before`` — the pre-batching path, emulated faithfully: one scalar
  ``run()`` per seed, each building a private simulator, driving a *python*
  chunk loop that syncs ``ejected`` to the host every chunk, and clearing
  the jit caches on close (the old ``run()`` teardown) — so every seed pays
  tables + trace + XLA compile again.
* ``after.batched`` — ``run(Experiment(replicas=R))``: all R seeds in one
  ``jax.vmap``-batched executable, one compile, completion detected on
  device by a ``lax.while_loop`` (zero per-chunk host syncs).
* ``after.sequential`` — R scalar runs through the new device-side loop
  sharing one :class:`SimulatorCache`, for reference.

Rows: ``name,us_total,derived``.  Acceptance: batched >= 3x before.
``--replicas N`` / ``--rounds N`` override the defaults.
"""
import json
import pathlib
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

NET_PARAMS = {"n_leaves": 168, "u": 6, "d": 6, "seed": 1}   # S = 1008
CHUNK, MAX_SLOTS = 8, 20_000


def _specs():
    from repro.api import NetworkSpec, RouteSpec
    return (NetworkSpec("mrls", NET_PARAMS),
            RouteSpec(policy="polarized", vcs=4, max_hops=8))


def phase_before(replicas: int, rounds: int) -> list:
    """Pre-PR scalar completion runs: private simulator per seed,
    host-synced python chunk loop, chunk-granular completion slot,
    cache-clearing teardown."""
    from repro.api import open_simulator
    from repro.simulator.engine import Traffic
    net, route = _specs()
    slots = []
    for seed in range(1, replicas + 1):
        with open_simulator(net, route) as sim:
            tr = Traffic("all2all", rounds=rounds)
            st = sim.make_state(tr, seed)
            expected = sim.S * rounds
            done_at = None
            while int(st["slot"]) < MAX_SLOTS:
                st = sim.run_chunk(st, tr, CHUNK)
                if int(st["ejected"]) >= expected:
                    done_at = int(st["slot"])
                    break
            slots.append(done_at or int(st["slot"]))
    return slots


def phase_batched(replicas: int, rounds: int) -> list:
    from repro.api import Experiment, WorkloadSpec, run
    net, route = _specs()
    res = run(Experiment(network=net, route=route,
                         workload=WorkloadSpec("all2all", rounds=rounds),
                         chunk=CHUNK, max_slots=MAX_SLOTS,
                         seed=1, replicas=replicas))
    return list(res.per_replica["slots"])


def phase_sequential(replicas: int, rounds: int) -> list:
    from repro.api import Experiment, SimulatorCache, WorkloadSpec, run
    net, route = _specs()
    with SimulatorCache() as cache:
        return [run(Experiment(network=net, route=route,
                               workload=WorkloadSpec("all2all", rounds=rounds),
                               chunk=CHUNK, max_slots=MAX_SLOTS, seed=s),
                    cache=cache).slots
                for s in range(1, replicas + 1)]


PHASES = {"before": phase_before, "batched": phase_batched,
          "sequential": phase_sequential}


def _child(phase: str, replicas: int, rounds: int):
    t0 = time.perf_counter()
    slots = PHASES[phase](replicas, rounds)
    print(json.dumps({"t": time.perf_counter() - t0, "slots": slots}))


def _spawn(phase: str, replicas: int, rounds: int) -> dict:
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()),
         "--phase", phase, "--replicas", str(replicas),
         "--rounds", str(rounds)],
        check=True, capture_output=True, text=True, cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(replicas: int = 8, rounds: int = 6):
    from benchmarks.common import emit
    before = _spawn("before", replicas, rounds)
    batched = _spawn("batched", replicas, rounds)
    seq = _spawn("sequential", replicas, rounds)

    assert batched["slots"] == seq["slots"]          # batched == scalar, bitwise
    assert all(n <= o for n, o in zip(batched["slots"], before["slots"]))

    emit("bench_replicas.before_8x_scalar", before["t"] * 1e6,
         f"slots={before['slots']}")
    emit("bench_replicas.after_batched", batched["t"] * 1e6,
         f"slots={batched['slots']}")
    emit("bench_replicas.after_sequential_shared_cache", seq["t"] * 1e6,
         f"slots={seq['slots']}")
    emit("bench_replicas.speedup_batched_vs_before", 0.0,
         f"{before['t'] / batched['t']:.2f}x")


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default, cast=int):
        return cast(argv[argv.index(flag) + 1]) if flag in argv else default
    _replicas = _opt("--replicas", 8)
    _rounds = _opt("--rounds", 6)
    _phase = _opt("--phase", None, str)
    if _phase:
        _child(_phase, _replicas, _rounds)
    else:
        main(_replicas, _rounds)
