"""Per-slot step-loop speedup demo (ISSUE 3 acceptance criterion).

Isolates slots/sec of the simulator ``_step`` hot path before vs. after the
device-resident overhaul (compact routing tables + O(S) free-list +
donated buffers).  Same method as ``bench_replicas.py``: each variant runs
in its own subprocess so every timing is a clean cold-start wall clock.

* ``before`` — the pre-overhaul step, emulated faithfully by
  :class:`LegacySimulator`: full ``jnp.nonzero`` pool scan per inject,
  ``[NR, P]`` int32 distance-row gathers per crossbar sub-round, inline
  index arithmetic, and un-donated chunk state.
* ``after``  — the current engine (``backend="xla"``): compact bitmask /
  int16 tables, ring-buffer free-list, static requester geometry, donated
  buffers.
* ``pallas`` — optional (``--pallas``): the fused arbitration kernel in
  interpret mode (Python-executed kernel body — a correctness path on CPU,
  not a fast one).

Emits ``name,us_total,derived`` rows plus a machine-readable
``BENCH_step.json`` (``--out``).  ``--check BASELINE.json`` exits non-zero
if the measured before/after speedup regresses more than 20% below the
committed baseline's speedup for the same fabric (the ratio is measured
on one machine in one run, so the gate is insensitive to CI host speed;
absolute slots/sec vs the baseline host is printed for context).
Acceptance: after >= 2x before on the 1008-endpoint MRLS all2all loop.
"""
import functools
import json
import pathlib
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

FABRICS = {
    # name -> (mrls builder kwargs, timed slots per rep, reps)
    "tiny": ({"n_leaves": 14, "u": 3, "d": 3, "seed": 0}, 256, 5),
    "mrls1008": ({"n_leaves": 168, "u": 6, "d": 6, "seed": 1}, 64, 5),
    # the paper's 104976-endpoint f=1 MRLS (CPU-hours; for TPU hosts)
    "full": ({"n_leaves": 5832, "u": 18, "d": 18, "seed": 1}, 8, 2),
}
REGRESSION_TOLERANCE = 0.20


def _make_legacy_class():
    """Subclass emulating the pre-overhaul step (old gather/scan hot path).

    Built lazily so importing this file stays cheap for ``--help``.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.routing import polarized_port_mask
    from repro.simulator.engine import BIG, Simulator

    class LegacySimulator(Simulator):
        """Pre-ISSUE-3 step: nonzero pool scan, [NR,P] int32 distance
        gathers, per-round index arithmetic, no buffer donation."""

        def __init__(self, tables, cfg):
            super().__init__(tables, cfg)
            self.dist32 = jnp.asarray(tables.dist_leaf, jnp.int32)

        def init_state(self, traffic, seed_arrays):
            # restore the pre-overhaul per-packet layout: free bitmap +
            # unpacked src/dst/born/hops arrays
            st = super().init_state(traffic, seed_arrays)
            st["p_free"] = jnp.ones(self.pool, bool)
            for k in ("p_src", "p_dst", "p_dst_sw", "p_born", "p_hops"):
                st[k] = jnp.zeros(self.pool, jnp.int32)
            return st

        # -------------------------------------------------------------- #
        def _inject(self, st, key, traffic):
            S, d = self.S, self.d_leaf
            e = jnp.arange(S, dtype=jnp.int32)
            k1, k2, k3, k4 = jax.random.split(key, 4)

            idle = st["msg_rem"] == 0
            pat = traffic.pattern
            assert pat == "all2all", "legacy emulation benches all2all only"
            start = idle & (st["prog"] < traffic.rounds)
            dst = (e + st["prog"] + 1) % S
            size = jnp.ones((S,), jnp.int32)

            msg_rem = jnp.where(start, size, st["msg_rem"])
            msg_dst = jnp.where(start, dst, st["msg_dst"])
            prog = st["prog"] + start.astype(jnp.int32)

            want = (msg_rem > 0) & (st["eq_len"] < self.QE)
            src_lr = e // d
            dst_lr = msg_dst // d
            local = src_lr == dst_lr
            deliver_local = want & local
            want_net = want & ~local

            # the old O(pool) allocator: full free-bitmap compaction
            rank = jnp.cumsum(want_net.astype(jnp.int32)) - 1
            free_idx = jnp.nonzero(st["p_free"], size=min(S, self.pool),
                                   fill_value=-1)[0].astype(jnp.int32)
            in_free = rank < free_idx.shape[0]
            pid = jnp.where(want_net & in_free,
                            free_idx[jnp.clip(rank, 0, free_idx.shape[0] - 1)],
                            -1)
            ok = want_net & (pid >= 0)

            mid = jnp.full((S,), -1, jnp.int32)
            if self.cfg.policy in ("ugal", "valiant"):
                mid_lr = jax.random.randint(k4, (S,), 0, self.n1)
                if self.cfg.policy == "ugal":
                    sw = self.leaf_ids[src_lr]
                    nb = self.nbrs0[sw]
                    occ0 = st["qlen"].reshape(self.N, self.P, self.V)[
                        nb, self.nbr_port[sw], 0]
                    vp = self.valid_port[sw]

                    def best(t_lr):
                        d_n = self.dist32[t_lr[:, None], nb]
                        d_c = self.dist32[t_lr, sw]
                        m = vp & (d_n == d_c[:, None] - 1)
                        return jnp.min(jnp.where(m, occ0, 1 << 20), axis=1)

                    q_min = best(dst_lr)
                    q_val = best(mid_lr)
                    d_min = self.dist32[dst_lr, sw]
                    d_val = (self.dist32[mid_lr, sw]
                             + self.dist32[dst_lr, self.leaf_ids[mid_lr]])
                    take_val = q_min * d_min > q_val * d_val
                    mid = jnp.where(take_val, mid_lr, -1)
                else:
                    mid = mid_lr

            widx = jnp.where(ok, jnp.maximum(pid, 0), self.pool)
            st = dict(st)
            st["p_free"] = st["p_free"].at[widx].set(False, mode="drop")
            st["p_src"] = st["p_src"].at[widx].set(src_lr, mode="drop")
            st["p_dst"] = st["p_dst"].at[widx].set(dst_lr, mode="drop")
            st["p_dst_sw"] = st["p_dst_sw"].at[widx].set(
                self.leaf_ids[dst_lr], mode="drop")
            st["p_mid"] = st["p_mid"].at[widx].set(mid, mode="drop")
            st["p_born"] = st["p_born"].at[widx].set(st["slot"], mode="drop")
            st["p_hops"] = st["p_hops"].at[widx].set(0, mode="drop")
            pos = (st["eq_head"] + st["eq_len"]) % self.QE
            st["eq_buf"] = st["eq_buf"].at[e, jnp.where(ok, pos, self.QE)].set(
                jnp.maximum(pid, 0), mode="drop")
            st["eq_len"] = st["eq_len"] + ok.astype(jnp.int32)

            consumed = ok | deliver_local
            st["msg_rem"] = msg_rem - consumed.astype(jnp.int32)
            st["msg_dst"] = msg_dst
            st["prog"] = prog
            n_local = deliver_local.sum(dtype=jnp.int32)
            st["created"] = st["created"] + ok.sum(dtype=jnp.int32) + n_local
            st["ejected"] = st["ejected"] + n_local
            st["pool_stall"] = st["pool_stall"] + (want_net & ~ok).sum(
                dtype=jnp.int32)
            st["lat_hist"] = st["lat_hist"].at[1].add(n_local)
            return st

        # -------------------------------------------------------------- #
        def _crossbar_round(self, st, key, ep_active):
            N, P, V, Q, S = self.N, self.P, self.V, self.Q, self.S
            OQ = self.cfg.out_queue
            k_vc, k_tie, k_arb = jax.random.split(key, 3)

            qlen3 = st["qlen"].reshape(N, P, V)
            vc_prio = jax.random.uniform(k_vc, (N, P, V))
            vc_prio = jnp.where(qlen3 > 0, vc_prio, -1.0)
            vc_sel = jnp.argmax(vc_prio, axis=2)
            has_pkt = jnp.take_along_axis(
                qlen3, vc_sel[:, :, None], 2)[:, :, 0] > 0

            q_idx = (jnp.arange(N * P, dtype=jnp.int32).reshape(N, P) * V
                     + vc_sel.astype(jnp.int32)).reshape(-1)
            head = st["qbuf"].reshape(-1)[q_idx * Q + st["qhead"][q_idx]]
            net_pkt = jnp.where(has_pkt.reshape(-1), head, -1)

            ep_head = st["eq_buf"].reshape(-1)[
                jnp.arange(S, dtype=jnp.int32) * self.QE + st["eq_head"]]
            ep_pkt = jnp.where((st["eq_len"] > 0) & ep_active, ep_head, -1)

            cur_net = jnp.repeat(jnp.arange(N, dtype=jnp.int32), P)
            cur_ep = self.leaf_ids[jnp.arange(S, dtype=jnp.int32) // self.d_leaf]
            cur = jnp.concatenate([cur_net, cur_ep])
            pkt = jnp.concatenate([net_pkt, ep_pkt])
            NR = cur.shape[0]
            valid = pkt >= 0
            pkt0 = jnp.maximum(pkt, 0)

            s_lr, t_lr = st["p_src"][pkt0], st["p_dst"][pkt0]
            hops = st["p_hops"][pkt0]
            dst_sw = st["p_dst_sw"][pkt0]
            mid_lr = st["p_mid"][pkt0]

            eject = valid & (cur == dst_sw)
            route = valid & ~eject

            nb = self.nbrs0[cur]
            vp = self.valid_port[cur]
            dflat = self.dist32.reshape(-1)
            d_ct = dflat[t_lr * N + cur]
            d_nt = dflat[(t_lr * N)[:, None] + nb]           # [NR,P] gather

            pol = self.cfg.policy
            if pol == "polarized":
                d_cs = dflat[s_lr * N + cur]
                d_ns = dflat[(s_lr * N)[:, None] + nb]       # [NR,P] gather
                allowed, deroute = polarized_port_mask(
                    d_cs[:, None], d_ct[:, None], d_ns, d_nt,
                    hops[:, None], self.cfg.max_hops, vp)
                next_vc = jnp.minimum(hops // 2, V - 1)
            elif pol in ("minimal_adaptive", "ksp"):
                allowed = vp & (d_nt == d_ct[:, None] - 1)
                deroute = jnp.zeros_like(allowed)
                next_vc = jnp.minimum(hops // 2, V - 1)
            elif pol in ("ugal", "valiant"):
                tgt = jnp.where(mid_lr >= 0, mid_lr, t_lr)
                d_cg = dflat[tgt * N + cur]
                d_ng = dflat[(tgt * N)[:, None] + nb]
                allowed = vp & (d_ng == d_cg[:, None] - 1)
                deroute = jnp.zeros_like(allowed)
                next_vc = jnp.minimum(hops, V - 1)
            else:
                raise ValueError(pol)

            oq_idx = (cur[:, None] * P
                      + jnp.arange(P, dtype=jnp.int32)[None, :]) * V \
                + next_vc[:, None]
            dq_idx = (nb * P + self.nbr_port[cur]) * V + next_vc[:, None]
            occ = st["oq_len"][oq_idx] + st["qlen"][dq_idx]
            credit = st["oq_len"][oq_idx] < OQ
            score = (occ.astype(jnp.float32)
                     + self.cfg.deroute_penalty * deroute
                     + jax.random.uniform(k_tie, (NR, P)))
            if pol == "ksp":
                score = jax.random.uniform(k_tie, (NR, P))
            score = jnp.where(allowed & credit, score, BIG)
            port = jnp.argmin(score, axis=1).astype(jnp.int32)
            can_move = route & (jnp.min(score, axis=1) < BIG)

            out_key = cur * P + port
            rnd = jax.random.randint(k_arb, (NR,), 0, 1 << 8, dtype=jnp.int32)
            prio = (rnd << 23) | jnp.arange(NR, dtype=jnp.int32)
            prio = jnp.where(can_move, prio, -1)
            seg = jnp.full((N * P,), -1, jnp.int32).at[out_key].max(prio)
            win = can_move & (seg[out_key] == prio)

            tgt_q = oq_idx[jnp.arange(NR), port]
            tgt_pos = tgt_q * OQ + (st["oq_head"][tgt_q]
                                    + st["oq_len"][tgt_q]) % OQ
            oq_buf = st["oq_buf"].reshape(-1)
            oq_buf = oq_buf.at[jnp.where(win, tgt_pos, oq_buf.shape[0])].set(
                pkt0, mode="drop")
            oq_len = st["oq_len"].at[jnp.where(win, tgt_q, self.NQ)].add(
                1, mode="drop")

            leave = win | eject
            net_leave = leave[: N * P]
            qi = jnp.where(net_leave, q_idx, self.NQ)
            qhead = st["qhead"].at[qi].add(1, mode="drop") % Q
            qlen = st["qlen"].at[qi].add(-1, mode="drop")
            ep_leave = leave[N * P:]
            eq_head = (st["eq_head"] + ep_leave.astype(jnp.int32)) % self.QE
            eq_len = st["eq_len"] - ep_leave.astype(jnp.int32)

            p_free = st["p_free"].at[jnp.where(eject, pkt0, self.pool)].set(
                True, mode="drop")
            lat = jnp.clip(st["slot"] - st["p_born"][pkt0] + 1, 0,
                           self.cfg.hist_bins - 1)
            lat_hist = st["lat_hist"].at[jnp.where(eject, lat, 0)].add(
                jnp.where(eject, 1, 0))

            st = dict(st)
            st["oq_buf"] = oq_buf.reshape(self.NQ, OQ)
            st["oq_len"] = oq_len
            st["qhead"], st["qlen"] = qhead, qlen
            st["eq_head"], st["eq_len"] = eq_head, eq_len
            st["p_free"] = p_free
            st["lat_hist"] = lat_hist
            st["ejected"] = st["ejected"] + eject.sum(dtype=jnp.int32)
            st["hop_sum"] = st["hop_sum"] + jnp.where(eject, hops, 0).sum(
                dtype=jnp.int32)
            return st

        # -------------------------------------------------------------- #
        def _link_phase(self, st, key):
            N, P, V, Q = self.N, self.P, self.V, self.Q
            OQ = self.cfg.out_queue
            oq_len3 = st["oq_len"].reshape(N, P, V)
            np_idx = jnp.arange(N * P, dtype=jnp.int32)
            sw = np_idx // P
            pt = np_idx % P
            nb = self.nbrs0[sw, pt]
            nbp = self.nbr_port[sw, pt]
            link_ok = self.valid_port[sw, pt]
            dq = (nb[:, None] * P + nbp[:, None]) * V + jnp.arange(
                V, dtype=jnp.int32)
            room = st["qlen"][dq] < Q
            nonempty = oq_len3.reshape(N * P, V) > 0
            cand = nonempty & room & link_ok[:, None]
            prio = jnp.where(cand, jax.random.uniform(key, (N * P, V)), -1.0)
            vcs = jnp.argmax(prio, axis=1).astype(jnp.int32)
            send = jnp.take_along_axis(cand, vcs[:, None], 1)[:, 0]

            src_q = np_idx * V + vcs
            pkt = st["oq_buf"].reshape(-1)[src_q * OQ + st["oq_head"][src_q]]
            pkt0 = jnp.maximum(pkt, 0)
            tgt_q = dq[np_idx, vcs]
            tgt_pos = tgt_q * Q + (st["qhead"][tgt_q] + st["qlen"][tgt_q]) % Q

            qbuf = st["qbuf"].reshape(-1)
            qbuf = qbuf.at[jnp.where(send, tgt_pos, qbuf.shape[0])].set(
                pkt0, mode="drop")
            qlen = st["qlen"].at[jnp.where(send, tgt_q, self.NQ)].add(
                1, mode="drop")
            sq = jnp.where(send, src_q, self.NQ)
            oq_head = st["oq_head"].at[sq].add(1, mode="drop") % OQ
            oq_len = st["oq_len"].at[sq].add(-1, mode="drop")
            p_hops = st["p_hops"].at[jnp.where(send, pkt0, self.pool)].add(
                1, mode="drop")
            mid_lr = st["p_mid"][pkt0]
            reached_mid = send & (mid_lr >= 0) & (
                nb == self.leaf_ids[jnp.maximum(mid_lr, 0)])
            p_mid = st["p_mid"].at[jnp.where(reached_mid, pkt0, self.pool)
                                   ].set(-1, mode="drop")

            st = dict(st)
            st["qbuf"] = qbuf.reshape(self.NQ, Q)
            st["qlen"] = qlen
            st["oq_head"], st["oq_len"] = oq_head, oq_len
            st["p_hops"], st["p_mid"] = p_hops, p_mid
            return st

        # un-donated chunk runner (the old double-buffering behaviour)
        @functools.partial(jax.jit, static_argnums=(0, 2, 3))
        def run_chunk(self, st, traffic, n_slots):
            def body(carry, _):
                return self._step(carry, traffic), None
            return jax.lax.scan(body, st, None, length=n_slots)[0]

    return LegacySimulator


# ---------------------------------------------------------------------- #
def _measure(sim, n_slots: int, reps: int) -> float:
    """slots/sec of the compiled step loop (compile + warm rep excluded).

    Best-of-reps: each rep is timed separately and the fastest wins, so a
    background-load hiccup in one rep doesn't skew the comparison.
    """
    import jax
    from repro.simulator.engine import Traffic
    tr = Traffic("all2all", rounds=1 << 30)     # injectors never go idle
    st = sim.make_state(tr, 0)
    st = jax.block_until_ready(sim.run_chunk(st, tr, n_slots))   # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        st = jax.block_until_ready(sim.run_chunk(st, tr, n_slots))
        best = min(best, time.perf_counter() - t0)
    return n_slots / best


def _child(phase: str, fabric: str, policy: str):
    from repro.core import mrls, build_tables
    from repro.simulator.engine import Simulator, SimConfig
    params, n_slots, reps = FABRICS[fabric]
    tables = build_tables(mrls(**params))
    cfg = SimConfig(policy=policy, max_hops=10,
                    backend="pallas" if phase == "pallas" else "xla")
    cls = _make_legacy_class() if phase == "before" else Simulator
    sim = cls(tables, cfg)
    sps = _measure(sim, n_slots, reps)
    print(json.dumps({"slots_per_sec": sps}))


def _spawn(phase: str, fabric: str, policy: str) -> float:
    out = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()),
         "--phase", phase, "--fabric", fabric, "--policy", policy],
        check=True, capture_output=True, text=True, cwd=str(_ROOT))
    return json.loads(out.stdout.strip().splitlines()[-1])["slots_per_sec"]


def main(fabric: str, policy: str, out_path, check_path, with_pallas: bool):
    from benchmarks.common import emit
    before = _spawn("before", fabric, policy)
    after = _spawn("after", fabric, policy)
    record = {"policy": policy,
              "before_slots_per_sec": before,
              "after_slots_per_sec": after,
              "speedup": after / before}
    emit(f"bench_step.{fabric}.before", 1e6 / before,
         f"{before:.1f} slots/s")
    emit(f"bench_step.{fabric}.after", 1e6 / after, f"{after:.1f} slots/s")
    emit(f"bench_step.{fabric}.speedup", 0.0, f"{after / before:.2f}x")
    if with_pallas:
        pallas = _spawn("pallas", fabric, policy)
        record["pallas_interpret_slots_per_sec"] = pallas
        emit(f"bench_step.{fabric}.pallas_interpret", 1e6 / pallas,
             f"{pallas:.1f} slots/s")

    if out_path:
        doc = {}
        p = pathlib.Path(out_path)
        if p.exists():
            doc = json.loads(p.read_text())
        doc[fabric] = record
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
        print(f"wrote {p}")

    if check_path:
        base = json.loads(pathlib.Path(check_path).read_text()).get(fabric)
        if base is None:
            print(f"no committed baseline for fabric {fabric!r}; skipping "
                  "regression check")
        else:
            # the hard gate is the before/after SPEEDUP, which compares two
            # measurements from this same machine and so is insensitive to
            # how fast the CI runner happens to be; absolute slots/sec
            # against the baseline host is reported for context only
            ref_speedup = base["speedup"]
            floor = (1 - REGRESSION_TOLERANCE) * ref_speedup
            speedup = after / before
            abs_ref = base["after_slots_per_sec"]
            print(f"context: after={after:.1f} slots/s vs baseline host "
                  f"{abs_ref:.1f} ({after / abs_ref:.2f}x of baseline)")
            status = "OK" if speedup >= floor else "REGRESSION"
            print(f"regression check [{status}]: speedup={speedup:.2f}x "
                  f"vs committed {ref_speedup:.2f}x (floor {floor:.2f}x)")
            if speedup < floor:
                sys.exit(1)


if __name__ == "__main__":
    argv = sys.argv[1:]

    def _opt(flag, default):
        return argv[argv.index(flag) + 1] if flag in argv else default
    _fabric = _opt("--fabric", "mrls1008")
    if "--full" in argv:
        _fabric = "full"
    _policy = _opt("--policy", "polarized")
    _phase = _opt("--phase", None)
    if _phase:
        _child(_phase, _fabric, _policy)
    else:
        main(_fabric, _policy, _opt("--out", None), _opt("--check", None),
             "--pallas" in argv)
