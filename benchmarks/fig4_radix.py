"""Figure 4: radix required to connect S endpoints per topology family.

For each target S: the smallest radix R such that the topology (at its
normalization — MRLS at f=1, FT non-blocking, OFT full) connects >= S
endpoints.
"""
import math
import sys

sys.path.insert(0, "src")

from repro.core import mrls_design, mrls_expected_A, prob_dstar_leq
from benchmarks.common import emit, timed


def ft_radix(S: int, h: int) -> int:
    # S = 2 (R/2)^(h+1)
    return 2 * math.ceil((S / 2) ** (1 / (h + 1)))


def oft_radix(S: int) -> int:
    # S = 2(q^2+q+1)(q+1); find the smallest prime-power-ish q
    q = 2
    while 2 * (q * q + q + 1) * (q + 1) < S:
        q += 1
    return 2 * (q + 1)


def mrls_radix(S: int, d_star_max: int = 7) -> int:
    """Smallest even R with f=1 whose MRLS reaches S at D* <= d_star_max."""
    for R in range(6, 256, 2):
        n1, n2, u, d = mrls_design(S, R, 1.0)
        if prob_dstar_leq(n1, n2, u, R, d_star_max) > 0.5:
            return R
    return -1


def main(full: bool = True):
    print("# fig4: radix required per topology to reach S endpoints")
    for S in (1_000, 10_000, 100_000, 1_000_000, 10_000_000):
        r, us = timed(lambda: mrls_radix(S))
        emit(f"fig4.mrls@S={S}", us, f"R={r}")
        emit(f"fig4.ft3@S={S}", 0.1, f"R={ft_radix(S, 2)}")
        emit(f"fig4.ft4@S={S}", 0.1, f"R={ft_radix(S, 3)}")
        emit(f"fig4.oft@S={S}", 0.1, f"R={oft_radix(S)}")


if __name__ == "__main__":
    main()
