"""Figure 7: MRLS vs Dragonfly / Dragonfly+ at 16K endpoints and
Cost_links <= 1.5.  Scaled default: ~400-endpoint family; ``--full``
builds DF(32,16512)/DF+(32,16640)/MRLS(32,16640).  Scenarios are pure
spec declarations; execution goes through ``repro.api``."""
import sys

sys.path.insert(0, "src")

from repro.api import NetworkSpec
from benchmarks.bench_sim import cli_replicas, run_scenario


def _dfp(n_groups, lpg, spg, p, gps):
    return NetworkSpec("dragonfly_plus", {
        "n_groups": n_groups, "leaves_per_group": lpg,
        "spines_per_group": spg, "p": p, "global_per_spine": gps})


def main(full: bool = False, replicas: int = 4):
    print("# fig7: direct-network comparison "
          f"({'FULL paper size' if full else 'scaled family'}, "
          f"replicas={replicas})")
    if full:
        scen = [
            ("fig7.df.ugal",
             NetworkSpec("dragonfly", {"a": 16, "p": 8, "h": 8}), "ugal", 6),
            ("fig7.dfplus.ugal", _dfp(65, 16, 16, 16, 16), "ugal", 6),
            ("fig7.mrls_u19.pol",
             NetworkSpec("mrls", {"n_leaves": 1280, "u": 19, "d": 13,
                                  "seed": 1}), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 300, 300, 16, 16384
    else:
        scen = [
            ("fig7.df.ugal",
             NetworkSpec("dragonfly", {"a": 6, "p": 3, "h": 3}), "ugal", 6),
            ("fig7.dfplus.ugal", _dfp(13, 6, 6, 6, 6), "ugal", 6),
            ("fig7.mrls_u7.pol",
             NetworkSpec("mrls", {"n_leaves": 96, "u": 7, "d": 5,
                                  "seed": 1}), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 256
    for name, net, policy, hops in scen:
        run_scenario(name, net, policy, hops, warm, measure, rounds, ranks,
                     replicas=replicas)


if __name__ == "__main__":
    main("--full" in sys.argv, replicas=cli_replicas(sys.argv))
