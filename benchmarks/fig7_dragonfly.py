"""Figure 7: MRLS vs Dragonfly / Dragonfly+ at 16K endpoints and
Cost_links <= 1.5.  Scaled default: ~400-endpoint family; ``--full``
builds DF(32,16512)/DF+(32,16640)/MRLS(32,16640)."""
import sys

sys.path.insert(0, "src")

from repro.core import mrls, dragonfly, dragonfly_plus
from benchmarks.bench_sim import run_scenario


def main(full: bool = False):
    print("# fig7: direct-network comparison "
          f"({'FULL paper size' if full else 'scaled family'})")
    if full:
        scen = [
            ("fig7.df.ugal", dragonfly(16, 8, 8), "ugal", 6),
            ("fig7.dfplus.ugal", dragonfly_plus(65, 16, 16, 16, 16),
             "ugal", 6),
            ("fig7.mrls_u19.pol", mrls(1280, 19, 13, seed=1), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 300, 300, 16, 16384
    else:
        scen = [
            ("fig7.df.ugal", dragonfly(6, 3, 3), "ugal", 6),
            ("fig7.dfplus.ugal", dragonfly_plus(13, 6, 6, 6, 6), "ugal", 6),
            ("fig7.mrls_u7.pol", mrls(96, 7, 5, seed=1), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 256
    for name, topo, policy, hops in scen:
        run_scenario(name, topo, policy, hops, warm, measure, rounds, ranks)


if __name__ == "__main__":
    main("--full" in sys.argv)
