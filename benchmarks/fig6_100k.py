"""Figure 6: 100K-endpoint scale — depopulated 4-level FT vs MRLS at
f = 1 / 2 / 3.  Scaled default: radix 12 (1296 endpoints, same ratios);
``--full`` builds the exact 104976-endpoint networks (CPU-hours)."""
import sys

sys.path.insert(0, "src")

from repro.core import mrls, fat_tree
from benchmarks.bench_sim import run_scenario


def main(full: bool = False):
    print("# fig6: 100K-endpoint-scale "
          f"({'FULL paper size' if full else 'scaled radix-12 family'})")
    if full:
        scen = [
            ("fig6.ft50.min", fat_tree(36, 3, a1=18), "minimal_adaptive", 6),
            ("fig6.mrls_f1.pol", mrls(5832, 18, 18, seed=1), "polarized", 8),
            ("fig6.mrls_f2.pol", mrls(8748, 24, 12, seed=1), "polarized", 8),
            ("fig6.mrls_f3.pol", mrls(11664, 27, 9, seed=1), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 300, 300, 16, 65536
    else:
        scen = [
            ("fig6.ft50.min", fat_tree(12, 3, a1=6), "minimal_adaptive", 6),
            ("fig6.mrls_f1.pol", mrls(216, 6, 6, seed=1), "polarized", 8),
            ("fig6.mrls_f2.pol", mrls(324, 8, 4, seed=1), "polarized", 8),
            ("fig6.mrls_f3.pol", mrls(432, 9, 3, seed=1), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 1024
    for name, topo, policy, hops in scen:
        run_scenario(name, topo, policy, hops, warm, measure, rounds, ranks)


if __name__ == "__main__":
    main("--full" in sys.argv)
