"""Figure 6: 100K-endpoint scale — depopulated 4-level FT vs MRLS at
f = 1 / 2 / 3.  Scaled default: radix 12 (1296 endpoints, same ratios);
``--full`` builds the exact 104976-endpoint networks (CPU-hours).
Scenarios are pure spec declarations; execution goes through
``repro.api``."""
import sys

sys.path.insert(0, "src")

from repro.api import NetworkSpec
from benchmarks.bench_sim import cli_replicas, run_scenario


def _mrls(n_leaves, u, d):
    return NetworkSpec("mrls", {"n_leaves": n_leaves, "u": u, "d": d,
                                "seed": 1})


def main(full: bool = False, replicas: int = 4):
    print("# fig6: 100K-endpoint-scale "
          f"({'FULL paper size' if full else 'scaled radix-12 family'}, "
          f"replicas={replicas})")
    if full:
        scen = [
            ("fig6.ft50.min",
             NetworkSpec("fat_tree", {"radix": 36, "h": 3, "a1": 18}),
             "minimal_adaptive", 6),
            ("fig6.mrls_f1.pol", _mrls(5832, 18, 18), "polarized", 8),
            ("fig6.mrls_f2.pol", _mrls(8748, 24, 12), "polarized", 8),
            ("fig6.mrls_f3.pol", _mrls(11664, 27, 9), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 300, 300, 16, 65536
    else:
        scen = [
            ("fig6.ft50.min",
             NetworkSpec("fat_tree", {"radix": 12, "h": 3, "a1": 6}),
             "minimal_adaptive", 6),
            ("fig6.mrls_f1.pol", _mrls(216, 6, 6), "polarized", 8),
            ("fig6.mrls_f2.pol", _mrls(324, 8, 4), "polarized", 8),
            ("fig6.mrls_f3.pol", _mrls(432, 9, 3), "polarized", 8),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 1024
    for name, net, policy, hops in scen:
        run_scenario(name, net, policy, hops, warm, measure, rounds, ranks,
                     replicas=replicas)


if __name__ == "__main__":
    main("--full" in sys.argv, replicas=cli_replicas(sys.argv))
