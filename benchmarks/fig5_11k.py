"""Figure 5: indirect networks at the 11K-endpoint scale.

OFT vs cost-matched MRLS (Polarized AND KSP) vs FT vs cost-1.4/2.0 MRLS.
Scaled default: radix 12, ~400 endpoints, same cost ratios.  ``--full``
builds the paper's exact 11K networks.
"""
import sys

sys.path.insert(0, "src")

from repro.core import mrls, oft, fat_tree
from benchmarks.bench_sim import run_scenario


def main(full: bool = False):
    print("# fig5: 11K-endpoint-scale indirect networks "
          f"({'FULL paper size' if full else 'scaled radix-12 family'})")
    if full:
        scen = [
            ("fig5.oft_q17.pol", oft(17), "polarized", 6),
            ("fig5.mrls_u18.pol", mrls(614, 18, 18, seed=1), "polarized", 6),
            ("fig5.mrls_u18.ksp", mrls(614, 18, 18, seed=1), "ksp", 4),
            ("fig5.mrls_u21.pol", mrls(744, 21, 15, seed=1), "polarized", 6),
            ("fig5.mrls_u24.pol", mrls(972, 24, 12, seed=1), "polarized", 6),
            ("fig5.ft_h2.min", fat_tree(36, 2), "minimal_adaptive", 4),
        ]
        warm, measure, rounds, ranks = 300, 300, 24, 8192
    else:
        scen = [
            ("fig5.oft_q5.pol", oft(5), "polarized", 6),
            ("fig5.mrls_u6.pol", mrls(62, 6, 6, seed=1), "polarized", 8),
            ("fig5.mrls_u6.ksp", mrls(62, 6, 6, seed=1), "ksp", 6),
            ("fig5.mrls_u7.pol", mrls(84, 7, 5, seed=1), "polarized", 8),
            ("fig5.mrls_u8.pol", mrls(108, 8, 4, seed=1), "polarized", 8),
            ("fig5.ft_h2.min", fat_tree(12, 2), "minimal_adaptive", 4),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 256
    for name, topo, policy, hops in scen:
        run_scenario(name, topo, policy, hops, warm, measure, rounds, ranks)


if __name__ == "__main__":
    main("--full" in sys.argv)
