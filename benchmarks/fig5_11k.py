"""Figure 5: indirect networks at the 11K-endpoint scale.

OFT vs cost-matched MRLS (Polarized AND KSP) vs FT vs cost-1.4/2.0 MRLS.
Scaled default: radix 12, ~400 endpoints, same cost ratios.  ``--full``
builds the paper's exact 11K networks.  Scenarios are pure spec
declarations; execution goes through ``repro.api``.
"""
import sys

sys.path.insert(0, "src")

from repro.api import NetworkSpec
from benchmarks.bench_sim import cli_replicas, run_scenario


def _mrls(n_leaves, u, d):
    return NetworkSpec("mrls", {"n_leaves": n_leaves, "u": u, "d": d,
                                "seed": 1})


def main(full: bool = False, replicas: int = 4):
    print("# fig5: 11K-endpoint-scale indirect networks "
          f"({'FULL paper size' if full else 'scaled radix-12 family'}, "
          f"replicas={replicas})")
    if full:
        scen = [
            ("fig5.oft_q17.pol", NetworkSpec("oft", {"q": 17}), "polarized", 6),
            ("fig5.mrls_u18.pol", _mrls(614, 18, 18), "polarized", 6),
            ("fig5.mrls_u18.ksp", _mrls(614, 18, 18), "ksp", 4),
            ("fig5.mrls_u21.pol", _mrls(744, 21, 15), "polarized", 6),
            ("fig5.mrls_u24.pol", _mrls(972, 24, 12), "polarized", 6),
            ("fig5.ft_h2.min", NetworkSpec("fat_tree", {"radix": 36, "h": 2}),
             "minimal_adaptive", 4),
        ]
        warm, measure, rounds, ranks = 300, 300, 24, 8192
    else:
        scen = [
            ("fig5.oft_q5.pol", NetworkSpec("oft", {"q": 5}), "polarized", 6),
            ("fig5.mrls_u6.pol", _mrls(62, 6, 6), "polarized", 8),
            ("fig5.mrls_u6.ksp", _mrls(62, 6, 6), "ksp", 6),
            ("fig5.mrls_u7.pol", _mrls(84, 7, 5), "polarized", 8),
            ("fig5.mrls_u8.pol", _mrls(108, 8, 4), "polarized", 8),
            ("fig5.ft_h2.min", NetworkSpec("fat_tree", {"radix": 12, "h": 2}),
             "minimal_adaptive", 4),
        ]
        warm, measure, rounds, ranks = 250, 250, 12, 256
    for name, net, policy, hops in scen:
        run_scenario(name, net, policy, hops, warm, measure, rounds, ranks,
                     replicas=replicas)


if __name__ == "__main__":
    main("--full" in sys.argv, replicas=cli_replicas(sys.argv))
