"""Benchmark harness entry point — one section per paper artifact.

Each row: ``name,us_per_call,derived``.  Default runs the scaled simulator
families (CPU-tractable); ``--full`` uses exact paper sizes for the
simulator figures (hours — used once for EXPERIMENTS.md §Repro).
"""
import sys

sys.path.insert(0, "src")


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import (table2, fig3_scalability, fig4_radix, fig5_11k,
                            fig6_100k, fig7_dragonfly, roofline)
    table2.main(full)
    fig3_scalability.main(full)
    fig4_radix.main(full)
    fig5_11k.main(full)
    fig6_100k.main(full)
    fig7_dragonfly.main(full)
    roofline.main(full)


if __name__ == '__main__':
    main()
