"""Roofline aggregation: read results/dryrun/*.json into the §Roofline
table (per arch x shape x mesh: the three terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio, roofline fraction)."""
import json
import os
import sys

sys.path.insert(0, "src")

from benchmarks.common import emit

DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh=None, fused=False):
    recs = []
    if not os.path.isdir(DIR):
        return recs
    for name in sorted(os.listdir(DIR)):
        if not name.endswith(".json") or "=" in name:
            continue            # skip override variants
        if ("_fused" in name) != fused:
            continue
        rec = json.load(open(os.path.join(DIR, name)))
        if mesh and rec.get("mesh") != mesh:
            continue
        recs.append(rec)
    return recs


def table(mesh="16x16", fused=False) -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | useful | roofline_frac |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh, fused=fused):
        if rec.get("status") == "skip":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skip: {rec['reason'][:40]} | — | — |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"ERROR | — | — |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{rec['useful_flops_ratio']:.3f} | "
            f"{rec['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def main(full: bool = True):
    print("# roofline: per (arch, shape) on the single-pod mesh")
    for rec in load_records("16x16"):
        if rec.get("status") == "ok":
            r = rec["roofline"]
            emit(f"roofline.{rec['arch']}.{rec['shape']}",
                 rec.get("compile_s", 0) * 1e6,
                 f"dom={r['dominant']}|bound={r['bound_s']:.4f}s|"
                 f"frac={rec['roofline_fraction']:.4f}")
        elif rec.get("status") == "skip":
            emit(f"roofline.{rec['arch']}.{rec['shape']}", 0, "skip")
        else:
            emit(f"roofline.{rec['arch']}.{rec['shape']}", 0, "ERROR")


if __name__ == "__main__":
    main()
