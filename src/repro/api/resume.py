"""Experiment-level resumable runs: ``run_resumable`` / ``resume``.

The glue between the declarative layer and
:mod:`repro.runtime.resilient`: ``run_resumable(experiment, ckpt_dir)``
executes an experiment through the checkpointed segment drivers, writing

* ``<ckpt_dir>/experiment.json`` — the spec, once, at the start (so a
  bare directory is resumable with no other context);
* ``<ckpt_dir>/step_*/`` — the engine-state snapshots (atomic,
  bounded retention, via :class:`repro.checkpointing.Checkpointer`);
* ``<ckpt_dir>/result.json`` — the final :class:`Result`, at completion.

Calling it again on the same directory — after a crash, a SIGKILL, or an
OOM kill — picks up the latest intact snapshot and produces a Result
**bitwise identical** to an uninterrupted run.  ``resume(ckpt_dir)``
is the argument-free variant driven purely by the stored spec (the CLI
``resume`` subcommand).

Supported metrics: ``completion`` (collective programs and legacy
all2all), ``throughput``, ``latency``, ``serving`` — scalar and
replicated.  ``resilience`` runs re-apply host-side failure transitions
at exact slots mid-run; checkpointing those is future work and is
refused with an explanation rather than resumed approximately.
"""
from __future__ import annotations

import os
from typing import Optional

from ..runtime.resilient import (ResilientConfig, run_completion_resumable,
                                 run_program_resumable, run_window_resumable)
from .runner import (Result, SimulatorCache, _admitted_masks, _batched_result,
                     _collective_program, _is_program, _LATENCY_KEYS,
                     _make_simulator, _nan_none, _to_traffic)
from .specs import Experiment

__all__ = ["run_resumable", "resume"]


def _write_spec(ckpt_dir: str, experiment: Experiment) -> None:
    path = os.path.join(ckpt_dir, "experiment.json")
    if os.path.exists(path):
        with open(path) as f:
            stored = Experiment.from_json(f.read())
        if stored != experiment:
            raise ValueError(
                f"{path} holds a different experiment "
                f"({stored.label()!r} != {experiment.label()!r}); refusing "
                "to mix checkpoints.  Use a fresh --ckpt-dir.")
        return
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(experiment.to_json(indent=1))
    os.replace(tmp, path)


def _scalar_window_result(exp: Experiment, metric: str, r: dict) -> Result:
    if metric == "throughput":
        return Result(experiment=exp, metric=metric,
                      throughput=float(r["throughput"]),
                      avg_hops=float(r["avg_hops"]),
                      ejected=int(r["ejected"]),
                      pool_stall=int(r["pool_stall"]))
    if metric == "latency":
        lat = {lbl: _nan_none(r[k]) for lbl, k in _LATENCY_KEYS}
        return Result(experiment=exp, metric=metric, latency=lat)
    lat = {lbl: _nan_none(r[k]) for lbl, k in _LATENCY_KEYS}
    return Result(experiment=exp, metric=metric,
                  throughput=float(r["delivered"]),
                  offered=float(r["offered"]),
                  dropped=int(r["dropped"]),
                  pool_stall=int(r["pool_stall"]), latency=lat)


def _batched_window_per(metric: str, r: dict) -> dict:
    if metric == "throughput":
        return {"throughput": tuple(float(x) for x in r["throughput"]),
                "avg_hops": tuple(float(x) for x in r["avg_hops"]),
                "ejected": tuple(int(x) for x in r["ejected"]),
                "pool_stall": tuple(int(x) for x in r["pool_stall"])}
    if metric == "latency":
        return {lbl: tuple(_nan_none(v) for v in r[k])
                for lbl, k in _LATENCY_KEYS}
    per = {"throughput": tuple(float(x) for x in r["delivered"]),
           "offered": tuple(float(x) for x in r["offered"]),
           "dropped": tuple(int(x) for x in r["dropped"]),
           "pool_stall": tuple(int(x) for x in r["pool_stall"])}
    per.update({lbl: tuple(_nan_none(v) for v in r[k])
                for lbl, k in _LATENCY_KEYS})
    return per


def run_resumable(experiment: Experiment, ckpt_dir: str, *,
                  every: int = 64, keep: int = 3,
                  cache: Optional[SimulatorCache] = None) -> Result:
    """Run ``experiment`` with checkpointed, resumable execution.

    Functionally :func:`repro.api.run` — same admission gate, same Result,
    bitwise — but killable at any point and resumable by re-invoking with
    the same ``ckpt_dir``.  ``every`` is the checkpoint cadence in engine
    chunks (completion metrics) or slots (windowed metrics).
    """
    metric = experiment.resolved_metric()
    if metric == "resilience":
        raise ValueError(
            "resilience runs apply failure transitions from the host at "
            "exact mid-run slots and are not resumable yet; run them "
            "through repro.api.run (their measurement windows are short) "
            "or wrap the whole run under the supervisor instead.")
    _write_spec(ckpt_dir, experiment)
    cfg = ResilientConfig(every=every, keep=keep)
    masks = _admitted_masks(experiment)
    owns = cache is None
    sim = (_make_simulator(experiment.network, experiment.route, masks)
           if owns
           else cache.get(experiment.network, experiment.route, masks))
    try:
        result = _run_resumable_on(sim, experiment, metric, ckpt_dir, cfg)
    finally:
        if owns:
            sim.close()
    path = os.path.join(ckpt_dir, "result.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(result.to_json(indent=1))
    os.replace(tmp, path)
    return result


def _run_resumable_on(sim, exp: Experiment, metric: str, ckpt_dir: str,
                      cfg: ResilientConfig) -> Result:
    batched = exp.replicas > 1
    seeds = exp.replica_seeds() if batched else None

    if _is_program(exp):
        if metric != "completion":
            raise ValueError(f"{exp.workload.pattern} only supports the "
                             "completion metric")
        cp = _collective_program(sim, exp)
        r = run_program_resumable(sim, cp, ckpt=ckpt_dir, chunk=exp.chunk,
                                  max_slots=exp.max_slots, seed=exp.seed,
                                  seeds=seeds, config=cfg)
        if batched:
            per = {"slots": tuple(int(x) for x in r["slots"]),
                   "completed": tuple(bool(x) for x in r["completed"]),
                   "pool_stall": tuple(int(x) for x in r["pool_stall"]),
                   "phase_slots": tuple(tuple(int(v) for v in row)
                                        for row in r["phase_slots"])}
            return _batched_result(exp, seeds, metric, per)
        return Result(experiment=exp, metric=metric, slots=int(r["slots"]),
                      completed=bool(r["completed"]),
                      pool_stall=int(r["pool_stall"]),
                      phase_slots=tuple(int(s) for s in r["phase_slots"]))

    traffic = _to_traffic(exp)
    if metric == "completion":
        if exp.workload.pattern != "all2all":
            raise ValueError(
                f"completion metric needs a collective workload, got "
                f"{exp.workload.pattern!r}")
        expected = sim.S * exp.workload.rounds
        r = run_completion_resumable(sim, traffic, expected, ckpt=ckpt_dir,
                                     chunk=exp.chunk,
                                     max_slots=exp.max_slots,
                                     seed=exp.seed, seeds=seeds, config=cfg)
        if batched:
            per = {"slots": tuple(int(x) for x in r["slots"]),
                   "completed": tuple(bool(x) for x in r["completed"]),
                   "pool_stall": tuple(int(x) for x in r["pool_stall"])}
            return _batched_result(exp, seeds, metric, per)
        return Result(experiment=exp, metric=metric, slots=int(r["slots"]),
                      completed=bool(r["completed"]),
                      pool_stall=int(r["pool_stall"]))

    r = run_window_resumable(sim, traffic, metric=metric, ckpt=ckpt_dir,
                             warm=exp.warm, measure=exp.measure,
                             seed=exp.seed, seeds=seeds, config=cfg)
    if batched:
        per = _batched_window_per(metric, r)
        return _batched_result(exp, seeds, metric, per)
    return _scalar_window_result(exp, metric, r)


def resume(ckpt_dir: str, *, every: int = 64, keep: int = 3,
           cache: Optional[SimulatorCache] = None) -> Result:
    """Resume (or verify) the run stored in ``ckpt_dir`` from its spec
    and latest intact snapshot.  Completed runs return the stored Result
    without recomputation."""
    spec = os.path.join(ckpt_dir, "experiment.json")
    if not os.path.exists(spec):
        raise FileNotFoundError(
            f"{spec} not found — not a resumable checkpoint directory "
            "(run_resumable writes it on first start)")
    done = os.path.join(ckpt_dir, "result.json")
    if os.path.exists(done):
        with open(done) as f:
            return Result.from_json(f.read())
    with open(spec) as f:
        experiment = Experiment.from_json(f.read())
    return run_resumable(experiment, ckpt_dir, every=every, keep=keep,
                         cache=cache)
