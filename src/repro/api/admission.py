"""Admission control: refuse (or downgrade) before compiling, not OOM after.

:func:`repro.api.estimate_memory` prices the *resident* simulation data
exactly, but XLA's compile-time memory dominates at scale — the 50k-MRLS
benchmark point measured ~15x the resident estimate at peak
(``benchmarks/BENCH_scale.json``).  This module closes that gap with an
**empirical compile-RAM multiplier**: recorded per (family, scale) by
``bench_scale.py`` next to each measured ``peak_rss_bytes``, and read
back here to predict a run's true peak::

    predicted = BASELINE_RSS_BYTES + multiplier * est["total_bytes"]

``check_admission(experiment)`` runs inside :func:`repro.api.run` /
``sweep`` (mode from ``REPRO_ADMISSION``: ``auto`` | ``warn`` | ``off``)
before any simulator is built:

* fits the budget — admit unchanged;
* over budget but the dense mask layout is the marginal cost — admit
  **downgraded** to ``masks="blocked"`` (identical results word for
  word; the layout only trades residency for bandwidth);
* still over — raise :class:`AdmissionError` with the actionable
  alternatives (smaller ``chunk``, switch-axis sharding, fewer replicas,
  a bigger host) instead of letting the kernel OOM-kill the host.

Decisions are memoized per (network, route, replicas): a sweep over
loads/seeds on one fabric prices admission once.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Optional, Tuple, Union

from .memory import estimate_memory, format_bytes
from .specs import Experiment

__all__ = ["AdmissionError", "AdmissionDecision", "BASELINE_RSS_BYTES",
           "DEFAULT_COMPILE_MULT", "host_ram_bytes",
           "compile_ram_multiplier", "predict_peak_rss", "check_admission"]

# process baseline (python + jax + XLA runtime) measured on the benchmark
# host: tiny fabrics with ~350 KB of simulation data sit at ~540 MB RSS
# (BENCH_scale.json "tiny"), so the baseline — not the fabric — is the
# floor every prediction starts from
BASELINE_RSS_BYTES = 512 << 20

# fallback compile-RAM multiplier when no at-scale record matches: the
# 50k-MRLS point measured (6.37 GiB - baseline) / 432 MiB ~ 13.9; rounded
# up for safety margin
DEFAULT_COMPILE_MULT = 15.0

# records below this endpoint count are baseline-dominated (the measured
# RSS is mostly the python/jax runtime, not the fabric) and would produce
# garbage multipliers
_MIN_RECORD_ENDPOINTS = 1000

_BENCH_SCALE = Path(__file__).resolve().parents[3] / "benchmarks" \
    / "BENCH_scale.json"


class AdmissionError(RuntimeError):
    """Predicted peak memory exceeds the budget and no safe downgrade
    closes the gap; the experiment was refused before compilation."""


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    action: str                  # "admit" | "downgrade" | "refuse" | "off"
    predicted_bytes: int         # resident estimate + predicted compile RAM
    resident_bytes: int          # estimate_memory total (after downgrade)
    budget_bytes: Optional[int]
    compile_mult: float
    masks: str = "auto"          # mask layout to build tables with
    reason: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def host_ram_bytes() -> Optional[int]:
    """MemTotal from ``/proc/meminfo`` (None on non-Linux hosts)."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return None


def _load_records(records: Union[None, str, Path, dict]) -> dict:
    if isinstance(records, dict):
        return records
    path = Path(records) if records is not None else _BENCH_SCALE
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def _iter_points(records: dict):
    for size, families in records.items():
        if not isinstance(families, dict):
            continue
        for family, rec in families.items():
            if isinstance(rec, dict):
                yield family, rec


def compile_ram_multiplier(family: Optional[str] = None,
                           records: Union[None, str, Path, dict] = None
                           ) -> float:
    """Empirical peak-RSS / resident-estimate multiplier.

    Scans ``BENCH_scale.json`` records with ``peak_rss_bytes`` +
    ``est_total_bytes`` and at least 1000 endpoints (smaller points are
    baseline-dominated), preferring the largest same-``family`` point,
    then the largest point overall; falls back to
    :data:`DEFAULT_COMPILE_MULT`.  Records that carry an explicit
    ``compile_ram_multiplier`` field use it directly.
    """
    best: Tuple[int, float, bool] = (0, DEFAULT_COMPILE_MULT, False)
    for fam, rec in _iter_points(_load_records(records)):
        n = rec.get("n_endpoints", 0)
        if n < _MIN_RECORD_ENDPOINTS:
            continue
        mult = rec.get("compile_ram_multiplier")
        if mult is None:
            peak, est = rec.get("peak_rss_bytes"), rec.get("est_total_bytes")
            if not peak or not est:
                continue
            mult = max(peak - BASELINE_RSS_BYTES, 0) / est
        same = family is not None and fam == family
        # same-family records always beat cross-family ones; within a
        # bucket the largest scale wins (closest to the compile regime)
        if (same, n) > (best[2], best[0]):
            best = (n, float(mult), same)
    return best[1]


def predict_peak_rss(resident_bytes: int, mult: float) -> int:
    """Predicted process peak RSS for a run whose resident simulation
    data totals ``resident_bytes``."""
    return int(BASELINE_RSS_BYTES + mult * resident_bytes)


def _mode() -> str:
    mode = os.environ.get("REPRO_ADMISSION", "auto").lower()
    if mode not in ("auto", "warn", "off"):
        raise ValueError(f"REPRO_ADMISSION={mode!r} (expected auto|warn|off)")
    return mode


_memo: dict = {}


def check_admission(experiment: Experiment, *,
                    budget_bytes: Optional[int] = None,
                    mode: Optional[str] = None,
                    records: Union[None, str, Path, dict] = None
                    ) -> AdmissionDecision:
    """Price ``experiment`` against the host budget before compiling.

    ``budget_bytes`` defaults to host RAM; ``mode`` defaults to the
    ``REPRO_ADMISSION`` env var (``auto``).  Returns the decision (whose
    ``masks`` field feeds the table build); raises
    :class:`AdmissionError` in ``auto`` mode when even the blocked-mask
    downgrade cannot fit.
    """
    mode = mode if mode is not None else _mode()
    if mode == "off":
        return AdmissionDecision(True, "off", 0, 0, None, 0.0)
    if budget_bytes is None:
        budget_bytes = host_ram_bytes()
    key = (experiment.network, experiment.route, experiment.replicas,
           budget_bytes, mode, id(records) if isinstance(records, dict)
           else records)
    hit = _memo.get(key)
    if hit is not None:
        if isinstance(hit, AdmissionError):
            raise hit
        return hit
    decision = _decide(experiment, budget_bytes, mode, records)
    if isinstance(decision, AdmissionError):
        _memo[key] = decision
        raise decision
    _memo[key] = decision
    return decision


def _decide(experiment: Experiment, budget_bytes: Optional[int], mode: str,
            records) -> Union[AdmissionDecision, AdmissionError]:
    est = estimate_memory(experiment)
    mult = compile_ram_multiplier(experiment.network.family, records)
    resident = est["total_bytes"]
    predicted = predict_peak_rss(resident, mult)
    if budget_bytes is None or predicted <= budget_bytes:
        return AdmissionDecision(True, "admit", predicted, resident,
                                 budget_bytes, mult)

    # blocked masks drop the host dense twins AND (for single-mask
    # policies) keep only the streamed device copy resident per block;
    # results are identical word for word, so this downgrade is safe
    host_masks = est["tables"]["host_mask_bytes"]
    down_resident = resident - host_masks
    down_predicted = predict_peak_rss(down_resident, mult)
    layout = est["tables"]["mask_layout"]
    if layout == "dense" and down_predicted <= budget_bytes:
        reason = (f"predicted peak {format_bytes(predicted)} over budget "
                  f"{format_bytes(budget_bytes)}; downgraded to "
                  f"masks='blocked' (drops {format_bytes(host_masks)} of "
                  f"host dense masks, predicted "
                  f"{format_bytes(down_predicted)})")
        if mode == "warn":
            print(f"[admission] WARNING: {reason}")
            return AdmissionDecision(True, "admit", predicted, resident,
                                     budget_bytes, mult, reason=reason)
        return AdmissionDecision(True, "downgrade", down_predicted,
                                 down_resident, budget_bytes, mult,
                                 masks="blocked", reason=reason)

    reason = (
        f"experiment {experiment.label()!r} predicts peak RSS "
        f"{format_bytes(predicted)} (resident {format_bytes(resident)} x "
        f"compile multiplier {mult:.1f} + {format_bytes(BASELINE_RSS_BYTES)}"
        f" baseline) but the budget is {format_bytes(budget_bytes)}. "
        "Options: fewer replicas (state is priced per replica), a smaller "
        "`chunk` (shorter scanned step program for XLA to optimize), "
        "switch-axis sharding across hosts (`repro.parallel.sharding`), "
        "masks='blocked' at build time, or a larger-memory host. "
        "Set REPRO_ADMISSION=warn to proceed anyway at your own risk.")
    if mode == "warn":
        print(f"[admission] WARNING: {reason}")
        return AdmissionDecision(True, "admit", predicted, resident,
                                 budget_bytes, mult, reason=reason)
    return AdmissionError(reason)
