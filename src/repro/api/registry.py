"""String-keyed topology-builder registry.

Seeds from :data:`repro.core.TOPOLOGY_BUILDERS` (the six paper families)
and accepts user registrations, so downstream code can declare fabrics by
name in JSON without importing builder functions.
"""
from __future__ import annotations

from typing import Callable, Optional

from ..core import TOPOLOGY_BUILDERS
from ..core.topology import Topology
from .specs import NetworkSpec

__all__ = ["register_topology", "topology_families", "build_network"]

_REGISTRY: dict = dict(TOPOLOGY_BUILDERS)


def register_topology(name: str, builder: Callable[..., Topology],
                      *, overwrite: bool = False) -> None:
    """Register ``builder`` under ``name`` for NetworkSpec resolution."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"topology family {name!r} already registered")
    _REGISTRY[name] = builder


def topology_families() -> tuple:
    return tuple(sorted(_REGISTRY))


def build_network(spec: NetworkSpec) -> Topology:
    """Resolve ``spec.family`` and build the topology from ``spec.params``."""
    try:
        builder = _REGISTRY[spec.family]
    except KeyError:
        raise KeyError(
            f"unknown topology family {spec.family!r}; known: "
            f"{topology_families()}") from None
    return builder(**spec.param_dict())
