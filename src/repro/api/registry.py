"""String-keyed registries behind the declarative layer.

* Topology builders: seeds from :data:`repro.core.TOPOLOGY_BUILDERS` (the
  six paper families) and accepts user registrations, so downstream code
  can declare fabrics by name in JSON without importing builder functions.
* Workload patterns: re-exported views of the shared pattern registry
  (:mod:`repro.workloads.patterns`) that ``WorkloadSpec`` and the engine
  both validate against, plus the collective -> program builder table
  (:data:`repro.workloads.programs.PROGRAM_BUILDERS`).
"""
from __future__ import annotations

from typing import Callable, Optional

from ..core import TOPOLOGY_BUILDERS
from ..core.topology import Topology
from ..workloads.patterns import pattern_kinds
from ..workloads.programs import PROGRAM_BUILDERS
from .specs import NetworkSpec

__all__ = ["register_topology", "topology_families", "build_network",
           "workload_patterns"]


def workload_patterns() -> tuple:
    """``(name, kind)`` pairs for every spec-level workload pattern, sorted
    by name.  Collectives marked ``collective*`` compile to device-resident
    workload programs."""
    out = []
    for name, kind in sorted(pattern_kinds().items()):
        if kind == "engine":
            continue                       # not reachable from WorkloadSpec
        if kind == "collective" and name in PROGRAM_BUILDERS:
            kind = "collective*"
        out.append((name, kind))
    return tuple(out)

_REGISTRY: dict = dict(TOPOLOGY_BUILDERS)


def register_topology(name: str, builder: Callable[..., Topology],
                      *, overwrite: bool = False) -> None:
    """Register ``builder`` under ``name`` for NetworkSpec resolution.

    Re-registering the *same* builder object under its existing name is a
    no-op (module reloads and interactive sessions hit this path);
    registering a *different* builder under a taken name still raises
    unless ``overwrite=True``.
    """
    if name in _REGISTRY and not overwrite:
        if _REGISTRY[name] is builder:
            return
        raise ValueError(f"topology family {name!r} already registered "
                         "with a different builder (pass overwrite=True "
                         "to replace it)")
    _REGISTRY[name] = builder


def topology_families() -> tuple:
    return tuple(sorted(_REGISTRY))


def build_network(spec: NetworkSpec) -> Topology:
    """Resolve ``spec.family`` and build the topology from ``spec.params``."""
    try:
        builder = _REGISTRY[spec.family]
    except KeyError:
        raise KeyError(
            f"unknown topology family {spec.family!r}; known: "
            f"{topology_families()}") from None
    return builder(**spec.param_dict())
