"""Declarative experiment layer over the simulator stack.

One import gives the whole pipeline::

    from repro.api import Experiment, NetworkSpec, RouteSpec, WorkloadSpec, run

    result = run(Experiment(
        network=NetworkSpec("mrls", {"n_leaves": 62, "u": 6, "d": 6, "seed": 1}),
        route=RouteSpec(policy="polarized", max_hops=8),
        workload=WorkloadSpec("uniform", load=1.0),
    ))
    print(result.throughput)

Specs are frozen + JSON round-trippable (``python -m repro.api run
spec.json`` executes them from files), :func:`run` owns simulator
lifetime, and :func:`sweep` expands cartesian axes while reusing
compiled simulators across grid points that share a fabric.  The
imperative layer (``repro.core``, ``repro.simulator``) stays importable
underneath for custom drivers.
"""
from .specs import (
    NetworkSpec, RouteSpec, WorkloadSpec, Experiment,
    BERNOULLI_PATTERNS, COLLECTIVE_PATTERNS,
)
from .registry import (register_topology, topology_families, build_network,
                       workload_patterns)
from .runner import (Result, SimulatorCache, open_simulator, routing_tables,
                     run, run_all)
from .memory import estimate_memory, format_bytes
from .admission import (AdmissionDecision, AdmissionError, check_admission,
                        compile_ram_multiplier, host_ram_bytes,
                        predict_peak_rss)
from .resume import resume, run_resumable
from .sweep import expand_axes, sweep
from .degrade import (DegradeSpec, degrade_sweep, degrade_sweep_many,
                      degrade_sweep_from_dict)
from ..core.failures import FailureEvent, FailureSchedule

__all__ = [
    "NetworkSpec", "RouteSpec", "WorkloadSpec", "Experiment",
    "BERNOULLI_PATTERNS", "COLLECTIVE_PATTERNS",
    "register_topology", "topology_families", "build_network",
    "workload_patterns",
    "Result", "SimulatorCache", "open_simulator", "routing_tables", "run",
    "run_all",
    "estimate_memory", "format_bytes",
    "AdmissionDecision", "AdmissionError", "check_admission",
    "compile_ram_multiplier", "host_ram_bytes", "predict_peak_rss",
    "resume", "run_resumable",
    "expand_axes", "sweep",
    "DegradeSpec", "degrade_sweep", "degrade_sweep_many",
    "degrade_sweep_from_dict",
    "FailureEvent", "FailureSchedule",
]
