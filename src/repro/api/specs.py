"""Frozen, hashable, JSON-serializable experiment specs.

The declarative layer of the repo: an :class:`Experiment` composes

* :class:`NetworkSpec`  — *what fabric* (topology family + params),
* :class:`RouteSpec`    — *how packets move* (policy + switch resources),
* :class:`WorkloadSpec` — *what traffic* (pattern / collective + intensity),

plus the measurement protocol (warm-up, measurement window, completion
bounds).  Every spec is a frozen dataclass that round-trips losslessly
through ``to_dict()``/``from_dict()`` and ``to_json()``/``from_json()``,
and is hashable — :func:`repro.api.sweep` keys compiled simulators on
``(network, route)`` so grid points sharing a fabric reuse the jit cache.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Optional, Tuple

# the single pattern-name registry, shared with the engine's ``Traffic``
# (repro.workloads.patterns) — a typo'd pattern raises the same error in
# both layers
from ..core.failures import FailureSchedule
from ..workloads.patterns import (ARRIVAL_PATTERNS, BERNOULLI_PATTERNS,
                                  COLLECTIVE_PATTERNS, check_arrival,
                                  check_pattern, check_schedule)

__all__ = [
    "NetworkSpec",
    "RouteSpec",
    "WorkloadSpec",
    "Experiment",
    "ARRIVAL_PATTERNS",
    "BERNOULLI_PATTERNS",
    "COLLECTIVE_PATTERNS",
]


def _freeze_value(key: str, v):
    """Recursively convert lists to tuples and reject non-JSON leaves."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_value(key, x) for x in v)
    if not isinstance(v, (int, float, str, bool, type(None))):
        raise TypeError(f"NetworkSpec param {key!r} must be a JSON scalar "
                        f"or list thereof, got {type(v).__name__}")
    return v


def _freeze_params(params) -> Tuple[Tuple[str, Any], ...]:
    """Normalize a params mapping to a sorted tuple of pairs (hashable)."""
    if isinstance(params, Mapping):
        items = params.items()
    else:  # already a sequence of pairs (e.g. from an earlier freeze)
        items = [(k, v) for k, v in params]
    return tuple((str(k), _freeze_value(str(k), v)) for k, v in sorted(items))


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """A topology family name plus builder kwargs.

    ``family`` is resolved through :mod:`repro.api.registry`
    (``mrls | fat_tree | oft | dragonfly | dragonfly_plus | rfc`` out of the
    box).  ``params`` are the builder's keyword arguments, stored as a
    sorted tuple of pairs so the spec is hashable and order-insensitive.

    ``failures`` optionally attaches a frozen
    :class:`repro.core.FailureSchedule` — deterministic link/switch
    down/up events the simulator applies mid-run.  It is part of the spec
    (and its hash), so the runner's simulator cache never conflates a
    degraded fabric with its pristine twin; the schedule is validated
    against the built topology at simulator-construction time.
    """

    family: str
    params: Tuple[Tuple[str, Any], ...] = ()
    failures: Optional[FailureSchedule] = None

    def __post_init__(self):
        object.__setattr__(self, "params", _freeze_params(self.params))
        if self.failures is not None and not isinstance(self.failures,
                                                        FailureSchedule):
            object.__setattr__(self, "failures",
                               FailureSchedule.from_dict(self.failures))

    def param_dict(self) -> dict:
        return {k: v for k, v in self.params}

    def to_dict(self) -> dict:
        d = {"family": self.family, "params": self.param_dict()}
        if self.failures is not None:
            d["failures"] = self.failures.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "NetworkSpec":
        failures = d.get("failures")
        if failures is not None and not isinstance(failures,
                                                   FailureSchedule):
            failures = FailureSchedule.from_dict(failures)
        return cls(family=d["family"], params=d.get("params", {}),
                   failures=failures)


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """Routing policy plus the switch resources it runs on.

    Mirrors :class:`repro.simulator.engine.SimConfig` minus the sim-RNG
    seed (which belongs to the :class:`Experiment`).  ``backend`` selects
    the arbitration implementation (``"xla"`` inline jnp — the default —
    or ``"pallas"``, the fused per-switch kernel); both are
    bitwise-identical per replica, so it is a pure performance knob.
    """

    policy: str = "polarized"
    vcs: int = 4
    max_hops: int = 8
    deroute_penalty: float = 8.0
    queue_depth: int = 8
    out_queue: int = 4
    speedup: int = 2
    endpoint_queue: int = 4
    pool: Optional[int] = None
    hist_bins: int = 4096
    backend: str = "xla"

    def to_sim_config(self, seed: int = 0):
        from ..simulator.engine import SimConfig

        return SimConfig(
            policy=self.policy, vcs=self.vcs, queue_depth=self.queue_depth,
            out_queue=self.out_queue, speedup=self.speedup,
            endpoint_queue=self.endpoint_queue, max_hops=self.max_hops,
            deroute_penalty=self.deroute_penalty, pool=self.pool,
            hist_bins=self.hist_bins, seed=seed, backend=self.backend,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "RouteSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Traffic program.

    ``pattern`` is one of the Bernoulli families (``uniform | rep | rsp |
    bu | mice_elephant`` plus the adversarial ``tornado | shift | hotspot |
    bursty``, driven by ``load``) or a collective (``all2all`` with
    ``rounds``; the allreduce family ``allreduce`` = Rabenseifner,
    ``ring_allreduce``, ``rd_allreduce`` = recursive doubling, over
    ``ranks`` ranks of ``vec_packets`` packets) or an open-loop arrival
    process (``poisson | pareto | diurnal``, driven by the offered
    ``load`` with the serving knobs ``pareto_alpha`` / ``pareto_cap`` /
    ``diurnal_amp`` / ``diurnal_period`` / ``arr_depth`` — measured with
    the ``serving`` metric, where delivered throughput may fall below
    offered load).  Pattern names are validated against the shared
    workloads registry (:mod:`repro.workloads.patterns`) — the same
    registry the engine's ``Traffic`` enforces.

    ``schedule`` picks the collective execution mode: ``""`` (default)
    keeps each pattern's native semantics (allreduce family: ``barrier``
    — the parity-locked phase-by-phase execution; ``all2all``:
    free-running rounds); ``"barrier"`` forces global phase barriers;
    ``"window"`` pipelines rounds, letting every endpoint run up to
    ``window`` phases ahead of the globally-completed phase.  Collectives
    with a schedule compile to a device-resident
    :class:`repro.workloads.WorkloadProgram` executed by the engine's
    on-device phase scheduler.
    """

    pattern: str = "uniform"
    load: float = 1.0
    rounds: int = 0              # all2all
    ranks: int = 0               # allreduce family; 0 -> largest pow2 <= S
    vec_packets: int = 16        # allreduce vector size (packets)
    elephant_frac: float = 0.1   # mice_elephant
    elephant_size: int = 16
    schedule: str = ""           # collective mode: "" | barrier | window
    window: int = 1              # lookahead depth for schedule="window"
    shift: int = 1               # shift: dst = (e + shift) mod S
    hot_frac: float = 0.1        # hotspot: fraction of incast messages
    hot_count: int = 1           # hotspot: number of hot endpoints
    burst_len: float = 8.0       # bursty: mean burst duration (slots)
    burst_load: float = 1.0      # bursty: injection probability in-burst
    # open-loop arrival (serving) knobs
    pareto_alpha: float = 1.5    # pareto: bounded-Pareto shape (> 1)
    pareto_cap: int = 64         # pareto: batch-size cap (packets)
    diurnal_amp: float = 0.5     # diurnal: relative amplitude [0, 1]
    diurnal_period: int = 512    # diurnal: modulation period (slots >= 2)
    arr_depth: int = 8           # per-endpoint pending-batch FIFO depth

    def __post_init__(self):
        kind = check_pattern(self.pattern)
        check_schedule(self.schedule, self.window)
        if kind == "arrival":
            check_arrival(self.pattern, self.load,
                          pareto_alpha=self.pareto_alpha,
                          pareto_cap=self.pareto_cap,
                          diurnal_amp=self.diurnal_amp,
                          diurnal_period=self.diurnal_period,
                          arr_depth=self.arr_depth)
        if self.schedule and kind != "collective":
            raise ValueError(
                f"schedule={self.schedule!r} needs a collective pattern, "
                f"got {self.pattern!r} ({kind})")
        if self.pattern == "all2all" and self.rounds <= 0:
            raise ValueError("all2all needs rounds > 0 (0 rounds would "
                             "report instant completion of an empty program)")
        if self.pattern in ("allreduce", "rd_allreduce") and self.ranks:
            if self.ranks < 2 or self.ranks & (self.ranks - 1):
                raise ValueError(
                    f"{self.pattern} ranks must be a power of two >= 2 "
                    f"(recursive halving/doubling), got {self.ranks}")
        if self.pattern == "ring_allreduce" and self.ranks and self.ranks < 2:
            raise ValueError(f"ring_allreduce needs ranks >= 2, got "
                             f"{self.ranks}")
        if self.pattern == "shift" and self.shift == 0:
            raise ValueError("shift pattern needs a non-zero shift offset")
        if self.pattern == "hotspot":
            if not 0.0 < self.hot_frac <= 1.0:
                raise ValueError(f"hot_frac must be in (0, 1], got "
                                 f"{self.hot_frac}")
            if self.hot_count < 1:
                raise ValueError(f"hot_count must be >= 1, got "
                                 f"{self.hot_count}")
        if self.pattern == "bursty":
            if not 0.0 < self.burst_load <= 1.0:
                raise ValueError(f"burst_load must be in (0, 1], got "
                                 f"{self.burst_load}")
            if self.burst_len < 1.0:
                raise ValueError(f"burst_len must be >= 1 slot, got "
                                 f"{self.burst_len}")
            if self.load > self.burst_load:
                raise ValueError(
                    f"bursty load {self.load} exceeds burst_load "
                    f"{self.burst_load}: the long-run offered load can "
                    "never exceed the in-burst intensity")
            duty_max = self.burst_len / (self.burst_len + 1.0)
            if self.load > self.burst_load * duty_max:
                raise ValueError(
                    f"bursty duty cycle {self.load / self.burst_load:.3f} "
                    f"is unreachable: with burst_len {self.burst_len} the "
                    f"ON fraction tops out at {duty_max:.3f}, so the "
                    "long-run offered load would silently undershoot "
                    "`load` — raise burst_len or burst_load")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "WorkloadSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Experiment:
    """One runnable scenario: fabric x routing x workload + measurement.

    ``metric`` is ``auto`` (Bernoulli patterns -> ``throughput``,
    collectives -> ``completion``, arrival processes -> ``serving``),
    ``throughput``, ``latency``, ``completion``, or ``serving`` (offered
    vs delivered rate, source drops, and birth-slot latency percentiles
    for the open-loop arrival patterns).  ``seed`` drives both the
    traffic permutations and the simulator PRNG stream — sweeping it on a
    shared simulator does not recompile.

    ``replicas`` makes replication a compiled axis: R > 1 runs seeds
    ``seed .. seed+R-1`` through one ``jax.vmap``-batched executable (one
    compile, no per-replica host round-trips) and the :class:`Result`
    carries per-replica values plus mean/std/min/max aggregates.
    """

    network: NetworkSpec
    route: RouteSpec = RouteSpec()
    workload: WorkloadSpec = WorkloadSpec()
    name: str = ""
    metric: str = "auto"
    seed: int = 0
    replicas: int = 1
    warm: int = 200
    measure: int = 400
    chunk: int = 16
    max_slots: int = 60_000

    def __post_init__(self):
        if self.metric not in ("auto", "throughput", "latency", "completion",
                               "serving", "resilience"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")

    # ------------------------------------------------------------------ #
    def resolved_metric(self) -> str:
        if self.metric != "auto":
            return self.metric
        # registry kind, not a static tuple: collectives registered after
        # import (register_program_builder) resolve to completion too
        kind = check_pattern(self.workload.pattern)
        if kind == "collective":
            return "completion"
        if kind == "arrival":
            return "serving"
        if self.network.failures is not None and len(self.network.failures):
            return "resilience"
        return "throughput"

    def label(self) -> str:
        return self.name or (f"{self.network.family}"
                             f".{self.route.policy}.{self.workload.pattern}")

    def replica_seeds(self) -> Tuple[int, ...]:
        """The per-replica seeds a batched run uses: ``seed .. seed+R-1``."""
        return tuple(self.seed + i for i in range(self.replicas))

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "network": self.network.to_dict(),
            "route": self.route.to_dict(),
            "workload": self.workload.to_dict(),
            "name": self.name,
            "metric": self.metric,
            "seed": self.seed,
            "replicas": self.replicas,
            "warm": self.warm,
            "measure": self.measure,
            "chunk": self.chunk,
            "max_slots": self.max_slots,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "Experiment":
        d = dict(d)
        return cls(
            network=NetworkSpec.from_dict(d.pop("network")),
            route=RouteSpec.from_dict(d.pop("route", {})),
            workload=WorkloadSpec.from_dict(d.pop("workload", {})),
            **d,
        )

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Experiment":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------------ #
    def override(self, path: str, value) -> "Experiment":
        """Return a copy with the dotted ``path`` replaced by ``value``.

        Paths address the spec tree: ``seed``, ``workload.load``,
        ``route.policy``, ``network.params.u``, ...  This is the primitive
        :func:`repro.api.sweep` expands axes with.
        """
        head, _, rest = path.partition(".")
        if not rest:
            return dataclasses.replace(self, **{head: value})
        sub = getattr(self, head)
        if head == "network":
            field, _, leaf = rest.partition(".")
            if field == "params":
                params = sub.param_dict()
                params[leaf] = value
                new = dataclasses.replace(sub, params=params)
            else:
                new = dataclasses.replace(sub, **{rest: value})
        elif head in ("route", "workload"):
            new = dataclasses.replace(sub, **{rest: value})
        else:
            raise KeyError(f"cannot override {path!r}")
        return dataclasses.replace(self, **{head: new})
