"""Degraded-mode resilience sweeps: throughput retention vs links down.

Spec-first entry point: :func:`degrade_sweep` takes one frozen
:class:`DegradeSpec` — a base :class:`Experiment` plus a ladder of
link-failure *rates* (fraction of the fabric's undirected links) — runs
the resilience metric at each rate, and folds the results into a
degradation record::

    {"name": ..., "base": {...}, "n_links": L, "policy": ...,
     "fail_policy": "requeue" | "drop", "down_slot": ...,
     "points": [{"rate", "n_links_down", "delivered", "avg_hops",
                 "fail_drop", "p50", "p99", "retention"}, ...]}

``retention`` is delivered throughput relative to the sweep's rate-0
point (``None`` when the sweep doesn't include rate 0).  Failed links
are picked by :meth:`FailureSchedule.random_links` from one seed ladder,
so the 1%% set is a subset of the 2%% set and the curve is monotone in
the failed-link population, not resampled noise.

All rates share ONE armed simulator: the engine's failure branch traces
the live-mask path once, and between rates only the *host* schedule and
the device up-mask/table state change (``run_resilience`` restores the
pristine tables after every run), so an N-point sweep costs one compile.

A raw dict (the JSON file format) is accepted at the boundary via
``DegradeSpec.from_dict``; the old ``degrade_sweep(base_experiment,
rates, ...)`` positional signature and ``degrade_sweep_from_dict`` live
on as deprecation shims (see docs/API.md migration notes).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping, Optional, Sequence, Tuple, Union

from ..core.failures import FailureSchedule, canonical_link_ids
from ..core.routing import build_tables
from ..simulator.engine import Simulator
from .registry import build_network
from .runner import _to_traffic
from .specs import Experiment

__all__ = ["DegradeSpec", "degrade_sweep", "degrade_sweep_many",
           "degrade_sweep_from_dict"]

DEFAULT_RATES = (0.0, 0.01, 0.02, 0.05, 0.10)


@dataclasses.dataclass(frozen=True)
class DegradeSpec:
    """One degradation sweep: base experiment x failure-rate ladder.

    ``base`` supplies fabric, route (typically ``policy="degraded"``),
    workload, warm/measure window, and seed; any failure schedule already
    on ``base.network`` is ignored — the sweep owns failure injection.
    ``fail_seed`` seeds the link ladder, ``down_slot`` the failure slot,
    ``fail_policy`` what in-flight packets on a dead port do
    (``requeue`` | ``drop``).
    """

    base: Experiment
    rates: Tuple[float, ...] = DEFAULT_RATES
    down_slot: int = 1
    fail_policy: str = "requeue"
    fail_seed: int = 0

    def __post_init__(self):
        if not isinstance(self.base, Experiment):
            object.__setattr__(self, "base", Experiment.from_dict(self.base))
        rates = tuple(float(r) for r in self.rates)
        if not rates:
            raise ValueError("DegradeSpec needs at least one rate")
        if any(r < 0 or r >= 1 for r in rates):
            raise ValueError(f"rates must lie in [0, 1), got {list(rates)}")
        object.__setattr__(self, "rates", rates)
        if self.fail_policy not in ("requeue", "drop"):
            raise ValueError(f"unknown fail_policy {self.fail_policy!r} "
                             "(expected requeue|drop)")
        if self.down_slot < 0:
            raise ValueError(f"down_slot must be >= 0, got {self.down_slot}")

    def to_dict(self) -> dict:
        return {"base": self.base.to_dict(), "rates": list(self.rates),
                "down_slot": self.down_slot,
                "fail_policy": self.fail_policy,
                "fail_seed": self.fail_seed}

    @classmethod
    def from_dict(cls, d: Mapping) -> "DegradeSpec":
        return cls(base=Experiment.from_dict(d["base"]),
                   rates=tuple(d.get("rates", DEFAULT_RATES)),
                   down_slot=int(d.get("down_slot", 1)),
                   fail_policy=d.get("fail_policy", "requeue"),
                   fail_seed=int(d.get("fail_seed", 0)))


def _schedule(topo, k: int, *, down_slot: int, seed: int,
              fail_policy: str) -> FailureSchedule:
    if k == 0:
        return FailureSchedule(events=(), policy=fail_policy)
    return FailureSchedule.random_links(topo, k, down_slot=down_slot,
                                        seed=seed, policy=fail_policy)


def degrade_sweep(spec: Union[DegradeSpec, Mapping, Experiment],
                  rates: Optional[Sequence[float]] = None, *,
                  down_slot: int = 1, fail_policy: str = "requeue",
                  fail_seed: int = 0) -> dict:
    """Run one degradation sweep and return its record (see module doc).

    ``spec`` is a :class:`DegradeSpec` (or its dict form, converted at
    the boundary).  Passing a bare :class:`Experiment` plus ``rates`` —
    the pre-spec signature — still works but is deprecated.
    """
    if isinstance(spec, Experiment):
        warnings.warn(
            "degrade_sweep(base_experiment, rates, ...) is deprecated; "
            "pass degrade_sweep(DegradeSpec(base=..., rates=..., ...))",
            DeprecationWarning, stacklevel=2)
        spec = DegradeSpec(base=spec, rates=tuple(rates or DEFAULT_RATES),
                           down_slot=down_slot, fail_policy=fail_policy,
                           fail_seed=fail_seed)
    elif not isinstance(spec, DegradeSpec):
        spec = DegradeSpec.from_dict(spec)
    elif rates is not None:
        raise TypeError("rates is part of DegradeSpec; pass it there")

    base = spec.base
    network = dataclasses.replace(base.network, failures=None)
    topo = build_network(network)
    n_links = int(len(canonical_link_ids(topo)))
    ks = [int(round(r * n_links)) for r in spec.rates]

    schedules = [_schedule(topo, k, down_slot=spec.down_slot,
                           seed=spec.fail_seed,
                           fail_policy=spec.fail_policy) for k in ks]

    # arm the simulator with the largest schedule so the failure branch
    # is traced; per-rate we only swap the host-side schedule object
    # (run_resilience restores pristine tables after each run)
    arm = max(schedules, key=len)
    if len(arm) == 0:
        arm = _schedule(topo, 1, down_slot=spec.down_slot,
                        seed=spec.fail_seed, fail_policy=spec.fail_policy)
    tables = build_tables(topo)
    sim = Simulator(tables, base.route.to_sim_config(), failures=arm)
    traffic = _to_traffic(base)

    points = []
    for rate, k, sched in zip(spec.rates, ks, schedules):
        sim.failures = sched.validate(topo)
        r = sim.run_resilience(traffic, warm=base.warm,
                               measure=base.measure, seed=base.seed)
        points.append({
            "rate": rate, "n_links_down": k,
            "delivered": float(r["throughput"]),
            "avg_hops": float(r["avg_hops"]),
            "fail_drop": int(r["fail_drop"]),
            "p50": _none_nan(r["p0.5"]), "p99": _none_nan(r["p0.99"]),
        })

    base_pt = next((p for p in points if p["n_links_down"] == 0), None)
    for p in points:
        p["retention"] = (p["delivered"] / base_pt["delivered"]
                          if base_pt and base_pt["delivered"] else None)

    return {"name": base.label(), "base": base.to_dict(),
            "n_links": n_links, "policy": base.route.policy,
            "fail_policy": spec.fail_policy, "down_slot": spec.down_slot,
            "fail_seed": spec.fail_seed, "points": points}


def degrade_sweep_many(specs: Sequence[Union[DegradeSpec, Mapping]]) -> list:
    """Run several degradation sweeps; returns one record per spec."""
    return [degrade_sweep(s) for s in specs]


def _none_nan(v) -> Optional[float]:
    v = float(v)
    return None if v != v else v


def degrade_sweep_from_dict(spec: dict) -> list:
    """Deprecated CLI bridge — :func:`degrade_sweep` now takes the dict
    directly (``{"sweeps": [...]}`` lists go through
    :func:`degrade_sweep_many`)."""
    warnings.warn(
        "degrade_sweep_from_dict is deprecated; pass the dict to "
        "degrade_sweep (or degrade_sweep_many for {'sweeps': [...]})",
        DeprecationWarning, stacklevel=2)
    specs = spec.get("sweeps", [spec]) if isinstance(spec, dict) else spec
    return degrade_sweep_many(specs)
