"""Degraded-mode resilience sweeps: throughput retention vs links down.

:func:`degrade_sweep` takes one base :class:`Experiment` and a ladder of
link-failure *rates* (fraction of the fabric's undirected links), runs
the resilience metric at each rate, and folds the results into a
degradation record::

    {"name": ..., "base": {...}, "n_links": L, "policy": ...,
     "fail_policy": "requeue" | "drop", "down_slot": ...,
     "points": [{"rate", "n_links_down", "delivered", "avg_hops",
                 "fail_drop", "p50", "p99", "retention"}, ...]}

``retention`` is delivered throughput relative to the sweep's rate-0
point (``None`` when the sweep doesn't include rate 0).  Failed links
are picked by :meth:`FailureSchedule.random_links` from one seed ladder,
so the 1%% set is a subset of the 2%% set and the curve is monotone in
the failed-link population, not resampled noise.

All rates share ONE armed simulator: the engine's failure branch traces
the live-mask path once, and between rates only the *host* schedule and
the device up-mask/table state change (``run_resilience`` restores the
pristine tables after every run), so an N-point sweep costs one compile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..core.failures import FailureSchedule, canonical_link_ids
from ..core.routing import build_tables
from ..simulator.engine import Simulator
from .registry import build_network
from .runner import _to_traffic
from .specs import Experiment

__all__ = ["degrade_sweep", "degrade_sweep_from_dict"]


def _schedule(topo, k: int, *, down_slot: int, seed: int,
              fail_policy: str) -> FailureSchedule:
    if k == 0:
        return FailureSchedule(events=(), policy=fail_policy)
    return FailureSchedule.random_links(topo, k, down_slot=down_slot,
                                        seed=seed, policy=fail_policy)


def degrade_sweep(base: Experiment, rates: Sequence[float], *,
                  down_slot: int = 1, fail_policy: str = "requeue",
                  fail_seed: int = 0) -> dict:
    """Run one degradation sweep and return its record (see module doc).

    ``base`` supplies fabric, route (typically ``policy="degraded"``),
    workload, warm/measure window, and seed; any schedule already on
    ``base.network`` is ignored — the sweep owns failure injection.
    """
    rates = [float(r) for r in rates]
    if not rates:
        raise ValueError("degrade_sweep needs at least one rate")
    if any(r < 0 or r >= 1 for r in rates):
        raise ValueError(f"rates must lie in [0, 1), got {rates}")

    network = dataclasses.replace(base.network, failures=None)
    topo = build_network(network)
    n_links = int(len(canonical_link_ids(topo)))
    ks = [int(round(r * n_links)) for r in rates]

    schedules = [_schedule(topo, k, down_slot=down_slot, seed=fail_seed,
                           fail_policy=fail_policy) for k in ks]

    # arm the simulator with the largest schedule so the failure branch
    # is traced; per-rate we only swap the host-side schedule object
    # (run_resilience restores pristine tables after each run)
    arm = max(schedules, key=len)
    if len(arm) == 0:
        arm = _schedule(topo, 1, down_slot=down_slot, seed=fail_seed,
                        fail_policy=fail_policy)
    tables = build_tables(topo)
    sim = Simulator(tables, base.route.to_sim_config(), failures=arm)
    traffic = _to_traffic(base)

    points = []
    for rate, k, sched in zip(rates, ks, schedules):
        sim.failures = sched.validate(topo)
        r = sim.run_resilience(traffic, warm=base.warm,
                               measure=base.measure, seed=base.seed)
        points.append({
            "rate": rate, "n_links_down": k,
            "delivered": float(r["throughput"]),
            "avg_hops": float(r["avg_hops"]),
            "fail_drop": int(r["fail_drop"]),
            "p50": _none_nan(r["p0.5"]), "p99": _none_nan(r["p0.99"]),
        })

    base_pt = next((p for p in points if p["n_links_down"] == 0), None)
    for p in points:
        p["retention"] = (p["delivered"] / base_pt["delivered"]
                          if base_pt and base_pt["delivered"] else None)

    return {"name": base.label(), "base": base.to_dict(),
            "n_links": n_links, "policy": base.route.policy,
            "fail_policy": fail_policy, "down_slot": down_slot,
            "fail_seed": fail_seed, "points": points}


def _none_nan(v) -> Optional[float]:
    v = float(v)
    return None if v != v else v


def degrade_sweep_from_dict(spec: dict) -> list:
    """CLI bridge: ``{"base": {experiment}, "rates": [...], ...}`` or a
    ``{"sweeps": [...]}`` list of such specs; returns a list of records."""
    specs = spec.get("sweeps", [spec]) if isinstance(spec, dict) else spec
    out = []
    for s in specs:
        base = Experiment.from_dict(s["base"])
        out.append(degrade_sweep(
            base, s.get("rates", (0.0, 0.01, 0.02, 0.05, 0.10)),
            down_slot=int(s.get("down_slot", 1)),
            fail_policy=s.get("fail_policy", "requeue"),
            fail_seed=int(s.get("fail_seed", 0))))
    return out
