"""One-call experiment execution: ``run(experiment) -> Result``.

Owns the four-stage pipeline every driver used to hand-wire —
topology builder -> ``build_tables`` -> ``Simulator(SimConfig)`` ->
``Traffic`` — plus simulator lifetime (context-managed; teardown clears
the jit caches that otherwise accumulate one executable per instance)
and collective orchestration: collectives compile to device-resident
workload programs (:mod:`repro.workloads`) and run as **one** device
computation per experiment — the old per-phase host loop (fresh
``Traffic("phase")`` state + ``run_completion`` per Rabenseifner phase)
is gone, with bitwise-identical ``phase_slots``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Mapping, Optional, Tuple

import jax
import numpy as np

from ..core import build_tables
from ..simulator.engine import Simulator, Traffic
from ..workloads import build_collective_program, compile_program
from .registry import build_network
from .specs import Experiment, NetworkSpec, RouteSpec

__all__ = ["Result", "SimulatorCache", "open_simulator", "routing_tables",
           "run", "run_all"]


def routing_tables(network: NetworkSpec, full: bool = False):
    """Build the network and its precomputed routing tables in one call."""
    return build_tables(build_network(network), full=full)


# ---------------------------------------------------------------------- #
# results
# ---------------------------------------------------------------------- #
def _retuple(v):
    """JSON arrays -> tuples, recursively (inverse of JSON serialization)."""
    if isinstance(v, (list, tuple)):
        return tuple(_retuple(x) for x in v)
    return v


def _aggregate(values) -> Optional[dict]:
    """mean/std/min/max over per-replica values (``None`` entries dropped;
    bools averaged as completion fractions)."""
    vals = [float(v) for v in values if v is not None]
    if not vals:
        return None
    arr = np.asarray(vals, np.float64)
    return {"mean": float(arr.mean()), "std": float(arr.std()),
            "min": float(arr.min()), "max": float(arr.max())}


@dataclasses.dataclass(frozen=True)
class Result:
    """Structured record of one experiment run.

    Only the fields relevant to ``metric`` are populated; the rest stay
    ``None``.  ``latency`` maps percentile labels (``p50``/``p99``/
    ``p999``/``p9999``) to slot counts — uniformly ``float`` (``None``
    when the measurement window ejected nothing), never a mix of int and
    float; ``phase_slots`` holds per-phase completion slots for
    collectives with a phase schedule (allreduce).  The ``serving``
    metric populates ``throughput`` (delivered), ``offered`` (accepted +
    dropped arrivals, packets/slot/endpoint), ``dropped`` (packets the
    full arrival FIFOs rejected in the window), ``pool_stall``, and
    ``latency`` — the open loop means ``throughput`` may fall below
    ``offered``.

    For a batched run (``experiment.replicas > 1``) the scalar metric
    fields hold the across-replica *mean* (``completed`` is the AND), and
    three extra fields are populated: ``replica_seeds`` (the seeds, in
    replica order), ``per_replica`` (field name -> tuple of exact
    per-replica values), and ``aggregates`` (field name ->
    ``{"mean","std","min","max"}``).
    """

    experiment: Experiment
    metric: str
    throughput: Optional[float] = None
    avg_hops: Optional[float] = None
    ejected: Optional[float] = None
    pool_stall: Optional[float] = None
    offered: Optional[float] = None
    dropped: Optional[float] = None
    fail_drop: Optional[float] = None
    latency: Optional[Mapping[str, float]] = None
    slots: Optional[float] = None
    completed: Optional[bool] = None
    phase_slots: Optional[Tuple[float, ...]] = None
    replica_seeds: Optional[Tuple[int, ...]] = None
    per_replica: Optional[Mapping[str, Tuple]] = None
    aggregates: Optional[Mapping[str, Mapping[str, float]]] = None

    @property
    def name(self) -> str:
        return self.experiment.label()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["experiment"] = self.experiment.to_dict()
        if self.latency is not None:
            d["latency"] = dict(self.latency)
        if self.phase_slots is not None:
            d["phase_slots"] = list(self.phase_slots)
        if self.replica_seeds is not None:
            d["replica_seeds"] = list(self.replica_seeds)
        if self.per_replica is not None:
            d["per_replica"] = {k: list(v) for k, v in self.per_replica.items()}
        if self.aggregates is not None:
            d["aggregates"] = {k: dict(v) for k, v in self.aggregates.items()}
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "Result":
        d = dict(d)
        d["experiment"] = Experiment.from_dict(d["experiment"])
        for key in ("phase_slots", "replica_seeds"):
            if d.get(key) is not None:
                d[key] = _retuple(d[key])
        if d.get("per_replica") is not None:
            d["per_replica"] = {k: _retuple(v)
                                for k, v in d["per_replica"].items()}
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Result":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------- #
# simulator lifetime
# ---------------------------------------------------------------------- #
def _make_simulator(network: NetworkSpec, route: RouteSpec,
                    masks: str = "auto") -> Simulator:
    topo = build_network(network)
    if network.failures is not None:
        network.failures.validate(topo)   # fail before the table build
    tables = build_tables(topo, masks=masks)
    return Simulator(tables, route.to_sim_config(),
                     failures=network.failures)


def _admitted_masks(experiment: Experiment) -> str:
    """Admission-control gate for every ``run``/``run_all`` entry: price
    the experiment (resident estimate x empirical compile-RAM multiplier)
    against host RAM *before* building anything, and return the mask
    layout to build tables with (``"blocked"`` when admission downgraded
    a dense layout to fit).  Raises :class:`repro.api.admission.
    AdmissionError` with actionable alternatives when nothing fits;
    ``REPRO_ADMISSION=warn|off`` relaxes the gate."""
    from .admission import check_admission
    return check_admission(experiment).masks


class SimulatorCache:
    """Compiled-simulator reuse across experiments.

    Keyed on ``(NetworkSpec, RouteSpec)`` — both frozen and hashable — so
    a sweep over loads/patterns/seeds on one fabric compiles once.  Also a
    context manager: closing tears down every cached simulator (one cache
    clear total, matching the old manual ``del sim; jax.clear_caches()``).
    """

    def __init__(self):
        self._sims: dict = {}

    def get(self, network: NetworkSpec, route: RouteSpec,
            masks: str = "auto") -> Simulator:
        key = (network, route, masks)
        sim = self._sims.get(key)
        if sim is None:
            sim = self._sims[key] = _make_simulator(network, route, masks)
        return sim

    def __len__(self) -> int:
        return len(self._sims)

    def release(self, network: NetworkSpec, route: RouteSpec,
                masks: str = "auto",
                *, clear: Optional[bool] = None) -> None:
        """Drop one simulator (no-op if absent) — for drivers that know a
        fabric won't be needed again before the cache as a whole closes.

        ``clear=None`` (default) clears the process-global jit cache only
        when this was the last cached simulator: clearing while other
        fabrics are still cached would evict their executables too and
        force silent recompiles.
        """
        sim = self._sims.pop((network, route, masks), None)
        if sim is not None:
            if clear is None:
                clear = not self._sims
            sim.close(clear=clear)

    def close(self) -> None:
        sims, self._sims = list(self._sims.values()), {}
        for sim in sims:
            sim.close(clear=False)
        if sims:
            jax.clear_caches()

    def __enter__(self) -> "SimulatorCache":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


@contextlib.contextmanager
def open_simulator(network: NetworkSpec, route: RouteSpec = RouteSpec()):
    """Low-level escape hatch: a context-managed Simulator for a spec pair."""
    sim = _make_simulator(network, route)
    try:
        yield sim
    finally:
        sim.close()


# ---------------------------------------------------------------------- #
# execution
# ---------------------------------------------------------------------- #
def _to_traffic(exp: Experiment) -> Traffic:
    from ..workloads.patterns import check_pattern
    w = exp.workload
    if check_pattern(w.pattern) == "arrival":
        # arrival families reach the engine as Traffic("arrival") with the
        # process name in ``process`` — never by family name
        return Traffic("arrival", process=w.pattern, load=w.load,
                       pareto_alpha=w.pareto_alpha,
                       pareto_cap=w.pareto_cap,
                       diurnal_amp=w.diurnal_amp,
                       diurnal_period=w.diurnal_period,
                       arr_depth=w.arr_depth)
    return Traffic(pattern=w.pattern, load=w.load, rounds=w.rounds,
                   elephant_frac=w.elephant_frac,
                   elephant_size=w.elephant_size,
                   shift=w.shift, hot_frac=w.hot_frac,
                   hot_count=w.hot_count, burst_len=w.burst_len,
                   burst_load=w.burst_load)


# Result latency labels -> engine percentile keys (p999 is the serving
# SLO tail added alongside the coarse ladder)
_LATENCY_KEYS = (("p50", "p0.5"), ("p99", "p0.99"), ("p999", "p0.999"),
                 ("p9999", "p0.9999"))


def _nan_none(v) -> Optional[float]:
    """NaN (empty measurement window) -> None so Results stay strict-JSON
    and round-trip losslessly."""
    v = float(v)
    return None if np.isnan(v) else v


def _is_program(exp: Experiment) -> bool:
    """Collectives with a program builder execute device-resident.
    ``all2all`` only joins when a schedule is requested (its default is
    the legacy free-running engine pattern); everything else in
    ``PROGRAM_BUILDERS`` — built-in or registered via
    ``register_program_builder`` — always compiles."""
    from ..workloads.programs import PROGRAM_BUILDERS
    w = exp.workload
    if w.pattern == "all2all":
        return bool(w.schedule)
    return w.pattern in PROGRAM_BUILDERS


def _collective_program(sim: Simulator, exp: Experiment):
    """Build + compile the workload program for a collective experiment.

    The allreduce family defaults to the parity-locked ``barrier``
    schedule (bitwise the old host loop); a scheduled ``all2all``
    compiles its shifted-exchange rounds under the requested mode.
    """
    w = exp.workload
    prog = build_collective_program(
        w.pattern, sim.S, rounds=w.rounds, ranks=w.ranks,
        vec_packets=w.vec_packets)
    return compile_program(prog, schedule=w.schedule or "barrier",
                           window=w.window)


def _run_collective(sim: Simulator, exp: Experiment) -> Result:
    """One device-resident program run replaces the old per-phase host
    loop (fresh ``Traffic("phase")`` state + ``run_completion`` per
    Rabenseifner phase) — same ``phase_slots``, zero host round-trips."""
    cp = _collective_program(sim, exp)
    r = sim.run_program(cp, chunk=exp.chunk, max_slots=exp.max_slots,
                        seed=exp.seed)
    return Result(experiment=exp, metric="completion",
                  slots=int(r["slots"]), completed=bool(r["completed"]),
                  pool_stall=int(r["pool_stall"]),
                  phase_slots=tuple(int(s) for s in r["phase_slots"]))


# ---------------------------------------------------------------------- #
# batched (vmapped-replica) execution
# ---------------------------------------------------------------------- #
def _batched_metrics(sim: Simulator, exp: Experiment, seeds) -> Tuple[str, dict]:
    """Run ``exp`` once per seed inside one vmapped executable.

    Returns ``(metric, per)`` where ``per`` maps metric field names to
    tuples of exact per-replica python scalars (``phase_slots``: tuple of
    per-replica tuples).  Replica ``i`` is bitwise-identical to a scalar
    run with ``seed=seeds[i]``.
    """
    metric = exp.resolved_metric()
    w = exp.workload
    seeds = [int(s) for s in seeds]

    if _is_program(exp):
        if metric != "completion":
            raise ValueError(f"{w.pattern} only supports the completion "
                             "metric")
        # one device computation for all R replicas x P phases: the phase
        # counters and per-phase completion slots live on device
        cp = _collective_program(sim, exp)
        r = sim.run_program(cp, chunk=exp.chunk, max_slots=exp.max_slots,
                            seeds=seeds)
        return metric, {
            "slots": tuple(int(x) for x in r["slots"]),
            "completed": tuple(bool(x) for x in r["completed"]),
            "pool_stall": tuple(int(x) for x in r["pool_stall"]),
            "phase_slots": tuple(tuple(int(v) for v in row)
                                 for row in r["phase_slots"]),
        }

    traffic = _to_traffic(exp)
    if metric == "throughput":
        r = sim.run_throughput_batch(traffic, seeds, warm=exp.warm,
                                     measure=exp.measure)
        return metric, {
            "throughput": tuple(float(x) for x in r["throughput"]),
            "avg_hops": tuple(float(x) for x in r["avg_hops"]),
            "ejected": tuple(int(x) for x in r["ejected"]),
            "pool_stall": tuple(int(x) for x in r["pool_stall"]),
        }
    if metric == "latency":
        r = sim.run_latency_batch(traffic, seeds, warm=exp.warm,
                                  measure=exp.measure)
        return metric, {
            lbl: tuple(_nan_none(v) for v in r[k])
            for lbl, k in _LATENCY_KEYS
        }
    if metric == "serving":
        r = sim.run_serving_batch(traffic, seeds, warm=exp.warm,
                                  measure=exp.measure)
        per = {
            "throughput": tuple(float(x) for x in r["delivered"]),
            "offered": tuple(float(x) for x in r["offered"]),
            "dropped": tuple(int(x) for x in r["dropped"]),
            "pool_stall": tuple(int(x) for x in r["pool_stall"]),
        }
        per.update({lbl: tuple(_nan_none(v) for v in r[k])
                    for lbl, k in _LATENCY_KEYS})
        return metric, per
    if metric == "resilience":
        # Failure transitions mutate host routing tables mid-run, so
        # replicas cannot share one vmapped executable; loop scalar runs
        # (replica i stays bitwise the scalar run with seed=seeds[i]).
        per = {"throughput": [], "avg_hops": [], "ejected": [],
               "pool_stall": [], "fail_drop": []}
        lat = {lbl: [] for lbl, _ in _LATENCY_KEYS}
        for s in seeds:
            r = sim.run_resilience(traffic, warm=exp.warm,
                                   measure=exp.measure, seed=s)
            per["throughput"].append(float(r["throughput"]))
            per["avg_hops"].append(float(r["avg_hops"]))
            per["ejected"].append(int(r["ejected"]))
            per["pool_stall"].append(int(r["pool_stall"]))
            per["fail_drop"].append(int(r["fail_drop"]))
            for lbl, k in _LATENCY_KEYS:
                lat[lbl].append(_nan_none(r[k]))
        out = {k: tuple(v) for k, v in per.items()}
        out.update({lbl: tuple(v) for lbl, v in lat.items()})
        return metric, out
    if metric == "completion":
        if w.pattern != "all2all":
            raise ValueError(
                f"completion metric needs a collective workload, got "
                f"{w.pattern!r}")
        r = sim.run_completion_batch(traffic, expected=sim.S * w.rounds,
                                     seeds=seeds, chunk=exp.chunk,
                                     max_slots=exp.max_slots)
        return metric, {
            "slots": tuple(int(x) for x in r["slots"]),
            "completed": tuple(bool(x) for x in r["completed"]),
            "pool_stall": tuple(int(x) for x in r["pool_stall"]),
        }
    raise ValueError(f"unknown metric {metric!r}")


def _batched_result(exp: Experiment, seeds, metric: str, per: dict) -> Result:
    agg = {}
    for k, vals in per.items():
        if k == "phase_slots":
            continue
        a = _aggregate(vals)
        if a is not None:
            agg[k] = a

    def mean(k):
        return agg[k]["mean"] if k in agg else None

    if metric == "throughput":
        kw = dict(throughput=mean("throughput"), avg_hops=mean("avg_hops"),
                  ejected=mean("ejected"), pool_stall=mean("pool_stall"))
    elif metric == "latency":
        kw = dict(latency={lbl: mean(lbl) for lbl, _ in _LATENCY_KEYS})
    elif metric == "serving":
        kw = dict(throughput=mean("throughput"), offered=mean("offered"),
                  dropped=mean("dropped"), pool_stall=mean("pool_stall"),
                  latency={lbl: mean(lbl) for lbl, _ in _LATENCY_KEYS})
    elif metric == "resilience":
        kw = dict(throughput=mean("throughput"), avg_hops=mean("avg_hops"),
                  ejected=mean("ejected"), pool_stall=mean("pool_stall"),
                  fail_drop=mean("fail_drop"),
                  latency={lbl: mean(lbl) for lbl, _ in _LATENCY_KEYS})
    else:
        kw = dict(slots=mean("slots"),
                  completed=bool(all(per["completed"])),
                  pool_stall=mean("pool_stall"))
        if "phase_slots" in per:
            rows = per["phase_slots"]
            kw["phase_slots"] = tuple(
                float(np.mean([row[i] for row in rows]))
                for i in range(len(rows[0])))
    return Result(experiment=exp, metric=metric,
                  replica_seeds=tuple(int(s) for s in seeds),
                  per_replica=per, aggregates=agg, **kw)


def _unfold_batch(group, metric: str, per: dict) -> list:
    """Split one batched run back into per-experiment scalar Results (used
    when ``run_all`` folds a seed-only group — replica i is bitwise the
    scalar run of ``group[i]``, so the Results are interchangeable)."""
    out = []
    for i, e in enumerate(group):
        if metric == "throughput":
            kw = dict(throughput=per["throughput"][i],
                      avg_hops=per["avg_hops"][i],
                      ejected=per["ejected"][i],
                      pool_stall=per["pool_stall"][i])
        elif metric == "latency":
            kw = dict(latency={lbl: per[lbl][i]
                               for lbl, _ in _LATENCY_KEYS})
        elif metric == "serving":
            kw = dict(throughput=per["throughput"][i],
                      offered=per["offered"][i],
                      dropped=per["dropped"][i],
                      pool_stall=per["pool_stall"][i],
                      latency={lbl: per[lbl][i]
                               for lbl, _ in _LATENCY_KEYS})
        elif metric == "resilience":
            kw = dict(throughput=per["throughput"][i],
                      avg_hops=per["avg_hops"][i],
                      ejected=per["ejected"][i],
                      pool_stall=per["pool_stall"][i],
                      fail_drop=per["fail_drop"][i],
                      latency={lbl: per[lbl][i]
                               for lbl, _ in _LATENCY_KEYS})
        else:
            kw = dict(slots=per["slots"][i], completed=per["completed"][i],
                      pool_stall=per["pool_stall"][i])
            if "phase_slots" in per:
                kw["phase_slots"] = per["phase_slots"][i]
        out.append(Result(experiment=e, metric=metric, **kw))
    return out


def _fold_key(e: Experiment) -> Experiment:
    return dataclasses.replace(e, seed=0, name="")


def _fold_groups(experiments) -> list:
    """Group consecutive experiments that differ only in ``seed``/``name``
    (unbatched ones) — each group becomes one vmapped run."""
    groups = []
    for e in experiments:
        if (groups and e.replicas == 1 and groups[-1][0].replicas == 1
                and _fold_key(groups[-1][0]) == _fold_key(e)):
            groups[-1].append(e)
        else:
            groups.append([e])
    return groups


# ---------------------------------------------------------------------- #
# entry points
# ---------------------------------------------------------------------- #
def run(experiment: Experiment, *,
        cache: Optional[SimulatorCache] = None) -> Result:
    """Execute ``experiment`` end to end and return a :class:`Result`.

    With ``cache`` given, the compiled simulator is fetched from / stored
    into it and left open; otherwise a private simulator is built and
    closed before returning.

    Admission control runs first (see :mod:`repro.api.admission`): an
    experiment predicted to exceed host RAM — resident estimate times the
    empirical compile-RAM multiplier — is auto-downgraded to blocked
    routing masks when that closes the gap, and refused with an
    actionable :class:`~repro.api.admission.AdmissionError` otherwise
    (``REPRO_ADMISSION=warn|off`` relaxes the gate).
    """
    masks = _admitted_masks(experiment)
    owns = cache is None
    sim = (_make_simulator(experiment.network, experiment.route, masks)
           if owns
           else cache.get(experiment.network, experiment.route, masks))
    try:
        return _run_on(sim, experiment)
    finally:
        if owns:
            sim.close()


def run_all(experiments, *, cache: Optional[SimulatorCache] = None,
            fold_seeds: bool = True) -> list:
    """Run a sequence of experiments, sharing simulators across same-fabric
    entries.  With a private cache (none passed in), each fabric's simulator
    is evicted right after its last use so multi-fabric suites don't
    accumulate ~25 live instances (the documented host-OOM mode).

    ``fold_seeds=True`` (default) folds consecutive experiments that differ
    only in ``seed`` (e.g. a ``sweep`` seed axis) into one vmapped batched
    run, then splits the Results back out — same Results, one compile and
    no per-replica host loops.
    """
    experiments = list(experiments)
    owns = cache is None
    if owns:
        cache = SimulatorCache()
    groups = (_fold_groups(experiments) if fold_seeds
              else [[e] for e in experiments])
    # admission decisions are memoized per fabric, so pricing every
    # experiment up front costs one topology build per distinct fabric
    masks = {id(e): _admitted_masks(e) for e in experiments}
    last_use = {(e.network, e.route, masks[id(e)]): i
                for i, e in enumerate(experiments)}
    results = []
    pos = 0
    try:
        for group in groups:
            if len(group) == 1:
                results.append(run(group[0], cache=cache))
            else:
                m = masks[id(group[0])]
                sim = cache.get(group[0].network, group[0].route, m)
                metric, per = _batched_metrics(
                    sim, group[0], [e.seed for e in group])
                results.extend(_unfold_batch(group, metric, per))
            pos += len(group)
            e = group[-1]
            if owns and last_use[(e.network, e.route,
                                  masks[id(e)])] == pos - 1:
                cache.release(e.network, e.route, masks[id(e)])
        return results
    finally:
        if owns:
            cache.close()


def _run_on(sim: Simulator, exp: Experiment) -> Result:
    metric = exp.resolved_metric()
    if exp.replicas > 1:
        seeds = exp.replica_seeds()
        metric, per = _batched_metrics(sim, exp, seeds)
        return _batched_result(exp, seeds, metric, per)
    if _is_program(exp):
        if metric != "completion":
            raise ValueError(f"{exp.workload.pattern} only supports the "
                             "completion metric")
        return _run_collective(sim, exp)

    traffic = _to_traffic(exp)
    if metric == "throughput":
        r = sim.run_throughput(traffic, warm=exp.warm, measure=exp.measure,
                               seed=exp.seed)
        return Result(experiment=exp, metric=metric,
                      throughput=float(r["throughput"]),
                      avg_hops=float(r["avg_hops"]),
                      ejected=int(r["ejected"]),
                      pool_stall=int(r["pool_stall"]))
    if metric == "latency":
        r = sim.run_latency(traffic, warm=exp.warm, measure=exp.measure,
                            seed=exp.seed)
        # zero ejections in the window -> NaN percentiles; map to None so
        # the Result stays strict-JSON and round-trips losslessly
        lat = {lbl: _nan_none(r[k]) for lbl, k in _LATENCY_KEYS}
        return Result(experiment=exp, metric=metric, latency=lat)
    if metric == "serving":
        r = sim.run_serving(traffic, warm=exp.warm, measure=exp.measure,
                            seed=exp.seed)
        lat = {lbl: _nan_none(r[k]) for lbl, k in _LATENCY_KEYS}
        return Result(experiment=exp, metric=metric,
                      throughput=float(r["delivered"]),
                      offered=float(r["offered"]),
                      dropped=int(r["dropped"]),
                      pool_stall=int(r["pool_stall"]), latency=lat)
    if metric == "resilience":
        r = sim.run_resilience(traffic, warm=exp.warm, measure=exp.measure,
                               seed=exp.seed)
        lat = {lbl: _nan_none(r[k]) for lbl, k in _LATENCY_KEYS}
        return Result(experiment=exp, metric=metric,
                      throughput=float(r["throughput"]),
                      avg_hops=float(r["avg_hops"]),
                      ejected=int(r["ejected"]),
                      pool_stall=int(r["pool_stall"]),
                      fail_drop=int(r["fail_drop"]), latency=lat)
    if metric == "completion":
        if exp.workload.pattern != "all2all":
            raise ValueError(
                f"completion metric needs a collective workload, got "
                f"{exp.workload.pattern!r}")
        expected = sim.S * exp.workload.rounds
        r = sim.run_completion(traffic, expected=expected, chunk=exp.chunk,
                               max_slots=exp.max_slots, seed=exp.seed)
        return Result(experiment=exp, metric=metric, slots=int(r["slots"]),
                      completed=bool(r["completed"]),
                      pool_stall=int(r["pool_stall"]))
    raise ValueError(f"unknown metric {metric!r}")
