"""One-call experiment execution: ``run(experiment) -> Result``.

Owns the four-stage pipeline every driver used to hand-wire —
topology builder -> ``build_tables`` -> ``Simulator(SimConfig)`` ->
``Traffic`` — plus simulator lifetime (context-managed; teardown clears
the jit caches that otherwise accumulate one executable per instance)
and collective orchestration (Rabenseifner allreduce runs its phase
schedule internally instead of callers patching ``st["partner"]``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Mapping, Optional, Tuple

import jax
import numpy as np

from ..core import build_tables
from ..core.collectives import rabenseifner_phases
from ..simulator.engine import Simulator, Traffic
from .registry import build_network
from .specs import Experiment, NetworkSpec, RouteSpec

__all__ = ["Result", "SimulatorCache", "open_simulator", "routing_tables",
           "run", "run_all"]


def routing_tables(network: NetworkSpec, full: bool = False):
    """Build the network and its precomputed routing tables in one call."""
    return build_tables(build_network(network), full=full)


# ---------------------------------------------------------------------- #
# results
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Result:
    """Structured record of one experiment run.

    Only the fields relevant to ``metric`` are populated; the rest stay
    ``None``.  ``latency`` maps percentile labels (``p50``/``p99``/
    ``p9999``) to slot counts; ``phase_slots`` holds per-phase completion
    slots for collectives with a phase schedule (allreduce).
    """

    experiment: Experiment
    metric: str
    throughput: Optional[float] = None
    avg_hops: Optional[float] = None
    ejected: Optional[int] = None
    latency: Optional[Mapping[str, int]] = None
    slots: Optional[int] = None
    completed: Optional[bool] = None
    phase_slots: Optional[Tuple[int, ...]] = None

    @property
    def name(self) -> str:
        return self.experiment.label()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["experiment"] = self.experiment.to_dict()
        if self.latency is not None:
            d["latency"] = dict(self.latency)
        if self.phase_slots is not None:
            d["phase_slots"] = list(self.phase_slots)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "Result":
        d = dict(d)
        d["experiment"] = Experiment.from_dict(d["experiment"])
        if d.get("phase_slots") is not None:
            d["phase_slots"] = tuple(d["phase_slots"])
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Result":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------- #
# simulator lifetime
# ---------------------------------------------------------------------- #
def _make_simulator(network: NetworkSpec, route: RouteSpec) -> Simulator:
    tables = build_tables(build_network(network))
    return Simulator(tables, route.to_sim_config())


class SimulatorCache:
    """Compiled-simulator reuse across experiments.

    Keyed on ``(NetworkSpec, RouteSpec)`` — both frozen and hashable — so
    a sweep over loads/patterns/seeds on one fabric compiles once.  Also a
    context manager: closing tears down every cached simulator (one cache
    clear total, matching the old manual ``del sim; jax.clear_caches()``).
    """

    def __init__(self):
        self._sims: dict = {}

    def get(self, network: NetworkSpec, route: RouteSpec) -> Simulator:
        key = (network, route)
        sim = self._sims.get(key)
        if sim is None:
            sim = self._sims[key] = _make_simulator(network, route)
        return sim

    def __len__(self) -> int:
        return len(self._sims)

    def release(self, network: NetworkSpec, route: RouteSpec,
                *, clear: Optional[bool] = None) -> None:
        """Drop one simulator (no-op if absent) — for drivers that know a
        fabric won't be needed again before the cache as a whole closes.

        ``clear=None`` (default) clears the process-global jit cache only
        when this was the last cached simulator: clearing while other
        fabrics are still cached would evict their executables too and
        force silent recompiles.
        """
        sim = self._sims.pop((network, route), None)
        if sim is not None:
            if clear is None:
                clear = not self._sims
            sim.close(clear=clear)

    def close(self) -> None:
        sims, self._sims = list(self._sims.values()), {}
        for sim in sims:
            sim.close(clear=False)
        if sims:
            jax.clear_caches()

    def __enter__(self) -> "SimulatorCache":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


@contextlib.contextmanager
def open_simulator(network: NetworkSpec, route: RouteSpec = RouteSpec()):
    """Low-level escape hatch: a context-managed Simulator for a spec pair."""
    sim = _make_simulator(network, route)
    try:
        yield sim
    finally:
        sim.close()


# ---------------------------------------------------------------------- #
# execution
# ---------------------------------------------------------------------- #
def _to_traffic(exp: Experiment) -> Traffic:
    w = exp.workload
    return Traffic(pattern=w.pattern, load=w.load, rounds=w.rounds,
                   elephant_frac=w.elephant_frac,
                   elephant_size=w.elephant_size)


def _run_allreduce(sim: Simulator, exp: Experiment) -> Result:
    n = exp.workload.ranks or 1 << (sim.S.bit_length() - 1)
    if n > sim.S:
        raise ValueError(f"allreduce ranks {n} > endpoints {sim.S}")
    total, ok, per_phase = 0, True, []
    for ph in rabenseifner_phases(n, exp.workload.vec_packets):
        tr = Traffic("phase", phase_packets=ph["packets"])
        st = sim.make_state(tr, seed=exp.seed)
        partner = np.arange(sim.S, dtype=np.int32)
        partner[:n] = ph["partner"]
        st["partner"] = np.asarray(partner)
        expected = int((partner[:n] != np.arange(n)).sum()) * ph["packets"]
        r = sim.run_completion(tr, expected=expected, chunk=exp.chunk,
                               max_slots=exp.max_slots, state=st)
        ok &= r["completed"]
        total += r["slots"]
        per_phase.append(int(r["slots"]))
    return Result(experiment=exp, metric="completion", slots=total,
                  completed=ok, phase_slots=tuple(per_phase))


def run(experiment: Experiment, *,
        cache: Optional[SimulatorCache] = None) -> Result:
    """Execute ``experiment`` end to end and return a :class:`Result`.

    With ``cache`` given, the compiled simulator is fetched from / stored
    into it and left open; otherwise a private simulator is built and
    closed before returning.
    """
    owns = cache is None
    sim = (_make_simulator(experiment.network, experiment.route) if owns
           else cache.get(experiment.network, experiment.route))
    try:
        return _run_on(sim, experiment)
    finally:
        if owns:
            sim.close()


def run_all(experiments, *,
            cache: Optional[SimulatorCache] = None) -> list:
    """Run a sequence of experiments, sharing simulators across same-fabric
    entries.  With a private cache (none passed in), each fabric's simulator
    is evicted right after its last use so multi-fabric suites don't
    accumulate ~25 live instances (the documented host-OOM mode)."""
    experiments = list(experiments)
    owns = cache is None
    if owns:
        cache = SimulatorCache()
    last_use = {(e.network, e.route): i for i, e in enumerate(experiments)}
    results = []
    try:
        for i, exp in enumerate(experiments):
            results.append(run(exp, cache=cache))
            if owns and last_use[(exp.network, exp.route)] == i:
                cache.release(exp.network, exp.route)
        return results
    finally:
        if owns:
            cache.close()


def _run_on(sim: Simulator, exp: Experiment) -> Result:
    metric = exp.resolved_metric()
    if exp.workload.pattern == "allreduce":
        if metric != "completion":
            raise ValueError("allreduce only supports the completion metric")
        return _run_allreduce(sim, exp)

    traffic = _to_traffic(exp)
    if metric == "throughput":
        r = sim.run_throughput(traffic, warm=exp.warm, measure=exp.measure,
                               seed=exp.seed)
        return Result(experiment=exp, metric=metric,
                      throughput=float(r["throughput"]),
                      avg_hops=float(r["avg_hops"]),
                      ejected=int(r["ejected"]))
    if metric == "latency":
        r = sim.run_latency(traffic, warm=exp.warm, measure=exp.measure,
                            seed=exp.seed)
        # zero ejections in the window -> NaN percentiles; map to None so
        # the Result stays strict-JSON and round-trips losslessly
        def _p(v):
            return None if isinstance(v, float) and np.isnan(v) else int(v)
        lat = {"p50": _p(r["p0.5"]), "p99": _p(r["p0.99"]),
               "p9999": _p(r["p0.9999"])}
        return Result(experiment=exp, metric=metric, latency=lat)
    if metric == "completion":
        if exp.workload.pattern != "all2all":
            raise ValueError(
                f"completion metric needs a collective workload, got "
                f"{exp.workload.pattern!r}")
        expected = sim.S * exp.workload.rounds
        r = sim.run_completion(traffic, expected=expected, chunk=exp.chunk,
                               max_slots=exp.max_slots, seed=exp.seed)
        return Result(experiment=exp, metric=metric, slots=int(r["slots"]),
                      completed=bool(r["completed"]))
    raise ValueError(f"unknown metric {metric!r}")
