"""``python -m repro.api`` — run experiment specs from JSON.

Commands:

* ``run <spec.json> [--replicas R] [--out results.json]`` — spec file
  holds one experiment object or ``{"experiments": [...]}``; simulators
  are shared across experiments on the same fabric.  ``--replicas R``
  overrides every experiment's ``replicas`` (one vmapped batched run over
  R seeds instead of R sequential runs).  ``--ckpt-dir DIR`` runs a
  single-experiment spec through the resumable runtime
  (:mod:`repro.runtime.resilient`): engine state snapshots at every
  ``--ckpt-every`` chunk/slot boundary, and re-running the same command
  after a kill resumes bitwise from the latest snapshot.
* ``resume <ckpt_dir>`` — continue (or just report) the run stored in a
  ``--ckpt-dir`` directory, from its saved spec and latest snapshot; a
  completed run prints its stored Result without recomputation.
* ``sweep <spec.json> [--replicas R] [--out results.json]`` — spec file
  holds ``{"base": <experiment>, "axes": {"workload.load": [...], ...}}``;
  a seed-only axis is folded into one batched run per remaining grid point.
* ``serve-sweep <spec.json> [--out slo.json]`` — spec file holds one
  :class:`repro.serving.ServingSpec` object (``{"serving": {...}}`` or
  ``{"servings": [...]}``, bare object accepted); runs the open-loop
  load ladder and prints the p50/p99/p999 SLO curve plus the saturation
  knee per spec.  ``--out`` writes the full SLO records.
* ``degrade <spec.json> [--out faults.json]`` — spec file holds
  ``{"base": <experiment>, "rates": [0, 0.01, ...]}`` (or
  ``{"sweeps": [...]}``); fails the given fraction of links early in
  warmup via one seeded :class:`repro.core.FailureSchedule` ladder and
  prints delivered throughput + retention per rate (the resilience
  metric's degradation curve).
* ``estimate <spec.json> [--out est.json]`` — price every experiment's
  memory footprint (routing tables, per-replica state, transients) via
  :func:`repro.api.estimate_memory` *without* running anything — the
  pre-flight check for extreme-scale fabrics.  Each line also prints the
  predicted process peak (resident + empirical compile-RAM multiplier
  from ``BENCH_scale.json``) and warns when it exceeds host RAM.
* ``families`` — list registered topology families.
* ``patterns`` — list the workload-pattern registry (Bernoulli families,
  collectives, and which collectives compile to device-resident programs).

Each result prints as a one-line human summary on stderr-free stdout plus,
with ``--out``, the full JSON records.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .memory import estimate_memory, format_bytes
from .runner import Result, run_all
from .registry import topology_families, workload_patterns
from .specs import Experiment
from .sweep import sweep
# registers the lm_prefill/lm_decode/lm_moe bridge patterns, so specs
# naming them load from any CLI entry point
from .. import serving

__all__ = ["main"]


def _summary(res: Result) -> str:
    bits = [f"{res.name}", f"metric={res.metric}"]
    if res.replica_seeds is not None:
        bits.append(f"replicas={len(res.replica_seeds)}")
    if res.offered is not None:
        bits.append(f"offered={res.offered:.3f}")
        bits.append(f"delivered={res.throughput:.3f}")
        if res.dropped:
            bits.append(f"dropped={res.dropped:g}")
    elif res.throughput is not None:
        bits.append(f"throughput={res.throughput:.3f}")
        bits.append(f"avg_hops={res.avg_hops:.2f}")
    if res.fail_drop:
        bits.append(f"fail_drop={res.fail_drop:g}")
    if res.latency is not None:
        bits.append("lat " + "/".join(f"{k}={v}" for k, v in res.latency.items()))
    if res.slots is not None:
        slots = (f"{res.slots:.1f}" if isinstance(res.slots, float)
                 else f"{res.slots}")
        bits.append(f"slots={slots}")
        bits.append(f"completed={res.completed}")
        agg = res.aggregates or {}
        if "slots" in agg:
            bits.append(f"slots_std={agg['slots']['std']:.1f}")
    return "  ".join(bits)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _emit(results: List[Result], out: Optional[str]) -> None:
    for res in results:
        print(_summary(res))
    if out:
        with open(out, "w") as f:
            json.dump([r.to_dict() for r in results], f, indent=2)
        print(f"wrote {len(results)} result(s) to {out}")


def _cmd_run(args) -> int:
    doc = _load(args.spec)
    specs = doc["experiments"] if "experiments" in doc else [doc]
    exps = [Experiment.from_dict(d) for d in specs]
    if args.replicas is not None:
        exps = [e.override("replicas", args.replicas) for e in exps]
    if args.ckpt_dir is not None:
        from .resume import run_resumable
        if len(exps) != 1:
            print("--ckpt-dir needs a single-experiment spec "
                  f"(got {len(exps)})", file=sys.stderr)
            return 2
        results = [run_resumable(exps[0], args.ckpt_dir,
                                 every=args.ckpt_every)]
    else:
        results = run_all(exps)
    _emit(results, args.out)
    return 0


def _cmd_resume(args) -> int:
    from .resume import resume
    res = resume(args.ckpt_dir, every=args.ckpt_every)
    _emit([res], args.out)
    return 0


def _cmd_sweep(args) -> int:
    doc = _load(args.spec)
    base = Experiment.from_dict(doc["base"])
    if args.replicas is not None:
        base = base.override("replicas", args.replicas)
    results = sweep(base, doc.get("axes", {}))
    _emit(results, args.out)
    return 0


def _fmt_q(v) -> str:
    return "-" if v is None else f"{v:g}"


def _cmd_serve_sweep(args) -> int:
    doc = _load(args.spec)
    if "servings" in doc:
        raw = doc["servings"]
    elif "serving" in doc:
        raw = [doc["serving"]]
    else:
        raw = [doc]
    specs = [serving.ServingSpec.from_dict(d) for d in raw]
    records = serving.serve_sweep_many(specs)
    for rec in records:
        print(f"{rec['name']}  process={rec['spec']['process']}  "
              f"loads={len(rec['points'])}")
        for p in rec["points"]:
            print(f"  load={p['load']:g}  offered={p['offered']:.3f}  "
                  f"delivered={p['delivered']:.3f}  "
                  f"p50={_fmt_q(p.get('p50'))}  p99={_fmt_q(p.get('p99'))}  "
                  f"p999={_fmt_q(p.get('p999'))}  dropped={p['dropped']:g}")
        sat = rec["saturation"]
        print("  saturation: " + (
            f"load={sat['load']:g} (delivered/offered={sat['ratio']:.3f})"
            if sat else "none within swept loads"))
        req = rec.get("request")
        if req:
            print(f"  request: {req['model']}/{req['phase']} -> "
                  f"{req['pattern']} ranks={req['shape']['ranks']} "
                  f"packets={req['shape']['packets']} "
                  f"slots={req['slots']} completed={req['completed']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {len(records)} SLO record(s) to {args.out}")
    return 0


def _cmd_degrade(args) -> int:
    from .degrade import degrade_sweep_from_dict
    records = degrade_sweep_from_dict(_load(args.spec))
    for rec in records:
        print(f"{rec['name']}  policy={rec['policy']}  "
              f"fail_policy={rec['fail_policy']}  links={rec['n_links']}")
        for p in rec["points"]:
            ret = ("-" if p["retention"] is None
                   else f"{p['retention']:.3f}")
            print(f"  rate={p['rate']:g}  down={p['n_links_down']}  "
                  f"delivered={p['delivered']:.3f}  retention={ret}  "
                  f"p50={_fmt_q(p.get('p50'))}  p99={_fmt_q(p.get('p99'))}  "
                  f"fail_drop={p['fail_drop']:g}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {len(records)} degradation record(s) to {args.out}")
    return 0


def _cmd_estimate(args) -> int:
    doc = _load(args.spec)
    specs = doc["experiments"] if "experiments" in doc else [doc]
    exps = [Experiment.from_dict(d) for d in specs]
    if args.replicas is not None:
        exps = [e.override("replicas", args.replicas) for e in exps]
    from .admission import (compile_ram_multiplier, host_ram_bytes,
                            predict_peak_rss)
    ram = host_ram_bytes()
    records = []
    for e in exps:
        est = estimate_memory(e)
        mult = compile_ram_multiplier(e.network.family)
        predicted = predict_peak_rss(est["total_bytes"], mult)
        est["compile_ram_multiplier"] = mult
        est["predicted_peak_rss_bytes"] = predicted
        records.append({"name": e.label(), **est})
        dims = est["dims"]
        over = (ram is not None and predicted > ram)
        print(f"{e.label()}  S={dims['n_endpoints']}  "
              f"masks={est['tables']['mask_layout']}  "
              f"tables={format_bytes(est['tables']['device_mask_bytes'] + est['tables']['dist_leaf_bytes'])}  "
              f"state/replica={format_bytes(est['state_bytes_per_replica'])}  "
              f"total={format_bytes(est['total_bytes'])}  "
              f"peak={format_bytes(est['peak_bytes'])}  "
              f"predicted_rss={format_bytes(predicted)} "
              f"(x{mult:.1f} compile)"
              + (f"  ** OVER host RAM {format_bytes(ram)} — admission "
                 "would refuse or downgrade **" if over else ""))
    if ram is not None:
        print(f"host RAM: {format_bytes(ram)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {len(records)} estimate(s) to {args.out}")
    return 0


def _cmd_families(_args) -> int:
    for name in topology_families():
        print(name)
    return 0


def _cmd_patterns(_args) -> int:
    for name, kind in workload_patterns():
        print(f"{name}  [{kind}]")
    print("(* = compiles to a device-resident workload program; "
          "supports schedule=barrier|window)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.api",
                                     description=__doc__.splitlines()[0])
    sub = parser.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="run experiment spec(s) from JSON")
    p_run.add_argument("spec", help="path to the experiment JSON file")
    p_run.add_argument("--out", help="write full Result JSON records here")
    p_run.add_argument("--replicas", type=int, default=None,
                       help="override replicas (>= 1): one vmapped batched "
                            "run over R seeds per experiment")
    p_run.add_argument("--ckpt-dir", default=None,
                       help="checkpoint directory: run resumably, "
                            "snapshotting engine state at segment "
                            "boundaries (single-experiment specs only)")
    p_run.add_argument("--ckpt-every", type=int, default=64,
                       help="segment length between checkpoints, in engine "
                            "chunks (completion) or slots (windowed "
                            "metrics); default 64")
    p_run.set_defaults(fn=_cmd_run)

    p_res = sub.add_parser(
        "resume", help="resume a --ckpt-dir run from its latest snapshot")
    p_res.add_argument("ckpt_dir", help="checkpoint directory of the run")
    p_res.add_argument("--out", help="write the full Result JSON here")
    p_res.add_argument("--ckpt-every", type=int, default=64,
                       help="segment length for the continued run")
    p_res.set_defaults(fn=_cmd_resume)

    p_sweep = sub.add_parser("sweep", help="run a {base, axes} sweep spec")
    p_sweep.add_argument("spec", help="path to the sweep JSON file")
    p_sweep.add_argument("--out", help="write full Result JSON records here")
    p_sweep.add_argument("--replicas", type=int, default=None,
                         help="override the base experiment's replicas (>= 1)")
    p_sweep.set_defaults(fn=_cmd_sweep)

    p_serve = sub.add_parser(
        "serve-sweep", help="run open-loop serving SLO sweep spec(s)")
    p_serve.add_argument("spec", help="path to the ServingSpec JSON file")
    p_serve.add_argument("--out", help="write full SLO JSON records here")
    p_serve.set_defaults(fn=_cmd_serve_sweep)

    p_deg = sub.add_parser(
        "degrade", help="run a link-failure degradation sweep spec")
    p_deg.add_argument("spec", help="path to the degrade JSON file")
    p_deg.add_argument("--out", help="write full degradation records here")
    p_deg.set_defaults(fn=_cmd_degrade)

    p_est = sub.add_parser(
        "estimate", help="estimate memory for experiment spec(s), no run")
    p_est.add_argument("spec", help="path to the experiment JSON file")
    p_est.add_argument("--out", help="write full estimate JSON records here")
    p_est.add_argument("--replicas", type=int, default=None,
                       help="override replicas for the estimate")
    p_est.set_defaults(fn=_cmd_estimate)

    p_fam = sub.add_parser("families", help="list topology families")
    p_fam.set_defaults(fn=_cmd_families)

    p_pat = sub.add_parser("patterns",
                           help="list workload patterns (shared registry)")
    p_pat.set_defaults(fn=_cmd_patterns)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
