"""``python -m repro.api`` — run experiment specs from JSON.

Commands:

* ``run <spec.json> [--replicas R] [--seed S] [--out results.json]`` —
  spec file holds one experiment object or ``{"experiments": [...]}``;
  simulators are shared across experiments on the same fabric.
  ``--replicas R`` overrides every experiment's ``replicas`` (one
  vmapped batched run over R seeds instead of R sequential runs);
  ``--seed S`` overrides every experiment's base seed.  ``--ckpt-dir
  DIR`` runs a single-experiment spec through the resumable runtime
  (:mod:`repro.runtime.resilient`): engine state snapshots at every
  ``--ckpt-every`` chunk/slot boundary, and re-running the same command
  after a kill resumes bitwise from the latest snapshot.
* ``resume <ckpt_dir>`` — continue (or just report) the run stored in a
  ``--ckpt-dir`` directory, from its saved spec and latest snapshot; a
  completed run prints its stored Result without recomputation.
* ``sweep <spec.json> [--replicas R] [--seed S] [--out results.json]`` —
  spec file holds ``{"base": <experiment>, "axes": {"workload.load":
  [...], ...}}``; a seed-only axis is folded into one batched run per
  remaining grid point.
* ``serve-sweep <spec.json> [--seed S] [--out slo.json]`` — spec file
  holds one :class:`repro.serving.ServingSpec` object (``{"serving":
  {...}}`` or ``{"servings": [...]}``, bare object accepted); runs the
  open-loop load ladder and prints the p50/p99/p999 SLO curve plus the
  saturation knee per spec.  ``--out`` writes the full SLO records.
* ``degrade <spec.json> [--seed S] [--out faults.json]`` — spec file
  holds one :class:`repro.api.DegradeSpec` (``{"base": <experiment>,
  "rates": [0, 0.01, ...]}``, or ``{"sweeps": [...]}``); fails the given
  fraction of links early in warmup via one seeded
  :class:`repro.core.FailureSchedule` ladder and prints delivered
  throughput + retention per rate.
* ``search <spec.json> [--replicas R] [--seed S] [--out record.json]``
  — design-space search (:mod:`repro.search`): spec file holds one
  :class:`repro.search.SearchSpec` (``{"search": {...}}`` or bare);
  samples (family, radix, f, policy, vcs) candidates at a fixed
  endpoint count, prunes infeasible ones via the memory estimator +
  admission *before* compiling, screens the rest with short runs,
  promotes survivors to full windows (successive halving), and commits
  the Pareto frontier artifact (``--pareto-out``, default
  ``artifacts/PARETO_search.json``).
* ``estimate <spec.json> [--out est.json]`` — price every experiment's
  memory footprint (routing tables, per-replica state, transients) via
  :func:`repro.api.estimate_memory` *without* running anything — the
  pre-flight check for extreme-scale fabrics.  Each line also prints the
  predicted process peak (resident + empirical compile-RAM multiplier
  from ``BENCH_scale.json``) and warns when it exceeds host RAM.
* ``families`` — list registered topology families.
* ``patterns`` — list the workload-pattern registry (Bernoulli families,
  collectives, and which collectives compile to device-resident programs).

Each result prints as a one-line human summary on stderr-free stdout plus,
with ``--out``, the full JSON records.

Subcommands live in a declarative registry: a driver module declares a
:class:`Subcommand` (name, handler, which of the shared
``spec``/``--out``/``--replicas``/``--seed`` surface it wants, plus any
extra flags) and calls :func:`register_subcommand` at import time —
``main()`` builds its parser from the registry and never needs editing.
The shared helpers :func:`load_spec`/:func:`spec_experiments` and
:func:`emit_results`/:func:`emit_records` give every driver the same
spec-loading and output discipline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Callable, List, Optional

from .memory import estimate_memory, format_bytes
from .runner import Result, run_all
from .registry import topology_families, workload_patterns
from .specs import Experiment
from .sweep import sweep
# registers the lm_prefill/lm_decode/lm_moe bridge patterns, so specs
# naming them load from any CLI entry point
from .. import serving

__all__ = ["Subcommand", "register_subcommand", "registered_subcommands",
           "load_spec", "spec_experiments", "emit_results", "emit_records",
           "main"]


# ---------------------------------------------------------------------- #
# subcommand registry
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Subcommand:
    """One CLI driver: parser shape + handler.

    ``fn(args) -> int`` receives the parsed namespace.  The shared flags
    are opt-in so every driver exposes the same surface with the same
    semantics: ``spec`` (positional JSON path; ``spec_name`` renames it
    for non-spec positionals like ``resume``'s checkpoint dir), ``out``
    (``--out``, the full-JSON escape hatch), ``replicas`` and ``seed``
    (spec-wide overrides).  ``configure(parser)`` adds driver-specific
    flags.
    """

    name: str
    help: str
    fn: Callable[[argparse.Namespace], int]
    spec: bool = True
    spec_name: str = "spec"
    spec_help: str = "path to the JSON spec file"
    out: Optional[str] = None          # --out help text; None = no flag
    replicas: bool = False
    seed: bool = False
    configure: Optional[Callable[[argparse.ArgumentParser], None]] = None


_SUBCOMMANDS: dict = {}


def register_subcommand(cmd: Subcommand) -> None:
    """Add ``cmd`` to the ``python -m repro.api`` dispatch table.

    Like :func:`repro.api.register_topology`: re-registering the *same*
    subcommand object is a no-op (module reloads), a different object
    under a taken name raises.
    """
    existing = _SUBCOMMANDS.get(cmd.name)
    if existing is not None and existing != cmd:
        raise ValueError(f"CLI subcommand {cmd.name!r} already registered")
    _SUBCOMMANDS[cmd.name] = cmd


def registered_subcommands() -> tuple:
    return tuple(_SUBCOMMANDS)


# ---------------------------------------------------------------------- #
# shared spec loading / result emission
# ---------------------------------------------------------------------- #
def load_spec(path: str, *, key: Optional[str] = None,
              plural: Optional[str] = None) -> list:
    """Load a JSON spec file and normalize to a list of document dicts.

    Spec files follow one convention everywhere: a bare object, or a
    wrapper holding ``{key: {...}}`` / ``{plural: [...]}`` (e.g.
    ``experiments`` / ``servings`` / ``sweeps`` / ``searches``).
    ``plural`` defaults to ``key + "s"``; pass it for irregular plurals
    (``search`` -> ``searches``).  With ``key=None`` the raw parsed
    document is returned as ``[doc]``.
    """
    with open(path) as f:
        doc = json.load(f)
    if key is None:
        return [doc]
    plural = plural or key + "s"
    if isinstance(doc, dict):
        if plural in doc:
            return list(doc[plural])
        if key in doc:
            return [doc[key]]
    return [doc]


def spec_experiments(path: str, *, replicas: Optional[int] = None,
                     seed: Optional[int] = None) -> List[Experiment]:
    """Load ``{"experiments": [...]}`` (or a bare experiment object) and
    apply the shared ``--replicas``/``--seed`` overrides."""
    exps = [Experiment.from_dict(d)
            for d in load_spec(path, key="experiment")]
    if replicas is not None:
        exps = [e.override("replicas", replicas) for e in exps]
    if seed is not None:
        exps = [e.override("seed", seed) for e in exps]
    return exps


def emit_results(results: List[Result], out: Optional[str]) -> None:
    """Print one summary line per Result; ``--out`` writes full JSON."""
    for res in results:
        print(_summary(res))
    if out:
        with open(out, "w") as f:
            json.dump([r.to_dict() for r in results], f, indent=2)
        print(f"wrote {len(results)} result(s) to {out}")


def emit_records(records: List[dict], out: Optional[str],
                 label: str = "record") -> None:
    """``--out`` writer for drivers whose records are plain dicts."""
    if out:
        with open(out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"wrote {len(records)} {label}(s) to {out}")


def _summary(res: Result) -> str:
    bits = [f"{res.name}", f"metric={res.metric}"]
    if res.replica_seeds is not None:
        bits.append(f"replicas={len(res.replica_seeds)}")
    if res.offered is not None:
        bits.append(f"offered={res.offered:.3f}")
        bits.append(f"delivered={res.throughput:.3f}")
        if res.dropped:
            bits.append(f"dropped={res.dropped:g}")
    elif res.throughput is not None:
        bits.append(f"throughput={res.throughput:.3f}")
        bits.append(f"avg_hops={res.avg_hops:.2f}")
    if res.fail_drop:
        bits.append(f"fail_drop={res.fail_drop:g}")
    if res.latency is not None:
        bits.append("lat " + "/".join(f"{k}={v}" for k, v in res.latency.items()))
    if res.slots is not None:
        slots = (f"{res.slots:.1f}" if isinstance(res.slots, float)
                 else f"{res.slots}")
        bits.append(f"slots={slots}")
        bits.append(f"completed={res.completed}")
        agg = res.aggregates or {}
        if "slots" in agg:
            bits.append(f"slots_std={agg['slots']['std']:.1f}")
    return "  ".join(bits)


def _fmt_q(v) -> str:
    return "-" if v is None else f"{v:g}"


# ---------------------------------------------------------------------- #
# built-in drivers
# ---------------------------------------------------------------------- #
def _cmd_run(args) -> int:
    exps = spec_experiments(args.spec, replicas=args.replicas,
                            seed=args.seed)
    if args.ckpt_dir is not None:
        from .resume import run_resumable
        if len(exps) != 1:
            print("--ckpt-dir needs a single-experiment spec "
                  f"(got {len(exps)})", file=sys.stderr)
            return 2
        results = [run_resumable(exps[0], args.ckpt_dir,
                                 every=args.ckpt_every)]
    else:
        results = run_all(exps)
    emit_results(results, args.out)
    return 0


def _cmd_resume(args) -> int:
    from .resume import resume
    res = resume(args.ckpt_dir, every=args.ckpt_every)
    emit_results([res], args.out)
    return 0


def _cmd_sweep(args) -> int:
    doc = load_spec(args.spec)[0]
    base = Experiment.from_dict(doc["base"])
    if args.replicas is not None:
        base = base.override("replicas", args.replicas)
    if args.seed is not None:
        base = base.override("seed", args.seed)
    results = sweep(base, doc.get("axes", {}))
    emit_results(results, args.out)
    return 0


def _cmd_serve_sweep(args) -> int:
    specs = [serving.ServingSpec.from_dict(d)
             for d in load_spec(args.spec, key="serving")]
    if args.seed is not None:
        specs = [dataclasses.replace(s, seed=args.seed) for s in specs]
    records = serving.serve_sweep_many(specs)
    for rec in records:
        print(f"{rec['name']}  process={rec['spec']['process']}  "
              f"loads={len(rec['points'])}")
        for p in rec["points"]:
            print(f"  load={p['load']:g}  offered={p['offered']:.3f}  "
                  f"delivered={p['delivered']:.3f}  "
                  f"p50={_fmt_q(p.get('p50'))}  p99={_fmt_q(p.get('p99'))}  "
                  f"p999={_fmt_q(p.get('p999'))}  dropped={p['dropped']:g}")
        sat = rec["saturation"]
        print("  saturation: " + (
            f"load={sat['load']:g} (delivered/offered={sat['ratio']:.3f})"
            if sat else "none within swept loads"))
        req = rec.get("request")
        if req:
            print(f"  request: {req['model']}/{req['phase']} -> "
                  f"{req['pattern']} ranks={req['shape']['ranks']} "
                  f"packets={req['shape']['packets']} "
                  f"slots={req['slots']} completed={req['completed']}")
    emit_records(records, args.out, "SLO record")
    return 0


def _cmd_degrade(args) -> int:
    from .degrade import DegradeSpec, degrade_sweep_many
    specs = [DegradeSpec.from_dict(d)
             for d in load_spec(args.spec, key="sweep")]
    if args.seed is not None:
        specs = [dataclasses.replace(
            s, base=s.base.override("seed", args.seed)) for s in specs]
    records = degrade_sweep_many(specs)
    for rec in records:
        print(f"{rec['name']}  policy={rec['policy']}  "
              f"fail_policy={rec['fail_policy']}  links={rec['n_links']}")
        for p in rec["points"]:
            ret = ("-" if p["retention"] is None
                   else f"{p['retention']:.3f}")
            print(f"  rate={p['rate']:g}  down={p['n_links_down']}  "
                  f"delivered={p['delivered']:.3f}  retention={ret}  "
                  f"p50={_fmt_q(p.get('p50'))}  p99={_fmt_q(p.get('p99'))}  "
                  f"fail_drop={p['fail_drop']:g}")
    emit_records(records, args.out, "degradation record")
    return 0


def _cmd_estimate(args) -> int:
    exps = spec_experiments(args.spec, replicas=args.replicas)
    from .admission import (compile_ram_multiplier, host_ram_bytes,
                            predict_peak_rss)
    ram = host_ram_bytes()
    records = []
    for e in exps:
        est = estimate_memory(e)
        mult = compile_ram_multiplier(e.network.family)
        predicted = predict_peak_rss(est["total_bytes"], mult)
        est["compile_ram_multiplier"] = mult
        est["predicted_peak_rss_bytes"] = predicted
        records.append({"name": e.label(), **est})
        dims = est["dims"]
        over = (ram is not None and predicted > ram)
        print(f"{e.label()}  S={dims['n_endpoints']}  "
              f"masks={est['tables']['mask_layout']}  "
              f"tables={format_bytes(est['tables']['device_mask_bytes'] + est['tables']['dist_leaf_bytes'])}  "
              f"state/replica={format_bytes(est['state_bytes_per_replica'])}  "
              f"total={format_bytes(est['total_bytes'])}  "
              f"peak={format_bytes(est['peak_bytes'])}  "
              f"predicted_rss={format_bytes(predicted)} "
              f"(x{mult:.1f} compile)"
              + (f"  ** OVER host RAM {format_bytes(ram)} — admission "
                 "would refuse or downgrade **" if over else ""))
    if ram is not None:
        print(f"host RAM: {format_bytes(ram)}")
    emit_records(records, args.out, "estimate")
    return 0


def _cmd_families(_args) -> int:
    for name in topology_families():
        print(name)
    return 0


def _cmd_patterns(_args) -> int:
    for name, kind in workload_patterns():
        print(f"{name}  [{kind}]")
    print("(* = compiles to a device-resident workload program; "
          "supports schedule=barrier|window)")
    return 0


def _run_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--ckpt-dir", default=None,
                   help="checkpoint directory: run resumably, snapshotting "
                        "engine state at segment boundaries "
                        "(single-experiment specs only)")
    p.add_argument("--ckpt-every", type=int, default=64,
                   help="segment length between checkpoints, in engine "
                        "chunks (completion) or slots (windowed metrics); "
                        "default 64")


def _resume_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--ckpt-every", type=int, default=64,
                   help="segment length for the continued run")


register_subcommand(Subcommand(
    "run", "run experiment spec(s) from JSON", _cmd_run,
    spec_help="path to the experiment JSON file",
    out="write full Result JSON records here",
    replicas=True, seed=True, configure=_run_flags))
register_subcommand(Subcommand(
    "resume", "resume a --ckpt-dir run from its latest snapshot",
    _cmd_resume, spec_name="ckpt_dir",
    spec_help="checkpoint directory of the run",
    out="write the full Result JSON here", configure=_resume_flags))
register_subcommand(Subcommand(
    "sweep", "run a {base, axes} sweep spec", _cmd_sweep,
    spec_help="path to the sweep JSON file",
    out="write full Result JSON records here", replicas=True, seed=True))
register_subcommand(Subcommand(
    "serve-sweep", "run open-loop serving SLO sweep spec(s)",
    _cmd_serve_sweep, spec_help="path to the ServingSpec JSON file",
    out="write full SLO JSON records here", seed=True))
register_subcommand(Subcommand(
    "degrade", "run a link-failure degradation sweep spec", _cmd_degrade,
    spec_help="path to the DegradeSpec JSON file",
    out="write full degradation records here", seed=True))
register_subcommand(Subcommand(
    "estimate", "estimate memory for experiment spec(s), no run",
    _cmd_estimate, spec_help="path to the experiment JSON file",
    out="write full estimate JSON records here", replicas=True))
register_subcommand(Subcommand(
    "families", "list topology families", _cmd_families, spec=False))
register_subcommand(Subcommand(
    "patterns", "list workload patterns (shared registry)", _cmd_patterns,
    spec=False))


# ---------------------------------------------------------------------- #
# dispatch
# ---------------------------------------------------------------------- #
def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.api",
                                     description=__doc__.splitlines()[0])
    sub = parser.add_subparsers(dest="command", required=True)
    for cmd in _SUBCOMMANDS.values():
        p = sub.add_parser(cmd.name, help=cmd.help)
        if cmd.spec:
            p.add_argument(cmd.spec_name, help=cmd.spec_help)
        if cmd.out is not None:
            p.add_argument("--out", help=cmd.out)
        if cmd.replicas:
            p.add_argument("--replicas", type=int, default=None,
                           help="override replicas (>= 1): one vmapped "
                                "batched run over R seeds per experiment")
        if cmd.seed:
            p.add_argument("--seed", type=int, default=None,
                           help="override the spec's base seed")
        if cmd.configure is not None:
            cmd.configure(p)
        p.set_defaults(fn=cmd.fn)
    args = parser.parse_args(argv)
    return args.fn(args)


# the search driver registers its own subcommand on import (the registry
# is populated above, so this import must stay below the definitions)
from .. import search as _search  # noqa: E402,F401  (registration side effect)


if __name__ == "__main__":
    sys.exit(main())
