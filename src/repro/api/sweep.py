"""Batched cartesian sweeps over experiment axes.

``sweep(base, axes)`` expands ``axes`` — a mapping of dotted spec paths
(``workload.load``, ``route.policy``, ``seed``, ``network.params.u``, ...)
to value lists — into the full grid, runs every point through
:func:`repro.api.run`, and returns one :class:`Result` per point in
row-major order (last axis fastest).  Grid points that share a
``(network, route)`` pair reuse the same compiled simulator via
:class:`SimulatorCache`; axes are ordered so fabric-changing axes vary
slowest (maximizing reuse runs between rebuilds) and a ``seed`` axis
varies fastest (so :func:`repro.api.run_all` can fold each seed-only
stretch into one vmapped batched run).
"""
from __future__ import annotations

import itertools
from typing import Mapping, Optional, Sequence

from .runner import SimulatorCache, run_all
from .specs import Experiment

__all__ = ["expand_axes", "sweep"]

# axes that force a new compiled simulator — keep them outermost
_FABRIC_PREFIXES = ("network.", "route.")


def _axis_order(axes: Mapping[str, Sequence]) -> list:
    names = list(axes)
    fabric = sorted(n for n in names if n.startswith(_FABRIC_PREFIXES))
    rest = [n for n in names
            if not n.startswith(_FABRIC_PREFIXES) and n != "seed"]
    # seed varies fastest so consecutive grid points differ only in seed and
    # run_all can fold them into one vmapped batched run
    tail = ["seed"] if "seed" in names else []
    return fabric + rest + tail


def expand_axes(base: Experiment, axes: Mapping[str, Sequence]) -> list:
    """The experiment grid, fabric axes outermost, insertion order inside."""
    if not axes:
        return [base]
    order = _axis_order(axes)
    grid = []
    for values in itertools.product(*(axes[name] for name in order)):
        exp = base
        for name, value in zip(order, values):
            exp = exp.override(name, value)
        if base.name and "name" not in axes:
            # re-label: inheriting the base name verbatim would stamp every
            # grid point with the base's (now wrong) policy/load label
            coords = ", ".join(f"{n}={v}" for n, v in zip(order, values))
            exp = exp.override("name", f"{base.name}[{coords}]")
        grid.append(exp)
    return grid


def sweep(base: Experiment, axes: Mapping[str, Sequence], *,
          cache: Optional[SimulatorCache] = None,
          fold_seeds: bool = True) -> list:
    """Run the cartesian grid; returns ``[Result]``, one per grid point.

    With a private cache (none passed in), each fabric's simulator is
    evicted right after its last grid point — fabric axes vary slowest, so
    at most one compiled simulator is live at a time.

    A trailing seed-only stretch of the grid (e.g. a ``"seed"`` axis, which
    always varies fastest) is folded into one ``jax.vmap``-batched run per
    surrounding grid point (``fold_seeds=False`` restores one scalar run
    per point); either way the returned Results are per-point and
    bitwise-identical.
    """
    return run_all(expand_axes(base, axes), cache=cache,
                   fold_seeds=fold_seeds)
