"""NetworkSpec-level memory estimator: *will this experiment fit?*

``estimate_memory(network, route, replicas=R)`` prices an experiment
before any device array is allocated: routing-table bytes, per-replica
simulator state, engine constants, and the step's transient peak, plus
the resolved mask layout (dense vs blocked — see
:func:`repro.core.build_tables`).  It builds the *topology* (cheap, host
numpy) but never the tables or the simulator, so pricing the paper's
104976-endpoint fabrics takes seconds and a few hundred MB, not the
gigabytes the real run needs.

The estimate mirrors the allocation formulas in
``repro.simulator.engine`` — the sizes are exact for the state and table
arrays (same shapes, same dtypes) and a documented upper bound for the
jit-internal transients.  It prices *resident simulation data* only:
XLA's compile-time memory (HLO optimization of the step executables,
which dominated measured RSS ~10x at the 50k scale point) is deliberately
out of scope.  ``benchmarks/bench_scale.py`` records measured peak RSS
next to these estimates so that gap stays visible at every scale point.
"""
from __future__ import annotations

from typing import Union

from ..core import routing as _routing
from ..core.routing import mask_table_bytes
from .registry import build_network
from .specs import Experiment, NetworkSpec, RouteSpec

__all__ = ["estimate_memory", "format_bytes"]


def format_bytes(n: Union[int, float]) -> str:
    """Human-readable bytes (binary units)."""
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if n < 1024 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} TiB"  # pragma: no cover - loop always returns


def estimate_memory(network: Union[NetworkSpec, Experiment],
                    route: RouteSpec = RouteSpec(), *,
                    replicas: int = 1) -> dict:
    """Byte-level memory estimate for a fabric + routing configuration.

    ``network`` is a :class:`NetworkSpec` (with ``route``/``replicas``
    given explicitly) or a whole :class:`Experiment` (its route and
    replica count are used).  Returns a dict with exact dims, a
    per-category byte breakdown, and ``total_bytes`` — the expected
    resident footprint of one live simulator plus ``replicas`` stacked
    states; ``peak_bytes`` adds the step-transient upper bound.
    """
    if isinstance(network, Experiment):
        route = network.route
        replicas = network.replicas
        network = network.network
    topo = build_network(network)

    n = topo.n_switches
    p = topo.max_ports
    n1 = topo.n_leaves
    s = topo.n_endpoints
    d = topo.endpoints_per_leaf
    v, q, oq, qe = route.vcs, route.queue_depth, route.out_queue, \
        route.endpoint_queue
    nq = n * p * v
    w = (p + 31) // 32
    nr = n * p + s
    r_max = p + d
    # the engine's pool default (SimConfig.pool or auto)
    pool = route.pool or int(min(2_000_000, max(1 << 14, s * 6)))

    # ---- routing tables (device-resident) ---------------------------- #
    one_mask = mask_table_bytes(n1, n, p)
    n_masks = 2 if route.policy in ("polarized", "degraded") else 1
    dist_bytes = n1 * n * 2                           # int16
    # read the limit off the module so it tracks build_tables' "auto"
    # resolution exactly (including test-time overrides)
    mask_layout = ("dense" if one_mask <= _routing.DENSE_MASK_LIMIT
                   else "blocked")
    # dense layout also retains the numpy twins on the host (both masks,
    # regardless of policy); blocked streams them and retains nothing
    host_mask_bytes = 2 * one_mask if mask_layout == "dense" else 0
    tables = {
        "dist_leaf_bytes": dist_bytes,
        "device_mask_bytes": n_masks * one_mask,
        "host_mask_bytes": host_mask_bytes,
        "mask_layout": mask_layout,
    }

    # ---- engine constants (per simulator, replica-invariant) --------- #
    constants = (
        4 * n * p * 4          # nbrs, nbr_port, nbrs0, valid_port(word-ish)
        + n * v * p * 4        # _dq_perm
        + nr * 4 * 2           # cur, _row_of
        + n * r_max * 5        # _dense_src (int32) + _dense_valid (bool)
        + n * p * 4            # _rev_idx
        + (s * p * 4 if route.policy == "ugal" else 0)   # _ugal_occ_idx
    )

    # ---- mutable state (per replica) --------------------------------- #
    state = (
        nq * q * 4 + nq * 8            # qbuf + qhead/qlen
        + nq * oq * 4 + nq * 8         # oq_buf + oq_head/oq_len
        + s * qe * 4 + s * 8           # eq_buf + eq_head/eq_len
        + pool * 4 * 4                 # fl_buf, p_sd, p_mid, p_bh
        + s * 4 * 3                    # msg_rem, msg_dst, prog
        + route.hist_bins * 4          # lat_hist
    )

    # ---- failure-schedule state (per replica, armed schedules only) --- #
    # with a non-empty FailureSchedule the engine moves the routing
    # tables INTO the state (tbl_min[/tbl_away] + tbl_dist) so
    # update_tables can rewrite them without recompiling, and adds the
    # live up-masks (link_up [N*P] bool, switch_up [N] bool) plus the
    # fail_drop counter
    has_failures = (network.failures is not None
                    and len(network.failures) > 0)
    failure_state = (n_masks * one_mask + dist_bytes   # tbl_min/away/dist
                     + n * p + n                       # link_up, switch_up
                     + 4) if has_failures else 0       # fail_drop
    state += failure_state

    # ---- step transients (jit-internal upper bound) ------------------ #
    # dominated by the [NR, P] f32 score/tie/occ planes (a handful are
    # live at once) and the [N, R_max, P] one-hot of the segmented
    # arbitration max
    transient = 6 * nr * p * 4 + n * r_max * p
    if has_failures:
        # host-side delta rebuild scratch: _pack_mask_block packs
        # affected leaf rows in leaf_block chunks (min+away words live
        # at once while repacking)
        transient += 2 * min(256, n1) * n * w * 4

    total = (tables["dist_leaf_bytes"] + tables["device_mask_bytes"]
             + tables["host_mask_bytes"] + constants + replicas * state)
    return {
        "network": network.to_dict(),
        "policy": route.policy,
        "replicas": replicas,
        "dims": {"n_switches": n, "n_leaves": n1, "n_endpoints": s,
                 "max_ports": p, "mask_words": w, "pool": pool,
                 "n_queues": nq, "n_requesters": nr},
        "tables": tables,
        "failures": {"armed": has_failures,
                     "state_bytes_per_replica": failure_state},
        "constants_bytes": constants,
        "state_bytes_per_replica": state,
        "transient_bytes": transient,
        "total_bytes": total,
        "peak_bytes": total + replicas * transient,
    }
