"""Sharded AdamW with global-norm clipping and schedules.

Optimizer state (m, v) mirrors the parameter ParamSpec tree — same logical
sharding axes, so state is ZeRO-sharded with the params.  ``state_dtype``
selects f32 (default) or bf16 moments; at 671B scale bf16 moments are what
lets params+grads+state fit 16 GB/chip v5e (see DESIGN.md §6 and
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models.common import ParamSpec, abstract_params, is_spec

__all__ = ["AdamWConfig", "opt_specs", "init_opt", "adamw_update",
           "warmup_cosine", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"
    schedule: Optional[Callable] = None     # step -> lr multiplier


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return f


def opt_specs(param_specs, cfg: AdamWConfig):
    """ParamSpec tree for (m, v) — same shapes/axes, state dtype, zeros."""
    def conv(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, cfg.state_dtype, "zeros")
    tree = jax.tree.map(conv, param_specs, is_leaf=is_spec)
    return {"m": tree, "v": tree, "step": ParamSpec((), (), "int32", "zeros")}


def init_opt(param_specs, cfg: AdamWConfig, sh=None):
    from ..models.common import init_params
    return init_params(opt_specs(param_specs, cfg), jax.random.PRNGKey(0), sh)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule else 1.0)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:                       # no decay on norms/biases
            delta = delta + cfg.weight_decay * pf
        return ((pf - lr * delta).astype(p.dtype),
                mf.astype(sd), vf.astype(sd))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree.unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree.unflatten(tdef, [n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
