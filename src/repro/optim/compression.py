"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

The pod axis of the production mesh crosses the datacenter fabric the paper
models (MRLS).  Even with the MRLS All2All advantage, DP gradient sync
across pods is bandwidth-precious, so the framework offers EF-int8: each
step sends int8-quantized gradients (4x fewer bytes than f32, 2x fewer than
bf16) and carries the quantization error forward (error feedback keeps the
method unbiased over time — Karimireddy et al., 2019).

``compress`` / ``decompress`` are pure and jit-safe; ``compressed_psum``
shows the shard_map pattern for applying them around a pod-axis psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from .. import _jax_compat  # noqa: F401 — polyfills jax.shard_map



def compress(g, ef):
    """g: f32/bf16 tensor; ef: error-feedback buffer (same shape, f32).
    Returns (q int8, scale f32 scalar, new_ef)."""
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_ef = gf - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def decompress(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, ef_tree):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_tree)
    qs, scales, efs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        qs.append(q); scales.append(s); efs.append(ne)
    return (jax.tree.unflatten(tdef, qs),
            jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, efs))


def decompress_tree(qs, scales, like):
    return jax.tree.map(
        lambda q, s, l: decompress(q, s, l.dtype), qs, scales, like)


def compressed_psum(x, ef, mesh, axis: str = "pod"):
    """EF-int8 all-reduce over ``axis``: quantize locally, all-gather int8
    (the wire format), sum in f32.  Bytes on the DCN: 1 per element instead
    of 4."""
    def inner(xl, el):
        q, s, ne = compress(xl, el)
        qg = jax.lax.all_gather(q, axis)                 # int8 on the wire
        sg = jax.lax.all_gather(s, axis)
        total = jnp.tensordot(sg, qg.astype(jnp.float32), axes=((0,), (0,)))
        return total.astype(xl.dtype), ne

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False,
    )(x, ef)
