"""Fabric planner: the paper's contribution applied to THIS framework.

On a multi-pod system the ``pod`` mesh axis crosses the datacenter (DCN)
fabric — exactly the extreme-scale leaf-spine network the paper studies.
The planner:

1. models candidate DCN fabrics with the paper's machinery
   (``repro.core``): MRLS at a chosen thickness f, Fat-Tree, Dragonfly;
2. takes the *measured* cross-pod collective byte volumes from a dry-run
   record (``repro.launch.dryrun`` JSON);
3. estimates per-step cross-pod communication time on each fabric from the
   capacity limit Θ (Eq. 1) and per-pattern efficiency factors calibrated
   with the packet simulator (All2All-class traffic: MRLS ≈ 1.5x FT
   throughput at 100K endpoints, ≈ 2x DF — Section 6);
4. recommends the pod-axis strategy (plain DP sync vs EF-int8 compressed
   sync — ``repro.optim.compression``) and reports the fabric ranking.

This is deliberately a *model*, not a simulation of every step: the
simulator calibrates pattern efficiencies once, the planner applies them to
arbitrary byte volumes (the same separation the paper draws between Θ and
simulated L).
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Mapping, Optional, Union

from ..core import analytics, topology
from ..core.routing import build_tables

# pattern efficiency = achieved fraction of min(1, Θ) under the pattern.
# DEFAULT_PATTERN_EFF is the hand-estimated fallback (benchmarks/fig5/6/7;
# see EXPERIMENTS.md §Repro); the *live* table below it is recalibrated
# from the committed design-space-search artifact
# (benchmarks/CALIB_pattern_eff.json, produced by
# scripts/calibrate_planner.py from artifacts/PARETO_search.json) —
# families/patterns the search did not measure keep the fallback value.
# all2all ~ uniform; allreduce (ring/halving over nearby ranks) is
# locality-friendly, which favors FT.
DEFAULT_PATTERN_EFF = {
    "mrls": {"all2all": 0.85, "allreduce": 0.75, "uniform": 0.85},
    "fat_tree": {"all2all": 0.60, "allreduce": 0.90, "uniform": 0.90},
    "dragonfly": {"all2all": 0.45, "allreduce": 0.75, "uniform": 0.75},
}

CALIB_PATH = Path(__file__).resolve().parents[3] / "benchmarks" \
    / "CALIB_pattern_eff.json"

# workload patterns (repro.workloads vocabulary) -> planner traffic class
_PATTERN_CLASS = {"uniform": "uniform", "all2all": "all2all",
                  "allreduce": "allreduce"}


def pattern_eff_from_search(records: Union[Mapping, list]) -> dict:
    """Distill ``eff[family][pattern]`` from search artifact record(s).

    ``records`` is a ``PARETO_search.json`` document: one search record,
    ``{"searches": [...]}``, or a list of records.  For every fully
    evaluated candidate, the achieved efficiency is measured throughput
    over the analytic ceiling ``min(1, Θ)``; per (family, pattern) the
    *best* candidate wins — the planner models the fabric one would
    actually deploy, not the average draw.
    """
    if isinstance(records, Mapping):
        records = records.get("searches", [records])
    eff: dict = {}
    for rec in records:
        pattern = _PATTERN_CLASS.get(
            rec.get("spec", {}).get("workload", {}).get("pattern"))
        if pattern is None:
            continue
        for cand in rec.get("candidates", ()):
            if cand.get("status") != "full":
                continue
            ceiling = min(1.0, cand["theta"])
            if ceiling <= 0:
                continue
            e = min(1.0, cand["throughput"] / ceiling)
            fam = eff.setdefault(cand["family"], {})
            fam[pattern] = max(fam.get(pattern, 0.0), e)
    return eff


def load_pattern_eff(path: Union[None, str, Path] = None) -> dict:
    """The live efficiency table: defaults overlaid with the committed
    calibration artifact (missing/unreadable file -> pure defaults)."""
    path = CALIB_PATH if path is None else Path(path)
    table = {fam: dict(pats) for fam, pats in DEFAULT_PATTERN_EFF.items()}
    try:
        with open(path) as f:
            calib = json.load(f)
    except (OSError, ValueError):
        return table
    for fam, pats in calib.get("eff", {}).items():
        for pattern, e in pats.items():
            table.setdefault(fam, {})[pattern] = float(e)
    return table


PATTERN_EFF = load_pattern_eff()


@dataclasses.dataclass
class FabricSpec:
    name: str               # mrls | fat_tree | dragonfly
    theta: float            # capacity limit (Eq. 1)
    cost_links: float       # links per endpoint (Eq. 2)
    link_gbps: float = 400.0


def build_fabric(kind: str, n_endpoints: int, radix: int = 64,
                 f: float = 2.0, link_gbps: float = 400.0) -> FabricSpec:
    """Instantiate a fabric model at ``n_endpoints`` NICs (pods x hosts)."""
    if kind == "mrls":
        n1, n2, u, d = analytics.mrls_design(n_endpoints, radix, f)
        A = analytics.mrls_expected_A(n1, n2, u, radix)
        theta = analytics.theta(u * n1, n1 * d, A)
        return FabricSpec("mrls", theta, u / d, link_gbps)
    if kind == "fat_tree":
        # non-blocking FT sized for n_endpoints (h levels as needed)
        k = radix // 2
        h = max(1, math.ceil(math.log(n_endpoints / (2 * k), k)))
        return FabricSpec("fat_tree", 1.0, float(h), link_gbps)
    if kind == "dragonfly":
        return FabricSpec("dragonfly", 1.0, 1.5, link_gbps)
    raise ValueError(kind)


def collective_time_s(fabric: FabricSpec, pattern: str,
                      bytes_per_endpoint: float) -> float:
    """Time to move ``bytes_per_endpoint`` under ``pattern``.

    endpoint injection rate = link_gbps; the fabric sustains
    eff * min(1, Θ) of it under the pattern.
    """
    eff = PATTERN_EFF[fabric.name][pattern]
    rate = fabric.link_gbps * 1e9 / 8 * eff * min(1.0, fabric.theta)
    return bytes_per_endpoint / rate


@dataclasses.dataclass
class PodAxisPlan:
    fabric_ranking: list          # [(name, step_comm_s, cost_links)]
    recommended_fabric: str
    compress_gradients: bool
    est_comm_s: dict


def plan_pod_axis(dryrun_record: dict, n_pod_endpoints: int = 512,
                  compute_s: Optional[float] = None,
                  link_gbps: float = 400.0) -> PodAxisPlan:
    """Given a dry-run JSON record, rank fabrics for its cross-pod traffic.

    Cross-pod traffic classes: the all-to-all bytes (MoE expert parallel)
    follow the All2All pattern; all-reduce/reduce-scatter bytes (DP/FSDP
    sync) follow the Allreduce pattern.
    """
    coll = dryrun_record["per_device"]["collective_bytes"]
    a2a = coll.get("all-to-all", 0.0)
    ar = (coll.get("all-reduce", 0.0) + coll.get("reduce-scatter", 0.0)
          + coll.get("all-gather", 0.0))
    ranking = []
    est = {}
    for kind in ("mrls", "fat_tree", "dragonfly"):
        fab = build_fabric(kind, n_pod_endpoints, link_gbps=link_gbps)
        t = (collective_time_s(fab, "all2all", a2a)
             + collective_time_s(fab, "allreduce", ar))
        ranking.append((kind, t, fab.cost_links))
        est[kind] = t
    ranking.sort(key=lambda x: x[1])
    best = ranking[0][0]
    # compress when cross-pod comm would not hide behind compute
    compress = compute_s is not None and est[best] > 0.5 * compute_s
    return PodAxisPlan(ranking, best, compress, est)
