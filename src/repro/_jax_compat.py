"""Version-compatibility polyfills for older installed jax (< 0.5).

This codebase targets the modern surface (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``); the container pins jax
0.4.37.  Importing this module backfills the missing attributes with
behavior-equivalent fallbacks — gated on absence, so on a current jax it
is a no-op.  Import it before touching those APIs (``repro.launch.mesh``,
``repro.models.moe`` and ``repro.optim.compression`` all do).
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # noqa: F401  (re-exported)
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

if not hasattr(jax, "set_mesh"):  # pragma: no cover - depends on jax
    # ``with jax.set_mesh(mesh):`` fallback: Mesh is itself a context
    # manager with the semantics this codebase relies on (named axes
    # visible to with_sharding_constraint / shard_map inside the block).
    jax.set_mesh = lambda mesh: mesh

if not hasattr(jax, "shard_map"):  # pragma: no cover - depends on jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None):
        kw = {} if check_vma is None else {"check_rep": check_vma}
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    jax.shard_map = _shard_map_compat
