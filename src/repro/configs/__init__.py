"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus shape cells.

Also provides ``reduced(cfg)`` — a small same-family config for CPU smoke
tests (few layers, narrow width, tiny vocab, few experts), exercised by
``tests/test_archs.py``; the FULL configs are only lowered via the dry-run.
"""
from __future__ import annotations

import dataclasses

from .base import SHAPES, ShapeCell, supports
from ..models.model import ModelConfig
from ..models.moe import MoECfg

from . import (command_r_plus_104b, deepseek_v3_671b, falcon_mamba_7b,
               hymba_1_5b, llama_3_2_vision_90b, nemotron_4_15b,
               qwen3_1_7b, qwen3_moe_235b_a22b, seamless_m4t_medium,
               starcoder2_15b)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (nemotron_4_15b, qwen3_1_7b, starcoder2_15b,
              command_r_plus_104b, hymba_1_5b, qwen3_moe_235b_a22b,
              deepseek_v3_671b, llama_3_2_vision_90b, seamless_m4t_medium,
              falcon_mamba_7b)
}

ARCHS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=4 if not cfg.hybrid else 4,
        d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512,
        dense_d_ff=256,
        q_block=64, kv_block=64, ssm_chunk=16,
        n_ctx_tokens=16 if cfg.n_ctx_tokens else 0,
        enc_layers=2 if cfg.enc_dec else 0,
        sliding_window=32 if cfg.sliding_window else None,
        full_attn_layers=(0, 3) if cfg.full_attn_layers else (),
        cross_every=cfg.cross_every and 2,
        dense_layers=min(cfg.dense_layers, 1),
    )
    if cfg.hybrid:
        kw.update(n_heads=5, n_kv_heads=1, head_dim=16, tp_heads=False)
    if cfg.moe is not None:
        kw["moe"] = MoECfg(n_experts=8, top_k=2,
                           d_expert=64, n_shared=cfg.moe.n_shared,
                           router_scale_bias=cfg.moe.router_scale_bias)
    if cfg.mla is not None:
        from ..models.model import MLACfg
        kw["mla"] = MLACfg(q_lora=64, kv_lora=32, nope_dim=32, rope_dim=16,
                           v_dim=32)
        kw.update(n_heads=4, n_kv_heads=4, head_dim=32)
    if cfg.family == "vlm":
        kw["n_layers"] = 4          # 2 super-blocks of (1 self + 1 cross)
    return dataclasses.replace(cfg, **kw)


__all__ = ["REGISTRY", "ARCHS", "get_config", "reduced", "SHAPES",
           "ShapeCell", "supports"]
