"""Shape cells shared by all LM architectures (assigned-architecture pool).

* ``train_4k``    — training step, seq 4096, global batch 256.
* ``prefill_32k`` — inference prefill, seq 32768, global batch 32.
* ``decode_32k``  — one-token decode with a 32K cache, global batch 128.
* ``long_500k``   — one-token decode with a 524288 context, batch 1;
                    only for sub-quadratic archs (SSM / hybrid) — full
                    attention archs skip it (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def supports(cfg, shape_name: str) -> tuple[bool, str]:
    """Whether an arch runs a shape cell (False -> documented skip)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500K dense-KV decode has no sub-quadratic path"
    return True, ""
