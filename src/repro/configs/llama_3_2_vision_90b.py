"""llama-3.2-vision-90b [vlm]: 100L d8192 64H (GQA kv=8) ff28672 vocab 128256.
20 super-blocks of (4 self-attn + 1 gated cross-attn to vision tokens)
[hf:meta-llama/Llama-3.2-11B-Vision].  The vision frontend is a stub:
input_specs() provides 1600 precomputed patch embeddings."""
from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, act="swiglu", rope_theta=500_000.0,
    cross_every=5, n_ctx_tokens=1600,
)
