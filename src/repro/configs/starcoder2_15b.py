"""starcoder2-15b [dense]: 40L d6144 48H (GQA kv=4) ff24576 vocab 49152.
GQA + RoPE + (non-gated) GELU MLP [arXiv:2402.19173]."""
from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152, act="gelu", rope_theta=100_000.0,
)
