"""deepseek-v3-671b [moe]: 61L d7168 128H MLA, 1 shared + 256 routed top-8
experts (ff2048), vocab 129280 [arXiv:2412.19437].  First 3 layers dense
(ff 18432); aux-loss-free router bias; MTP head omitted (documented)."""
from ..models.model import ModelConfig, MLACfg
from ..models.moe import MoECfg

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab=129280, act="swiglu", rope_theta=10_000.0,
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               router_scale_bias=True),
    dense_layers=3, dense_d_ff=18432,
    mla=MLACfg(q_lora=1536, kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
)
