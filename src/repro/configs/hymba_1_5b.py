"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) ff5504 vocab 32001, ssm_state=16.
Parallel attention + Mamba heads per layer [arXiv:2411.13676]; sliding-window
attention everywhere except 3 full-attention layers (first/middle/last), so
the arch is sub-quadratic and runs the long_500k cell.  25 heads are not
TP-divisible -> TP shards head_dim (tp_heads=False)."""
from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, act="swiglu", rope_theta=10_000.0,
    tp_heads=False,
    ssm_state=16, ssm_conv=4, ssm_expand=2, hybrid=True,
    full_attn_layers=(0, 15, 31), sliding_window=2048,
)
