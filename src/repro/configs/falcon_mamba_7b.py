"""falcon-mamba-7b [ssm]: 64L d4096, attention-free Mamba-1, ssm_state=16,
vocab 65024 [arXiv:2410.05355].  Pure mamba mixer blocks (d_ff=0); O(1)
decode state -> runs the long_500k cell."""
from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=65024, act="silu", rope_theta=0.0,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)
