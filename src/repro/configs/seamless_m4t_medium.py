"""seamless-m4t-medium [audio]: enc-dec, 12+12L d1024 16H ff4096
vocab 256206 [arXiv:2308.11596].  The speech frontend is a stub:
input_specs() provides 1024 precomputed frame embeddings; backbone is the
text decoder cross-attending the speech encoder (RMSNorm + ReLU FFN)."""
from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206, act="relu", rope_theta=10_000.0,
    enc_dec=True, enc_layers=12, n_ctx_tokens=1024,
)
