"""Routing for randomly-wired indirect networks (Section 4.3 of the paper).

Host-side (numpy): BFS distance tables and a reference step-by-step router
used by tests and analytics.  Device-side (jnp): vectorized Polarized port
scoring used by the cycle-level simulator.

Polarized routing (Camarero et al. [28], adapted to indirect networks here):
every candidate next-hop link is classified by the tuple
``(d(n,s)-d(c,s), d(n,t)-d(c,t))`` into Forward(+1,-1) / Expansion(+1,+1) /
Contraction(-1,-1) / Backtrack(-1,+1).  Forward is always allowed; Expansion
only while ``d(c,s) < d(c,t)``; Contraction only once ``d(c,s) >= d(c,t)``;
Backtrack never.  Theorem 4.2 bounds route length by ``2 D* - 2``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .topology import Topology

__all__ = [
    "bfs_distances",
    "RoutingTables",
    "build_tables",
    "pack_port_masks",
    "iter_port_mask_blocks",
    "mask_table_bytes",
    "polarized_port_mask",
    "route_packet_host",
    "POLICIES",
    "MASK_LAYOUTS",
    "DENSE_MASK_LIMIT",
]

POLICIES = ("polarized", "minimal_adaptive", "ksp", "ugal", "valiant")

MASK_LAYOUTS = ("auto", "dense", "blocked")

# ``masks="auto"`` switches to the blocked (streamed) layout once one dense
# numpy mask table would exceed this many bytes — small fabrics keep the
# dense arrays around for host-side tooling, paper-scale fabrics never
# materialize them.
DENSE_MASK_LIMIT = 256 * 1024 * 1024


# ---------------------------------------------------------------------- #
# distances
# ---------------------------------------------------------------------- #
def bfs_distances(topo: Topology, sources: np.ndarray) -> np.ndarray:
    """[len(sources), N] int16 hop distances (-1 = unreachable).

    Per-source frontier BFS with vectorized neighbor expansion; fast enough
    for the paper's 100K-endpoint networks (~6K sources x ~9K switches).
    The TPU-resident alternative is tropical matrix powering — see
    ``repro.kernels.minplus`` (the Pallas hot-spot kernel).
    """
    nbrs = topo.nbrs
    n = topo.n_switches
    sources = np.asarray(sources)
    out = np.full((len(sources), n), -1, np.int16)
    for row, s in enumerate(sources):
        dist = out[row]
        visited = np.zeros(n, bool)
        frontier = np.asarray([s], dtype=np.int64)
        visited[s] = True
        d = 0
        while frontier.size:
            dist[frontier] = d
            cand = nbrs[frontier].ravel()
            cand = cand[cand >= 0]
            cand = np.unique(cand)
            frontier = cand[~visited[cand]]
            visited[frontier] = True
            d += 1
    return out


@dataclasses.dataclass
class RoutingTables:
    """Precomputed routing state shared by host router and simulator.

    ``dist_leaf`` stays int16 end to end (distances are tiny; the simulator
    gathers these rows on every crossbar sub-round, so half-width halves the
    memory traffic).  ``min_mask`` is the compact per-(target-leaf, switch)
    minimal-port bitmask: bit ``p`` of word ``min_mask[t, c, p // 32]`` is
    set iff port ``p`` of switch ``c`` leads one hop closer to leaf ``t``
    (``nbrs[c, p] >= 0 and dist_leaf[t, nbrs[c, p]] == dist_leaf[t, c] - 1``).
    Minimal policies (``minimal_adaptive``/``ksp``/``ugal``/``valiant``) test
    these bits instead of gathering whole ``[P]`` distance rows per packet.

    Two mask layouts exist (``mask_layout``):

    * ``"dense"``   — ``min_mask``/``away_mask`` hold the full
      ``[N1, N, W]`` uint32 arrays (small fabrics; host-side tooling).
    * ``"blocked"`` — the dense arrays are **never materialized**
      (``min_mask is None``); consumers stream ``leaf_block``-row leaf
      blocks through :meth:`mask_blocks` instead.  Peak host memory for
      the mask tables drops from ``2 * N1 * N * W * 4`` retained bytes to
      two transient ``leaf_block * N * W * 4``-byte blocks, which is what
      makes the paper's 100K-endpoint fabrics buildable on ordinary hosts
      (the simulator streams the blocks straight into its device tables).

    Either way the *values* are identical word for word — the blocked
    layout is a streaming order, not a different encoding — so simulator
    results are bitwise independent of the layout.
    """

    topo: Topology
    dist_leaf: np.ndarray          # [N1, N] int16 distances from each leaf
    leaf_rank: np.ndarray          # [N] rank among leaves or -1
    dist_full: Optional[np.ndarray] = None   # [N, N] (small nets / direct nets)
    min_mask: Optional[np.ndarray] = None    # [N1, N, W] uint32 toward-bits
    away_mask: Optional[np.ndarray] = None   # [N1, N, W] uint32 away-bits
    mask_layout: str = "dense"     # "dense" | "blocked"
    leaf_block: int = 256          # block height of the blocked layout

    @property
    def diameter_leaf(self) -> int:
        leaves = self.topo.leaf_ids
        return int(self.dist_leaf[:, leaves].max())

    @property
    def diameter_star(self) -> int:
        if self.dist_full is not None:
            return int(self.dist_full.max())
        return int(self.dist_leaf.max())       # max over (leaf, any-switch)

    @property
    def avg_distance_leaf(self) -> float:
        leaves = self.topo.leaf_ids
        d = self.dist_leaf[:, leaves].astype(np.float64)
        n1 = len(leaves)
        return float(d.sum() / (n1 * (n1 - 1)))

    def mask_blocks(self, block: Optional[int] = None):
        """Yield ``(lo, hi, min_block, away_block)`` leaf blocks.

        The one consumer-facing view of the port masks that works for both
        layouts: dense tables are sliced, blocked tables are computed on
        the fly from ``dist_leaf`` (one transient ``[block, N, W]`` pair at
        a time, never the dense array).  Blocks tile ``[0, N1)`` in order.
        """
        block = block or self.leaf_block
        if self.min_mask is not None and self.away_mask is not None:
            n1 = self.min_mask.shape[0]
            for lo in range(0, n1, block):
                hi = min(lo + block, n1)
                yield lo, hi, self.min_mask[lo:hi], self.away_mask[lo:hi]
            return
        yield from iter_port_mask_blocks(self.dist_leaf, self.topo.nbrs,
                                         block)


def _pack_mask_block(dist_block: np.ndarray, nbrs: np.ndarray,
                     valid: np.ndarray, nbr_safe: np.ndarray):
    """One ``(min, away)`` uint32 block [B, N, W] for a leaf slice.

    The single bit-packing implementation shared by the dense and blocked
    layouts — the layouts cannot drift apart because there is nothing to
    drift between.
    """
    p = nbrs.shape[1]
    w = (p + 31) // 32
    d = dist_block                                        # [B, N]
    dn = d[:, nbr_safe]                                   # [B, N, P]
    toward = valid[None] & (dn == (d[:, :, None] - 1))
    away = valid[None] & (dn == (d[:, :, None] + 1))
    b, n = d.shape
    min_b = np.zeros((b, n, w), np.uint32)
    away_b = np.zeros((b, n, w), np.uint32)
    for j in range(p):
        min_b[:, :, j // 32] |= (
            toward[:, :, j].astype(np.uint32) << np.uint32(j % 32))
        away_b[:, :, j // 32] |= (
            away[:, :, j].astype(np.uint32) << np.uint32(j % 32))
    return min_b, away_b


def iter_port_mask_blocks(dist_leaf: np.ndarray, nbrs: np.ndarray,
                          block: int = 256):
    """Stream ``(lo, hi, min_block, away_block)`` leaf blocks.

    Each block is the ``[lo:hi]`` leaf slice of the dense
    :func:`pack_port_masks` output, computed without ever materializing
    the ``[N1, N, W]`` arrays — peak memory is one ``[block, N, P]``
    boolean intermediate plus the two ``[block, N, W]`` uint32 outputs.
    """
    n1 = dist_leaf.shape[0]
    valid = nbrs >= 0
    nbr_safe = np.where(valid, nbrs, 0)
    for lo in range(0, n1, block):
        hi = min(lo + block, n1)
        min_b, away_b = _pack_mask_block(dist_leaf[lo:hi], nbrs,
                                         valid, nbr_safe)
        yield lo, hi, min_b, away_b


def pack_port_masks(dist_leaf: np.ndarray, nbrs: np.ndarray,
                    leaf_chunk: int = 256):
    """``(min_mask, away_mask)`` — [N1, N, ceil(P/32)] uint32 bitmasks.

    Bit ``p`` of ``min_mask[t, c, p // 32]`` is set iff following port ``p``
    from switch ``c`` decreases the distance to leaf ``t`` by exactly one;
    ``away_mask`` is the increases-by-one twin.  Together they encode the
    full Polarized link classification (Forward / Expansion / Contraction
    are conjunctions of toward/away bits w.r.t. source and target, and the
    neighbor distance is recoverable as ``d(c,t) + away - toward``), so the
    simulator never gathers ``[P]``-wide distance rows.

    This is the *dense* assembly of :func:`iter_port_mask_blocks` — use
    the iterator directly (or ``build_tables(..., masks="blocked")``) when
    the ``2 * N1 * N * W * 4``-byte footprint matters.
    """
    n1, n = dist_leaf.shape
    p = nbrs.shape[1]
    w = (p + 31) // 32
    min_mask = np.zeros((n1, n, w), np.uint32)
    away_mask = np.zeros((n1, n, w), np.uint32)
    for lo, hi, min_b, away_b in iter_port_mask_blocks(dist_leaf, nbrs,
                                                       leaf_chunk):
        min_mask[lo:hi] = min_b
        away_mask[lo:hi] = away_b
    return min_mask, away_mask


def mask_table_bytes(n1: int, n: int, p: int) -> int:
    """Bytes of ONE dense ``[N1, N, W]`` uint32 mask table."""
    return n1 * n * ((p + 31) // 32) * 4


def build_tables(topo: Topology, full: bool = False, *,
                 masks: str = "auto",
                 leaf_block: int = 256) -> RoutingTables:
    """Distance tables + packed port masks for ``topo``.

    ``masks`` picks the port-mask layout: ``"dense"`` materializes the
    ``[N1, N, W]`` numpy arrays, ``"blocked"`` defers them to streamed
    leaf blocks (:meth:`RoutingTables.mask_blocks`), and ``"auto"`` (the
    default) uses ``"blocked"`` once one dense table would exceed
    :data:`DENSE_MASK_LIMIT` bytes — so small fabrics keep the old
    behaviour exactly and paper-scale fabrics never hold dense masks.
    """
    if masks not in MASK_LAYOUTS:
        raise ValueError(f"unknown mask layout {masks!r}; expected one of "
                         f"{MASK_LAYOUTS}")
    dist_leaf = bfs_distances(topo, topo.leaf_ids)
    dist_full = bfs_distances(topo, np.arange(topo.n_switches)) if full else None
    if masks == "auto":
        dense_bytes = mask_table_bytes(topo.n_leaves, topo.n_switches,
                                       topo.max_ports)
        masks = "dense" if dense_bytes <= DENSE_MASK_LIMIT else "blocked"
    if masks == "dense":
        min_mask, away_mask = pack_port_masks(dist_leaf, topo.nbrs,
                                              leaf_block)
    else:
        min_mask = away_mask = None
    return RoutingTables(topo, dist_leaf, topo.leaf_rank(), dist_full,
                         min_mask, away_mask, mask_layout=masks,
                         leaf_block=leaf_block)


# ---------------------------------------------------------------------- #
# Polarized port classification (numpy + jnp twins)
# ---------------------------------------------------------------------- #
def polarized_port_mask(
    d_cs, d_ct, d_ns, d_nt, hops, max_hops, valid,
):
    """Vectorized Polarized filter.  Works with numpy or jnp arrays.

    Args are broadcastable: ``d_cs, d_ct, hops`` per packet, ``d_ns, d_nt,
    valid`` per (packet, port).  Returns ``(allowed, is_deroute)`` masks.
    A deroute (Expansion/Contraction) additionally requires that the hop
    budget still admits finishing: ``hops + 1 + d_nt <= max_hops``.
    """
    import numpy as xp  # numpy semantics; jnp arrays pass through fine
    fwd = (d_ns == d_cs + 1) & (d_nt == d_ct - 1)
    exp_ = (d_ns == d_cs + 1) & (d_nt == d_ct + 1) & (d_cs < d_ct)
    con = (d_ns == d_cs - 1) & (d_nt == d_ct - 1) & (d_cs >= d_ct)
    budget_ok = (hops + 1 + d_nt) <= max_hops
    deroute = (exp_ | con)
    allowed = valid & (fwd | (deroute & budget_ok))
    del xp
    return allowed, deroute & valid


# ---------------------------------------------------------------------- #
# host-side reference router (tests, analytics, corner detection)
# ---------------------------------------------------------------------- #
def route_packet_host(
    tables: RoutingTables,
    src_leaf: int,
    dst_leaf: int,
    policy: str = "polarized",
    max_hops: Optional[int] = None,
    occupancy: Optional[np.ndarray] = None,     # [N, P] synthetic load
    rng: Optional[np.random.Generator] = None,
    deroute_penalty: float = 10.0,
) -> list[int]:
    """Route one packet switch-by-switch; returns the list of visited
    switches (including src and dst).  Raises RuntimeError on a *corner*
    (no allowed port — Section 4.3.2) or hop-budget exhaustion."""
    topo, dist = tables.topo, tables.dist_leaf
    lr = tables.leaf_rank
    s, t = lr[src_leaf], lr[dst_leaf]
    assert s >= 0 and t >= 0, "src/dst must be leaves"
    if max_hops is None:
        max_hops = 2 * tables.diameter_star - 2 if policy == "polarized" \
            else tables.diameter_leaf
    rng = rng or np.random.default_rng(0)
    occ = occupancy if occupancy is not None else np.zeros_like(topo.nbrs, np.float64)

    path = [src_leaf]
    cur, hops = src_leaf, 0
    mid = None
    if policy == "valiant" or policy == "ugal":
        mid = int(rng.choice(topo.leaf_ids))
        if policy == "ugal":       # UGAL-L: pick VAL only if MIN looks congested
            min_ports = np.nonzero(
                (topo.nbrs[cur] >= 0)
                & (dist[t, topo.nbrs[cur]] == dist[t, cur] - 1))[0]
            val_ports = np.nonzero(
                (topo.nbrs[cur] >= 0)
                & (dist[lr[mid], topo.nbrs[cur]] == dist[lr[mid], cur] - 1))[0]
            q_min = occ[cur, min_ports].min() if min_ports.size else np.inf
            q_val = occ[cur, val_ports].min() if val_ports.size else np.inf
            d_min, d_val = dist[t, cur], dist[lr[mid], cur] + dist[t, mid]
            if q_min * d_min <= q_val * d_val:
                mid = None        # go minimal
    target_rank = t if mid is None else lr[mid]

    while cur != dst_leaf:
        if hops >= max_hops:
            raise RuntimeError(f"hop budget exhausted at {cur} ({policy})")
        nb = topo.nbrs[cur]
        valid = nb >= 0
        nb_safe = np.where(valid, nb, 0)
        if policy == "polarized":
            allowed, deroute = polarized_port_mask(
                dist[s, cur], dist[t, cur],
                dist[s, nb_safe], dist[t, nb_safe],
                hops, max_hops, valid)
            if not allowed.any():
                raise RuntimeError(f"corner at switch {cur} for pair ({src_leaf},{dst_leaf})")
            score = occ[cur] + deroute_penalty * deroute + rng.uniform(0, 1e-6, nb.shape)
            score = np.where(allowed, score, np.inf)
            port = int(np.argmin(score))
        else:
            # minimal (adaptive / random) toward current target
            min_mask = valid & (dist[target_rank, nb_safe] == dist[target_rank, cur] - 1)
            if not min_mask.any():
                raise RuntimeError(f"no minimal port at {cur}")
            ports = np.nonzero(min_mask)[0]
            if policy == "ksp":
                port = int(rng.choice(ports))      # randomized minimal-DAG walk
            else:                                  # minimal_adaptive / ugal / valiant
                port = int(ports[np.argmin(occ[cur, ports])])
        cur = int(topo.nbrs[cur, port])
        hops += 1
        path.append(cur)
        if mid is not None and cur == mid:
            mid = None
            target_rank = t
    return path


def find_corners(tables: RoutingTables, n_samples: int = 2000, seed: int = 0) -> int:
    """Sample (s, t) leaf pairs and count Polarized routing failures
    (corners).  The paper re-rolls the MRLS if any corner exists; for random
    topologies the probability is negligible (Section 4.3.2)."""
    rng = np.random.default_rng(seed)
    leaves = tables.topo.leaf_ids
    corners = 0
    for _ in range(n_samples):
        a, b = rng.choice(leaves, 2, replace=False)
        try:
            route_packet_host(tables, int(a), int(b), "polarized", rng=rng)
        except RuntimeError:
            corners += 1
    return corners
