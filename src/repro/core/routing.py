"""Routing for randomly-wired indirect networks (Section 4.3 of the paper).

Host-side (numpy): BFS distance tables and a reference step-by-step router
used by tests and analytics.  Device-side (jnp): vectorized Polarized port
scoring used by the cycle-level simulator.

Polarized routing (Camarero et al. [28], adapted to indirect networks here):
every candidate next-hop link is classified by the tuple
``(d(n,s)-d(c,s), d(n,t)-d(c,t))`` into Forward(+1,-1) / Expansion(+1,+1) /
Contraction(-1,-1) / Backtrack(-1,+1).  Forward is always allowed; Expansion
only while ``d(c,s) < d(c,t)``; Contraction only once ``d(c,s) >= d(c,t)``;
Backtrack never.  Theorem 4.2 bounds route length by ``2 D* - 2``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .topology import Topology

__all__ = [
    "bfs_distances",
    "RoutingTables",
    "TableDelta",
    "build_tables",
    "pack_port_masks",
    "iter_port_mask_blocks",
    "mask_table_bytes",
    "polarized_port_mask",
    "route_packet_host",
    "POLICIES",
    "MASK_LAYOUTS",
    "DENSE_MASK_LIMIT",
    "UNREACHABLE",
]

POLICIES = ("polarized", "minimal_adaptive", "ksp", "ugal", "valiant",
            "degraded")

MASK_LAYOUTS = ("auto", "dense", "blocked")

# Sentinel distance for switches unreachable after failures.  Chosen so it
# (a) stays >= 0 — the engine's pristine-construction assert and every
# ``d >= 0`` check pass — and (b) sits far above any real diameter yet far
# below int16 overflow, so ``d - 1`` / ``d + 1`` comparisons against real
# distances are always false and hop-budget tests always fail (a packet is
# never steered toward an unreachable switch).
UNREACHABLE = 16384

# ``masks="auto"`` switches to the blocked (streamed) layout once one dense
# numpy mask table would exceed this many bytes — small fabrics keep the
# dense arrays around for host-side tooling, paper-scale fabrics never
# materialize them.
DENSE_MASK_LIMIT = 256 * 1024 * 1024


# ---------------------------------------------------------------------- #
# distances
# ---------------------------------------------------------------------- #
def bfs_distances(topo: Topology, sources: np.ndarray, *,
                  nbrs: Optional[np.ndarray] = None) -> np.ndarray:
    """[len(sources), N] int16 hop distances (-1 = unreachable).

    Per-source frontier BFS with vectorized neighbor expansion; fast enough
    for the paper's 100K-endpoint networks (~6K sources x ~9K switches).
    The TPU-resident alternative is tropical matrix powering — see
    ``repro.kernels.minplus`` (the Pallas hot-spot kernel).

    ``nbrs`` overrides the adjacency (same ``[N, P]`` -1-padded layout) —
    the delta-rebuild path passes an *effective* adjacency with failed
    links/switches masked out without mutating the topology.
    """
    nbrs = topo.nbrs if nbrs is None else nbrs
    n, p = topo.n_switches, nbrs.shape[1]
    sources = np.asarray(sources, dtype=np.int64)
    k = len(sources)
    out = np.full((k, n), -1, np.int16)
    # level-synchronous over source *blocks*: expand every block member's
    # frontier in one scatter per hop level — work proportional to the
    # frontier population (not B*N*P), which is what makes the
    # delta-rebuild path cheap when only a few leaf rows changed.  The
    # block bounds the per-level index arrays at the 100k scale points.
    block = 256
    for lo in range(0, k, block):
        hi = min(lo + block, k)
        b = hi - lo
        frontier = np.zeros((b, n), bool)
        frontier[np.arange(b), sources[lo:hi]] = True
        visited = frontier.copy()
        dist = out[lo:hi]
        d = 0
        while True:
            rows, nodes = np.nonzero(frontier)
            if rows.size == 0:
                break
            dist[rows, nodes] = d
            cand = nbrs[nodes]                       # [F, P]
            ok = (cand >= 0).ravel()
            nxt = np.zeros_like(frontier)
            nxt[np.repeat(rows, p)[ok], cand.ravel()[ok]] = True
            frontier = nxt & ~visited
            visited |= frontier
            d += 1
    return out


@dataclasses.dataclass
class TableDelta:
    """Changed rows + live masks from one :meth:`RoutingTables.apply_failures`.

    ``leaf_rows`` indexes the leaf-rank axis; the row arrays carry the
    recomputed distance/mask rows for exactly those leaves.  ``link_up``
    and ``switch_up`` are the *full* current liveness masks (tiny:
    ``N*P`` + ``N`` bools) — the engine consumes them wholesale.
    """

    leaf_rows: np.ndarray      # [K] int32 affected leaf ranks
    dist_rows: np.ndarray      # [K, N] int16 (UNREACHABLE where cut off)
    min_rows: np.ndarray       # [K, N, W] uint32 toward-bit rows
    away_rows: np.ndarray      # [K, N, W] uint32 away-bit rows
    link_up: np.ndarray        # [N, P] bool — directed-port liveness
    switch_up: np.ndarray      # [N] bool

    @property
    def n_affected(self) -> int:
        return int(self.leaf_rows.shape[0])


@dataclasses.dataclass
class RoutingTables:
    """Precomputed routing state shared by host router and simulator.

    ``dist_leaf`` stays int16 end to end (distances are tiny; the simulator
    gathers these rows on every crossbar sub-round, so half-width halves the
    memory traffic).  ``min_mask`` is the compact per-(target-leaf, switch)
    minimal-port bitmask: bit ``p`` of word ``min_mask[t, c, p // 32]`` is
    set iff port ``p`` of switch ``c`` leads one hop closer to leaf ``t``
    (``nbrs[c, p] >= 0 and dist_leaf[t, nbrs[c, p]] == dist_leaf[t, c] - 1``).
    Minimal policies (``minimal_adaptive``/``ksp``/``ugal``/``valiant``) test
    these bits instead of gathering whole ``[P]`` distance rows per packet.

    Two mask layouts exist (``mask_layout``):

    * ``"dense"``   — ``min_mask``/``away_mask`` hold the full
      ``[N1, N, W]`` uint32 arrays (small fabrics; host-side tooling).
    * ``"blocked"`` — the dense arrays are **never materialized**
      (``min_mask is None``); consumers stream ``leaf_block``-row leaf
      blocks through :meth:`mask_blocks` instead.  Peak host memory for
      the mask tables drops from ``2 * N1 * N * W * 4`` retained bytes to
      two transient ``leaf_block * N * W * 4``-byte blocks, which is what
      makes the paper's 100K-endpoint fabrics buildable on ordinary hosts
      (the simulator streams the blocks straight into its device tables).

    Either way the *values* are identical word for word — the blocked
    layout is a streaming order, not a different encoding — so simulator
    results are bitwise independent of the layout.
    """

    topo: Topology
    dist_leaf: np.ndarray          # [N1, N] int16 distances from each leaf
    leaf_rank: np.ndarray          # [N] rank among leaves or -1
    dist_full: Optional[np.ndarray] = None   # [N, N] (small nets / direct nets)
    min_mask: Optional[np.ndarray] = None    # [N1, N, W] uint32 toward-bits
    away_mask: Optional[np.ndarray] = None   # [N1, N, W] uint32 away-bits
    mask_layout: str = "dense"     # "dense" | "blocked"
    leaf_block: int = 256          # block height of the blocked layout
    dead_ports: Optional[np.ndarray] = None     # [N, P] bool, lazily allocated
    dead_switches: Optional[np.ndarray] = None  # [N] bool, lazily allocated

    @property
    def diameter_leaf(self) -> int:
        leaves = self.topo.leaf_ids
        return int(self.dist_leaf[:, leaves].max())

    @property
    def diameter_star(self) -> int:
        if self.dist_full is not None:
            return int(self.dist_full.max())
        return int(self.dist_leaf.max())       # max over (leaf, any-switch)

    @property
    def avg_distance_leaf(self) -> float:
        leaves = self.topo.leaf_ids
        d = self.dist_leaf[:, leaves].astype(np.float64)
        n1 = len(leaves)
        return float(d.sum() / (n1 * (n1 - 1)))

    def mask_blocks(self, block: Optional[int] = None):
        """Yield ``(lo, hi, min_block, away_block)`` leaf blocks.

        The one consumer-facing view of the port masks that works for both
        layouts: dense tables are sliced, blocked tables are computed on
        the fly from ``dist_leaf`` (one transient ``[block, N, W]`` pair at
        a time, never the dense array).  Blocks tile ``[0, N1)`` in order.
        """
        block = block or self.leaf_block
        if self.min_mask is not None and self.away_mask is not None:
            n1 = self.min_mask.shape[0]
            for lo in range(0, n1, block):
                hi = min(lo + block, n1)
                yield lo, hi, self.min_mask[lo:hi], self.away_mask[lo:hi]
            return
        yield from iter_port_mask_blocks(self.dist_leaf, self.topo.nbrs,
                                         block)

    # ------------------------------------------------------------------ #
    # delta rebuilds under failures
    # ------------------------------------------------------------------ #
    def apply_failures(self, down=(), up=()) -> TableDelta:
        """Apply link/switch state changes; recompute only affected rows.

        ``down``/``up`` are iterables of :class:`repro.core.failures
        .FailureEvent` taking effect now (``up`` restores previously
        downed elements).  The method mutates ``dist_leaf`` (and the
        dense ``min_mask``/``away_mask`` when materialized) **in place**
        — rows for unaffected leaves are untouched, and the dense
        ``[N1, N, W]`` tables are never re-materialized — then returns a
        :class:`TableDelta` with exactly the changed rows plus the full
        liveness masks.

        The frontier bound: a downed link ``{a, b}`` can change leaf
        ``t``'s distances only if the farther endpoint (say ``a``, with
        ``d(t,a) == d(t,b) + 1``) has **no other live toward port** —
        otherwise every shortest path re-routes through the alternate
        predecessor and all distances are preserved (both orientations
        are tested).  A restored link can change leaf ``t`` only if
        ``|d(t,a) - d(t,b)| >= 2`` on the current tables.  Switch events
        fall back to recomputing every leaf row (they cut up to ``P``
        links at once; the bench ladder uses link events only).

        Masks are always packed against the **static full adjacency**
        (``topo.nbrs``): a toward bit through a dead port stays set, and
        the engine's live up-mask excludes it at runtime.  That keeps
        :func:`_pack_mask_block` layout-identical for both mask layouts
        and makes restores nearly free — when a link comes back and no
        distance changed, the bits are already correct.
        """
        topo = self.topo
        n, p = topo.n_switches, topo.max_ports
        nbrs = topo.nbrs
        if self.dead_ports is None:
            self.dead_ports = np.zeros((n, p), bool)
            self.dead_switches = np.zeros(n, bool)
        n1 = self.dist_leaf.shape[0]
        affected = np.zeros(n1, bool)
        d32 = self.dist_leaf.astype(np.int32)          # sentinel-safe math

        # mark every down first, collecting freshly-killed link pairs; the
        # affected test then runs once, batched over all endpoints, against
        # the final dead state (a superset of the per-event sequential
        # test -- extra rows just recompute to identical values)
        down_pairs = []
        for ev in down:
            if ev.kind == "switch":
                self.dead_switches[ev.id] = True
                affected[:] = True
                continue
            c, pt = divmod(ev.id, p)
            nb = int(nbrs[c, pt])
            nbp = int(topo.nbr_port[c, pt])
            if not self.dead_ports[c, pt]:
                down_pairs.append((c, nb))
            self.dead_ports[c, pt] = True
            self.dead_ports[nb, nbp] = True
        if down_pairs and not affected.all():
            # x = farther endpoint candidates: both orientations of every
            # killed link; leaf t is affected iff d(t,x) == d(t,y) + 1 and
            # x keeps no other live toward port
            xs = sorted({x for pair in down_pairs for x in pair})
            xi = {x: i for i, x in enumerate(xs)}
            xa = np.asarray(xs)
            live = (nbrs[xa] >= 0) & ~self.dead_ports[xa]        # [X, P]
            nb_x = np.where(live, nbrs[xa], 0)
            alt = (live[None] & (d32[:, nb_x]
                                 == (d32[:, xa] - 1)[:, :, None])
                   ).any(axis=2)                                 # [N1, X]
            x2 = np.asarray([x for c, nb in down_pairs for x in (c, nb)])
            y2 = np.asarray([y for c, nb in down_pairs for y in (nb, c)])
            far = d32[:, x2] == d32[:, y2] + 1                   # [N1, 2K]
            cols = np.asarray([xi[x] for x in x2])
            affected |= (far & ~alt[:, cols]).any(axis=1)

        up_pairs = []
        for ev in up:
            if ev.kind == "switch":
                self.dead_switches[ev.id] = False
                affected[:] = True
                continue
            c, pt = divmod(ev.id, p)
            nb = int(nbrs[c, pt])
            nbp = int(topo.nbr_port[c, pt])
            if self.dead_ports[c, pt]:
                up_pairs.append((c, nb))
            self.dead_ports[c, pt] = False
            self.dead_ports[nb, nbp] = False
        if up_pairs and not affected.all():
            cs = np.asarray([c for c, _ in up_pairs])
            nbs = np.asarray([nb for _, nb in up_pairs])
            affected |= (np.abs(d32[:, cs] - d32[:, nbs]) >= 2).any(axis=1)

        valid = nbrs >= 0
        nbr_safe = np.where(valid, nbrs, 0)
        switch_up = ~self.dead_switches
        link_up = (valid & ~self.dead_ports
                   & switch_up[:, None] & switch_up[nbr_safe])

        leaf_rows = np.nonzero(affected)[0].astype(np.int32)
        k = len(leaf_rows)
        w = (p + 31) // 32
        if k == 0:
            return TableDelta(leaf_rows,
                              np.zeros((0, n), np.int16),
                              np.zeros((0, n, w), np.uint32),
                              np.zeros((0, n, w), np.uint32),
                              link_up, switch_up)

        # effective adjacency: dead ports and any port touching a dead
        # switch become -1 (BFS only; the topology itself never mutates)
        eff = nbrs.copy()
        eff[self.dead_ports] = -1
        eff[~switch_up] = -1
        eff[valid & ~switch_up[nbr_safe]] = -1
        newd = bfs_distances(topo, topo.leaf_ids[affected], nbrs=eff)
        dist_rows = np.where(newd < 0, UNREACHABLE, newd).astype(np.int16)
        self.dist_leaf[affected] = dist_rows

        min_rows = np.empty((k, n, w), np.uint32)
        away_rows = np.empty((k, n, w), np.uint32)
        for lo in range(0, k, self.leaf_block):        # bounded scratch
            hi = min(lo + self.leaf_block, k)
            min_rows[lo:hi], away_rows[lo:hi] = _pack_mask_block(
                dist_rows[lo:hi], nbrs, valid, nbr_safe)
        if self.min_mask is not None:
            self.min_mask[affected] = min_rows
            self.away_mask[affected] = away_rows
        return TableDelta(leaf_rows, dist_rows, min_rows, away_rows,
                          link_up, switch_up)


def _pack_mask_block(dist_block: np.ndarray, nbrs: np.ndarray,
                     valid: np.ndarray, nbr_safe: np.ndarray):
    """One ``(min, away)`` uint32 block [B, N, W] for a leaf slice.

    The single bit-packing implementation shared by the dense and blocked
    layouts — the layouts cannot drift apart because there is nothing to
    drift between.
    """
    p = nbrs.shape[1]
    w = (p + 31) // 32
    d = dist_block                                        # [B, N]
    dn = d[:, nbr_safe]                                   # [B, N, P]
    toward = valid[None] & (dn == (d[:, :, None] - 1))
    away = valid[None] & (dn == (d[:, :, None] + 1))
    # one shot bit-pack: port j contributes bit j%32 of word j//32; the
    # bits are distinct within a word, so the segmented sum IS the OR
    shifts = np.uint32(1) << (np.arange(p, dtype=np.uint32) % np.uint32(32))
    starts = np.arange(0, p, 32)
    min_b = np.add.reduceat(toward * shifts, starts, axis=2)
    away_b = np.add.reduceat(away * shifts, starts, axis=2)
    return min_b.astype(np.uint32, copy=False), \
        away_b.astype(np.uint32, copy=False)


def iter_port_mask_blocks(dist_leaf: np.ndarray, nbrs: np.ndarray,
                          block: int = 256):
    """Stream ``(lo, hi, min_block, away_block)`` leaf blocks.

    Each block is the ``[lo:hi]`` leaf slice of the dense
    :func:`pack_port_masks` output, computed without ever materializing
    the ``[N1, N, W]`` arrays — peak memory is one ``[block, N, P]``
    boolean intermediate plus the two ``[block, N, W]`` uint32 outputs.
    """
    n1 = dist_leaf.shape[0]
    valid = nbrs >= 0
    nbr_safe = np.where(valid, nbrs, 0)
    for lo in range(0, n1, block):
        hi = min(lo + block, n1)
        min_b, away_b = _pack_mask_block(dist_leaf[lo:hi], nbrs,
                                         valid, nbr_safe)
        yield lo, hi, min_b, away_b


def pack_port_masks(dist_leaf: np.ndarray, nbrs: np.ndarray,
                    leaf_chunk: int = 256):
    """``(min_mask, away_mask)`` — [N1, N, ceil(P/32)] uint32 bitmasks.

    Bit ``p`` of ``min_mask[t, c, p // 32]`` is set iff following port ``p``
    from switch ``c`` decreases the distance to leaf ``t`` by exactly one;
    ``away_mask`` is the increases-by-one twin.  Together they encode the
    full Polarized link classification (Forward / Expansion / Contraction
    are conjunctions of toward/away bits w.r.t. source and target, and the
    neighbor distance is recoverable as ``d(c,t) + away - toward``), so the
    simulator never gathers ``[P]``-wide distance rows.

    This is the *dense* assembly of :func:`iter_port_mask_blocks` — use
    the iterator directly (or ``build_tables(..., masks="blocked")``) when
    the ``2 * N1 * N * W * 4``-byte footprint matters.
    """
    n1, n = dist_leaf.shape
    p = nbrs.shape[1]
    w = (p + 31) // 32
    min_mask = np.zeros((n1, n, w), np.uint32)
    away_mask = np.zeros((n1, n, w), np.uint32)
    for lo, hi, min_b, away_b in iter_port_mask_blocks(dist_leaf, nbrs,
                                                       leaf_chunk):
        min_mask[lo:hi] = min_b
        away_mask[lo:hi] = away_b
    return min_mask, away_mask


def mask_table_bytes(n1: int, n: int, p: int) -> int:
    """Bytes of ONE dense ``[N1, N, W]`` uint32 mask table."""
    return n1 * n * ((p + 31) // 32) * 4


def build_tables(topo: Topology, full: bool = False, *,
                 masks: str = "auto",
                 leaf_block: int = 256) -> RoutingTables:
    """Distance tables + packed port masks for ``topo``.

    ``masks`` picks the port-mask layout: ``"dense"`` materializes the
    ``[N1, N, W]`` numpy arrays, ``"blocked"`` defers them to streamed
    leaf blocks (:meth:`RoutingTables.mask_blocks`), and ``"auto"`` (the
    default) uses ``"blocked"`` once one dense table would exceed
    :data:`DENSE_MASK_LIMIT` bytes — so small fabrics keep the old
    behaviour exactly and paper-scale fabrics never hold dense masks.
    """
    if masks not in MASK_LAYOUTS:
        raise ValueError(f"unknown mask layout {masks!r}; expected one of "
                         f"{MASK_LAYOUTS}")
    dist_leaf = bfs_distances(topo, topo.leaf_ids)
    dist_full = bfs_distances(topo, np.arange(topo.n_switches)) if full else None
    if masks == "auto":
        dense_bytes = mask_table_bytes(topo.n_leaves, topo.n_switches,
                                       topo.max_ports)
        masks = "dense" if dense_bytes <= DENSE_MASK_LIMIT else "blocked"
    if masks == "dense":
        min_mask, away_mask = pack_port_masks(dist_leaf, topo.nbrs,
                                              leaf_block)
    else:
        min_mask = away_mask = None
    return RoutingTables(topo, dist_leaf, topo.leaf_rank(), dist_full,
                         min_mask, away_mask, mask_layout=masks,
                         leaf_block=leaf_block)


# ---------------------------------------------------------------------- #
# Polarized port classification (numpy + jnp twins)
# ---------------------------------------------------------------------- #
def polarized_port_mask(
    d_cs, d_ct, d_ns, d_nt, hops, max_hops, valid,
):
    """Vectorized Polarized filter.  Works with numpy or jnp arrays.

    Args are broadcastable: ``d_cs, d_ct, hops`` per packet, ``d_ns, d_nt,
    valid`` per (packet, port).  Returns ``(allowed, is_deroute)`` masks.
    A deroute (Expansion/Contraction) additionally requires that the hop
    budget still admits finishing: ``hops + 1 + d_nt <= max_hops``.
    """
    import numpy as xp  # numpy semantics; jnp arrays pass through fine
    fwd = (d_ns == d_cs + 1) & (d_nt == d_ct - 1)
    exp_ = (d_ns == d_cs + 1) & (d_nt == d_ct + 1) & (d_cs < d_ct)
    con = (d_ns == d_cs - 1) & (d_nt == d_ct - 1) & (d_cs >= d_ct)
    budget_ok = (hops + 1 + d_nt) <= max_hops
    deroute = (exp_ | con)
    allowed = valid & (fwd | (deroute & budget_ok))
    del xp
    return allowed, deroute & valid


# ---------------------------------------------------------------------- #
# host-side reference router (tests, analytics, corner detection)
# ---------------------------------------------------------------------- #
def route_packet_host(
    tables: RoutingTables,
    src_leaf: int,
    dst_leaf: int,
    policy: str = "polarized",
    max_hops: Optional[int] = None,
    occupancy: Optional[np.ndarray] = None,     # [N, P] synthetic load
    rng: Optional[np.random.Generator] = None,
    deroute_penalty: float = 10.0,
) -> list[int]:
    """Route one packet switch-by-switch; returns the list of visited
    switches (including src and dst).  Raises RuntimeError on a *corner*
    (no allowed port — Section 4.3.2) or hop-budget exhaustion."""
    topo, dist = tables.topo, tables.dist_leaf
    lr = tables.leaf_rank
    s, t = lr[src_leaf], lr[dst_leaf]
    assert s >= 0 and t >= 0, "src/dst must be leaves"
    if max_hops is None:
        max_hops = 2 * tables.diameter_star - 2 if policy == "polarized" \
            else tables.diameter_leaf
    rng = rng or np.random.default_rng(0)
    occ = occupancy if occupancy is not None else np.zeros_like(topo.nbrs, np.float64)

    path = [src_leaf]
    cur, hops = src_leaf, 0
    mid = None
    if policy == "valiant" or policy == "ugal":
        mid = int(rng.choice(topo.leaf_ids))
        if policy == "ugal":       # UGAL-L: pick VAL only if MIN looks congested
            min_ports = np.nonzero(
                (topo.nbrs[cur] >= 0)
                & (dist[t, topo.nbrs[cur]] == dist[t, cur] - 1))[0]
            val_ports = np.nonzero(
                (topo.nbrs[cur] >= 0)
                & (dist[lr[mid], topo.nbrs[cur]] == dist[lr[mid], cur] - 1))[0]
            q_min = occ[cur, min_ports].min() if min_ports.size else np.inf
            q_val = occ[cur, val_ports].min() if val_ports.size else np.inf
            d_min, d_val = dist[t, cur], dist[lr[mid], cur] + dist[t, mid]
            if q_min * d_min <= q_val * d_val:
                mid = None        # go minimal
    target_rank = t if mid is None else lr[mid]

    while cur != dst_leaf:
        if hops >= max_hops:
            raise RuntimeError(f"hop budget exhausted at {cur} ({policy})")
        nb = topo.nbrs[cur]
        valid = nb >= 0
        nb_safe = np.where(valid, nb, 0)
        if policy == "polarized":
            allowed, deroute = polarized_port_mask(
                dist[s, cur], dist[t, cur],
                dist[s, nb_safe], dist[t, nb_safe],
                hops, max_hops, valid)
            if not allowed.any():
                raise RuntimeError(f"corner at switch {cur} for pair ({src_leaf},{dst_leaf})")
            score = occ[cur] + deroute_penalty * deroute + rng.uniform(0, 1e-6, nb.shape)
            score = np.where(allowed, score, np.inf)
            port = int(np.argmin(score))
        else:
            # minimal (adaptive / random) toward current target
            min_mask = valid & (dist[target_rank, nb_safe] == dist[target_rank, cur] - 1)
            if not min_mask.any():
                raise RuntimeError(f"no minimal port at {cur}")
            ports = np.nonzero(min_mask)[0]
            if policy == "ksp":
                port = int(rng.choice(ports))      # randomized minimal-DAG walk
            else:                                  # minimal_adaptive / ugal / valiant
                port = int(ports[np.argmin(occ[cur, ports])])
        cur = int(topo.nbrs[cur, port])
        hops += 1
        path.append(cur)
        if mid is not None and cur == mid:
            mid = None
            target_rank = t
    return path


def find_corners(tables: RoutingTables, n_samples: int = 2000, seed: int = 0) -> int:
    """Sample (s, t) leaf pairs and count Polarized routing failures
    (corners).  The paper re-rolls the MRLS if any corner exists; for random
    topologies the probability is negligible (Section 4.3.2)."""
    rng = np.random.default_rng(seed)
    leaves = tables.topo.leaf_ids
    corners = 0
    for _ in range(n_samples):
        a, b = rng.choice(leaves, 2, replace=False)
        try:
            route_packet_host(tables, int(a), int(b), "polarized", rng=rng)
        except RuntimeError:
            corners += 1
    return corners
