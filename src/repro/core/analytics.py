"""Topology metrics and the paper's analytic machinery.

Exact metrics (Section 2.2): average distance A, diameter D/D*, capacity
limit Theta = 2M / (S * A)  (Eq. 1), link/switch costs (Eqs. 2-3).

Appendix A: distance-distribution estimation for MRLS via the
coupon-collector neighborhood recurrence (Eqs. 5-6), expected A / A*, and the
D* threshold probabilities (Eqs. 7-9) used to draw the scalability spectrum
(Figs. 3-4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .topology import Topology
from .routing import RoutingTables, build_tables

__all__ = [
    "Metrics", "exact_metrics",
    "mrls_distance_distribution", "mrls_expected_A", "mrls_expected_A_star",
    "prob_dstar_leq", "dstar_thresholds", "mrls_design",
    "theta", "cost_links", "cost_switches",
]


# ---------------------------------------------------------------------- #
# exact metrics
# ---------------------------------------------------------------------- #
def theta(M: int, S: int, A: float) -> float:
    """Capacity limit  Theta = 2M / (S A)   (Eq. 1)."""
    return 2.0 * M / (S * A)


def cost_links(M: int, S: int) -> float:
    return M / S                                             # Eq. 2


def cost_switches(N: int, S: int) -> float:
    return N / S                                             # Eq. 3


@dataclasses.dataclass
class Metrics:
    name: str
    S: int
    N: int
    M: int
    A: float            # avg leaf-leaf distance
    D: int              # leaf-leaf diameter
    D_star: int         # max distance over all switch pairs seen
    theta: float
    cost_links: float
    cost_switches: float

    def row(self) -> str:
        return (f"{self.name:>26s}  S={self.S:<7d} N={self.N:<6d} M={self.M:<7d} "
                f"A={self.A:5.3f} D={self.D} D*={self.D_star} "
                f"Θ={self.theta:5.3f} C_l={self.cost_links:5.3f} C_s={self.cost_switches:5.3f}")


def exact_metrics(topo: Topology, tables: Optional[RoutingTables] = None,
                  full: bool = False) -> Metrics:
    tables = tables or build_tables(topo, full=full)
    A = tables.avg_distance_leaf
    S, N, M = topo.n_endpoints, topo.n_switches, topo.n_links
    return Metrics(
        name=topo.name, S=S, N=N, M=M, A=A,
        D=tables.diameter_leaf, D_star=tables.diameter_star,
        theta=theta(M, S, A),
        cost_links=cost_links(M, S),
        cost_switches=cost_switches(N, S),
    )


# ---------------------------------------------------------------------- #
# Appendix A.1 — distance distribution via coupon-collector recurrence
# ---------------------------------------------------------------------- #
def _eta(x: float, n1_i: float, n_next: float) -> float:
    """Expected neighborhood size  eta_i(x) = N_{i+1} (1 - exp(-x n1_i / N_{i+1}))
    (Eq. 6, from Kan's martingale coupon-collector bound [35])."""
    return n_next * (1.0 - math.exp(-x * n1_i / n_next))


def mrls_distance_distribution(
    n1: int, n2: int, u: int, R: int, r_max: int = 24,
) -> dict:
    """Expected sphere sizes n_r^i and ball sizes b_r^i for i in {1, 2}
    (leaf-centered and spine-centered), per Appendix A.1.

    Level sizes: N_1 = n1 leaves (degree u), N_2 = n2 spines (degree R).
    Balls alternate level: a ball of radius r centered at level i lives at
    level (i + r) mod 2 — so the growth step uses the branching factor and
    target-level size of the *current* frontier level.
    """
    N = {1: float(n1), 2: float(n2)}
    deg = {1: float(u), 2: float(R)}

    out = {}
    for i in (1, 2):
        b = [1.0]                      # b_0 = 1
        n_r = [1.0]                    # n_0 = 1
        for r in range(r_max):
            cur_level = 1 + ((i + r + 1) % 2)   # level of frontier at radius r
            nxt_level = 1 + ((i + r) % 2)       # level reached at radius r+1
            grown = _eta(b[r], deg[cur_level], N[nxt_level])
            b.append(min(grown, N[nxt_level]))
            if r + 1 >= 2:
                n_r.append(max(b[r + 1] - b[r - 1], 0.0))
            else:
                n_r.append(b[r + 1])
        out[i] = {"b": np.asarray(b), "n": np.asarray(n_r)}
    return out


def mrls_expected_A(n1: int, n2: int, u: int, R: int) -> float:
    """Expected leaf-leaf average distance  A = (1/(N1-1)) sum 2i * n_{2i}^1."""
    dist = mrls_distance_distribution(n1, n2, u, R)
    n = dist[1]["n"]
    total, weight = 0.0, 0.0
    for r in range(2, len(n), 2):
        total += r * n[r]
        weight += n[r]
    # normalize by realized mass (clip against N1-1 for tiny truncation error)
    return total / max(weight, 1e-12)


def mrls_expected_A_star(n1: int, n2: int, u: int, R: int) -> float:
    """A* over all ordered switch pairs: start from both leaf and spine."""
    dist = mrls_distance_distribution(n1, n2, u, R)
    total, weight = 0.0, 0.0
    for i, cnt in ((1, n1), (2, n2)):
        n = dist[i]["n"]
        for r in range(1, len(n)):
            total += cnt * r * n[r]
            weight += cnt * n[r]
    return total / max(weight, 1e-12)


# ---------------------------------------------------------------------- #
# Appendix A.2/A.3 — D* thresholds
# ---------------------------------------------------------------------- #
def _log_p_empty(x: float, y: float, n: float) -> float:
    """log P[X ∩ Y = ∅] for random x- and y-subsets of an n-set (Eq. 9),
    via log-gamma so it works for the fractional expectations of App. A.1."""
    x, y = min(x, n), min(y, n)
    if x + y >= n:
        return -math.inf
    return (math.lgamma(n - x + 1) + math.lgamma(n - y + 1)
            - math.lgamma(n - x - y + 1) - math.lgamma(n + 1))


def prob_dstar_leq(n1: int, n2: int, u: int, R: int, k: int) -> float:
    """P[D* <= k]  (Eq. 8).

    Considers pairs (s leaf, t leaf) for odd k and (s leaf, t spine) for even
    k, testing S_1(s) ∩ S_{k-2}(t) = ∅ at the spine level (the paper's most
    precise choice i=1)."""
    if k < 2:
        return 0.0
    dist = mrls_distance_distribution(n1, n2, u, R)
    # Y is the parity BALL B_{k-2}(t) (spine-level switches within k-2 of t):
    # d(s,t) <= k-1 iff S_1(s) intersects it.  The paper's Eq. (7) uses the
    # sphere S_{k-2}(t); ball == sphere-dominated in the threshold regime,
    # and the ball stays exact once the distribution saturates (P -> 1).
    if k % 2 == 1:            # t leaf — both endpoints leaves
        G = n1 * (n1 - 1) / 2.0
        y = float(dist[1]["b"][k - 2])
    else:                     # t spine
        G = float(n1) * n2
        y = float(dist[2]["b"][k - 2])
    x = float(u)              # |S_1(s)|, s leaf
    log_p = _log_p_empty(x, y, float(n2))
    lam = G * math.exp(log_p) if log_p > -700 else 0.0
    return math.exp(-lam)


def mrls_design(S: int, R: int, f: float) -> tuple[int, int, int, int]:
    """Pick (n1, n2, u, d) for a target endpoint count S, radix R, thickness
    f = u/d.  Exact divisibility is relaxed (fine-grain scalability means any
    nearby size works; we round to the nearest valid instance)."""
    d = max(1, round(R / (1.0 + f)))
    u = R - d
    n1 = max(2, round(S / d))
    # u*n1 must be divisible by R for integral spine count: round n1 up.
    while (u * n1) % R:
        n1 += 1
    n2 = (u * n1) // R
    return n1, n2, u, d


def dstar_thresholds(R: int, f: float, k_max: int = 8,
                     s_lo: float = 1e2, s_hi: float = 1e9) -> dict[int, float]:
    """Endpoint count S at which P[D* <= k] = 1/2 (the region boundaries of
    Fig. 3), found by bisection over S for each k."""
    out = {}
    for k in range(2, k_max + 1):
        lo, hi = s_lo, s_hi
        def p_of(s):
            n1, n2, u, d = mrls_design(int(s), R, f)
            return prob_dstar_leq(n1, n2, u, R, k)
        if p_of(lo) < 0.5:
            continue                       # threshold below range
        if p_of(hi) > 0.5:
            out[k] = math.inf
            continue
        for _ in range(80):
            mid = math.sqrt(lo * hi)
            if p_of(mid) >= 0.5:
                lo = mid
            else:
                hi = mid
        out[k] = math.sqrt(lo * hi)
    return out
