"""Network topologies from the MRLS paper (Cano et al., 2026).

All topologies are represented as switch-level graphs (Section 2.1 of the
paper): vertices are switches, edges are bidirectional links.  Endpoints are
abstracted: each *leaf* switch owns ``endpoints_per_leaf`` endpoints.

Builders:
  * :func:`mrls`         -- Multipass Random Leaf-Spine (Definition 4.1)
  * :func:`fat_tree`     -- non-blocking folded-Clos Fat-Tree (+ depopulation)
  * :func:`oft`          -- 2-level Orthogonal Fat-Tree from PG(2, q) polarity
  * :func:`dragonfly`    -- canonical balanced Dragonfly (Kim et al.)
  * :func:`dragonfly_plus`-- Dragonfly+ (leaf-spine groups, global trunking)
  * :func:`rfc`          -- 2-level Random Folded Clos (up/down connected MRLS)
  * :func:`jellyfish`    -- random regular graph fabric (Singla et al.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Topology",
    "mrls",
    "fat_tree",
    "oft",
    "dragonfly",
    "dragonfly_plus",
    "rfc",
    "jellyfish",
]


@dataclasses.dataclass
class Topology:
    """A switch-level graph with endpoint bookkeeping.

    ``nbrs[c, p]`` is the switch reached by port ``p`` of switch ``c`` (or -1
    for an unused port).  ``nbr_port[c, p]`` is the port index *on that
    neighbor* that the link lands on — needed by the simulator to address the
    receiving input queue.  Multi-edges (parallel links) are allowed; each
    occupies distinct ports on both sides.
    """

    name: str
    kind: str                      # "indirect" | "direct"
    nbrs: np.ndarray               # [N, P] int32, -1 padded
    nbr_port: np.ndarray           # [N, P] int32, -1 padded
    is_leaf: np.ndarray            # [N] bool — switches with endpoints
    endpoints_per_leaf: int        # d
    level: np.ndarray              # [N] int32, 0 = leaf level
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    @property
    def n_switches(self) -> int:
        return int(self.nbrs.shape[0])

    @property
    def max_ports(self) -> int:
        return int(self.nbrs.shape[1])

    @property
    def leaf_ids(self) -> np.ndarray:
        return np.nonzero(self.is_leaf)[0].astype(np.int32)

    @property
    def n_leaves(self) -> int:
        return int(self.is_leaf.sum())

    @property
    def n_endpoints(self) -> int:
        return self.n_leaves * self.endpoints_per_leaf

    @property
    def n_links(self) -> int:
        """M — number of bidirectional switch-to-switch links."""
        return int((self.nbrs >= 0).sum()) // 2

    @property
    def degrees(self) -> np.ndarray:
        return (self.nbrs >= 0).sum(axis=1).astype(np.int32)

    # ------------------------------------------------------------------ #
    def endpoint_leaf(self, endpoint: np.ndarray) -> np.ndarray:
        """Map endpoint id(s) -> owning leaf switch id(s)."""
        leaves = self.leaf_ids
        return leaves[np.asarray(endpoint) // self.endpoints_per_leaf]

    def leaf_rank(self) -> np.ndarray:
        """[N] int32: rank of each switch among leaves (-1 for non-leaf)."""
        r = np.full(self.n_switches, -1, np.int32)
        r[self.leaf_ids] = np.arange(self.n_leaves, dtype=np.int32)
        return r

    def validate(self) -> None:
        """Structural invariants (used by property tests)."""
        n, p = self.nbrs.shape
        assert self.nbr_port.shape == (n, p)
        used = self.nbrs >= 0
        assert (self.nbr_port[used] >= 0).all()
        assert (~used == (self.nbr_port < 0)).all()
        # link reciprocity: the neighbor's port must point back here.
        c, pt = np.nonzero(used)
        dst, dpt = self.nbrs[c, pt], self.nbr_port[c, pt]
        assert (self.nbrs[dst, dpt] == c).all(), "non-reciprocal link"
        assert (self.nbr_port[dst, dpt] == pt).all(), "port mismatch"
        assert self.is_leaf.any()


# ---------------------------------------------------------------------- #
# construction helpers
# ---------------------------------------------------------------------- #
def _from_edges(
    name: str,
    kind: str,
    n_switches: int,
    edges: np.ndarray,          # [M, 2] int
    is_leaf: np.ndarray,
    endpoints_per_leaf: int,
    level: np.ndarray,
    max_ports: Optional[int] = None,
    meta: Optional[dict] = None,
) -> Topology:
    edges = np.asarray(edges, np.int64)
    deg = np.zeros(n_switches, np.int64)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    P = int(deg.max()) if max_ports is None else max_ports
    nbrs = np.full((n_switches, P), -1, np.int32)
    nbr_port = np.full((n_switches, P), -1, np.int32)
    cursor = np.zeros(n_switches, np.int64)
    # sequential port assignment (python loop is fine at build time)
    for a, b in edges:
        pa, pb = cursor[a], cursor[b]
        nbrs[a, pa], nbrs[b, pb] = b, a
        nbr_port[a, pa], nbr_port[b, pb] = pb, pa
        cursor[a], cursor[b] = pa + 1, pb + 1
    topo = Topology(
        name=name,
        kind=kind,
        nbrs=nbrs,
        nbr_port=nbr_port,
        is_leaf=np.asarray(is_leaf, bool),
        endpoints_per_leaf=int(endpoints_per_leaf),
        level=np.asarray(level, np.int32),
        meta=meta or {},
    )
    topo.validate()
    return topo


# ---------------------------------------------------------------------- #
# MRLS (Definition 4.1)
# ---------------------------------------------------------------------- #
def mrls(
    n_leaves: int,
    u: int,
    d: int,
    seed: int = 0,
    dedup_passes: int = 40,
    name: Optional[str] = None,
) -> Topology:
    """Multipass Random Leaf-Spine network.

    ``n_leaves`` leaf switches with ``d`` endpoint ports and ``u`` up-links;
    spines have ``R = u + d`` down-links.  Requires ``u * n_leaves % R == 0``
    (the paper's ``u N1 = R N2``).  Wiring is a random bipartite matching of
    port stubs (configuration model) with parallel-edge reduction via edge
    swaps — the Steger–Wormald-style process referenced by the paper [24].
    """
    R = u + d
    if (u * n_leaves) % R != 0:
        raise ValueError(f"u*N1 = {u * n_leaves} must be divisible by R = {R}")
    n_spines = (u * n_leaves) // R
    rng = np.random.default_rng(seed)

    leaf_stubs = np.repeat(np.arange(n_leaves), u)
    spine_stubs = np.repeat(np.arange(n_spines), R)
    rng.shuffle(spine_stubs)
    pairs = np.stack([leaf_stubs, spine_stubs], axis=1)  # [u*N1, 2]

    # reduce parallel edges by re-shuffling duplicate stubs together with a
    # random set of partners (a permutation preserves the degree sequence).
    for _ in range(dedup_passes):
        key = pairs[:, 0].astype(np.int64) * n_spines + pairs[:, 1]
        order = np.argsort(key, kind="stable")
        sk = key[order]
        dup_pos = order[1:][sk[1:] == sk[:-1]]
        if dup_pos.size == 0:
            break
        partners = rng.integers(0, pairs.shape[0], size=2 * dup_pos.size)
        swap = np.unique(np.concatenate([dup_pos, partners]))
        pairs[swap, 1] = pairs[rng.permutation(swap), 1]

    edges = np.stack([pairs[:, 0], n_leaves + pairs[:, 1]], axis=1)
    n = n_leaves + n_spines
    is_leaf = np.zeros(n, bool)
    is_leaf[:n_leaves] = True
    level = np.where(is_leaf, 0, 1).astype(np.int32)
    return _from_edges(
        name or f"MRLS(R={R},S={n_leaves * d},u={u})",
        "indirect",
        n,
        edges,
        is_leaf,
        d,
        level,
        max_ports=R,
        meta={"u": u, "d": d, "R": R, "n_leaves": n_leaves, "n_spines": n_spines,
              "f": u / d, "seed": seed},
    )


def rfc(n_leaves: int, u: int, d: int, seed: int = 0, max_tries: int = 20) -> Topology:
    """2-level Random Folded Clos: an MRLS re-rolled until it is up/down
    connected (leaf-leaf diameter 2), the regime where classic RFC routing
    works.  Raises if the size is beyond the D=2 threshold (see Fig. 3)."""
    from .routing import bfs_distances  # local import to avoid cycle

    for t in range(max_tries):
        topo = mrls(n_leaves, u, d, seed=seed + t, name=f"RFC(R={u+d},S={n_leaves*d})")
        dist = bfs_distances(topo, topo.leaf_ids)
        if dist[:, topo.leaf_ids].max() <= 2:
            topo.meta["rerolls"] = t
            return topo
    raise ValueError("network too large for up/down (D=2) connectivity — use mrls()")


# ---------------------------------------------------------------------- #
# Fat-Tree (folded Clos, Section 2.1.1)
# ---------------------------------------------------------------------- #
def fat_tree(radix: int, h: int, a1: Optional[int] = None) -> Topology:
    """Non-blocking folded-Clos Fat-Tree of height ``h`` (h+1 switch levels).

    Built as a mixed-radix n-tree: endpoints are addressed by digits
    ``(a_1, a_2, .., a_h)`` with ``a_1 in [A1]`` (default ``A1 = radix``) and
    ``a_i in [k]``, ``k = radix / 2``.  A level-``l`` switch is
    ``(a_1..a_{h-l}, p_1..p_l)``; its up-port ``p`` connects to
    ``(a_1..a_{h-l-1}, p_1..p_l, p)``.  Leaves have ``k`` endpoints.

    * full tree: ``a1 = radix`` (=2k) -> S = 2 k^{h+1}, the paper's formula.
    * 50% depopulated (paper's ``FT(36, 104976) 50% pop.``): ``a1 = k`` —
      half the pods built out, root level kept at full relative size.
    """
    k = radix // 2
    if radix % 2:
        raise ValueError("radix must be even")
    A1 = radix if a1 is None else a1

    # enumerate switches level by level; address -> id maps.
    def level_count(l: int) -> int:
        if l == h:
            return k ** h
        return A1 * k ** (h - 1)  # a_1 * k^(h-l-1) * k^l

    offsets = np.cumsum([0] + [level_count(l) for l in range(h + 1)])
    n = int(offsets[-1])

    def sid(l: int, a_digits: tuple, p_digits: tuple) -> int:
        # a_digits: (a_1..a_{h-l}); p_digits: (p_1..p_l)
        idx = 0
        if l < h:
            idx = a_digits[0]
            for d_ in a_digits[1:]:
                idx = idx * k + d_
        for d_ in p_digits:
            idx = idx * k + d_
        return int(offsets[l] + idx)

    edges = []
    import itertools

    for l in range(h):
        a_len = h - l
        a_space = itertools.product(range(A1), *([range(k)] * (a_len - 1)))
        for a in a_space:
            for p_ in itertools.product(*([range(k)] * l)):
                me = sid(l, a, p_)
                for p in range(k):
                    up = sid(l + 1, a[:-1], p_ + (p,))
                    edges.append((me, up))
    edges = np.asarray(edges, np.int64)
    is_leaf = np.zeros(n, bool)
    is_leaf[: level_count(0)] = True
    level = np.zeros(n, np.int32)
    for l in range(h + 1):
        level[offsets[l]: offsets[l + 1]] = l
    return _from_edges(
        f"FT(R={radix},h={h},S={level_count(0) * k})",
        "indirect",
        n,
        edges,
        is_leaf,
        k,
        level,
        max_ports=radix,
        meta={"radix": radix, "h": h, "k": k, "a1": A1},
    )


# ---------------------------------------------------------------------- #
# Orthogonal Fat-Tree (2-level, from a polarity of PG(2, q))
# ---------------------------------------------------------------------- #
def _pg2_points(q: int) -> np.ndarray:
    """Canonical representatives of the q^2+q+1 points of PG(2, q), q prime."""
    pts = [(1, y, z) for y in range(q) for z in range(q)]
    pts += [(0, 1, z) for z in range(q)]
    pts += [(0, 0, 1)]
    return np.asarray(pts, np.int64)


def _is_prime(q: int) -> bool:
    if q < 2:
        return False
    i = 2
    while i * i <= q:
        if q % i == 0:
            return False
        i += 1
    return True


def oft(q: int) -> Topology:
    """2-level Orthogonal Fat-Tree [6, 7] built from the standard polarity
    (correlation ``x <-> x^perp``) of PG(2, q), q prime.

    * ``N1 = 2(q^2+q+1)`` leaves (point-side + line-side), ``q+1`` up-links,
      ``q+1`` endpoint ports each (R = 2(q+1)).
    * ``N2 = q^2+q+1`` spines; spine ``j`` connects to point-leaves ``p`` with
      ``p . x_j = 0`` and line-side leaves ``L`` with ``x_j in L`` — i.e. each
      spine sees q+1 leaves of each side.  Any two opposite-side leaves share
      a spine => leaf-leaf diameter 2 (paper: D=2, D*=3).
    """
    if not _is_prime(q):
        raise NotImplementedError("oft() supports prime q (the paper uses q=17)")
    pts = _pg2_points(q)                       # [m, 3]
    m = len(pts)                               # q^2+q+1
    # incidence: point i on line j  <=>  pts[i] . pts[j] == 0 (mod q)
    inc = (pts @ pts.T) % q == 0               # [m, m] symmetric
    # leaves: 0..m-1 point-side, m..2m-1 line-side; spines: 2m..3m-1
    edges = []
    pi, li = np.nonzero(inc)
    for a, b in zip(pi, li):
        edges.append((a, 2 * m + b))           # point-leaf a — spine b
        edges.append((m + a, 2 * m + b))       # line-leaf a  — spine b
    n = 3 * m
    is_leaf = np.zeros(n, bool)
    is_leaf[: 2 * m] = True
    level = np.where(is_leaf, 0, 1).astype(np.int32)
    d = q + 1
    return _from_edges(
        f"OFT(R={2 * (q + 1)},S={2 * m * d},q={q})",
        "indirect",
        n,
        np.asarray(edges, np.int64),
        is_leaf,
        d,
        level,
        max_ports=2 * (q + 1),
        meta={"q": q, "n_leaves": 2 * m, "n_spines": m},
    )


# ---------------------------------------------------------------------- #
# Dragonfly and Dragonfly+
# ---------------------------------------------------------------------- #
def dragonfly(a: int, p: int, h: int, n_groups: Optional[int] = None) -> Topology:
    """Canonical Dragonfly [5]: ``g`` groups of ``a`` switches; complete graph
    inside each group; ``h`` global ports per switch; ``p`` endpoints per
    switch.  Balanced max size: ``g = a*h + 1`` with exactly one global link
    between every group pair (palmtree arrangement)."""
    g = (a * h + 1) if n_groups is None else n_groups
    if n_groups is None:
        assert g == a * h + 1
    n = g * a
    edges = []
    # intra-group complete graph
    for grp in range(g):
        base = grp * a
        for i in range(a):
            for j in range(i + 1, a):
                edges.append((base + i, base + j))
    # global links: group gi global slot s in [a*h] -> peer group.
    # palmtree: slot s of group gi connects to group (gi + s + 1) mod g.
    if g == a * h + 1:
        for gi in range(g):
            for s in range(a * h):
                gj = (gi + s + 1) % g
                if gi < gj:
                    sw_i = gi * a + (s % a)
                    # peer's slot index: it sees gi at s2 with (gj + s2 + 1) % g == gi
                    s2 = (gi - gj - 1) % g
                    sw_j = gj * a + (s2 % a)
                    edges.append((sw_i, sw_j))
    else:
        raise NotImplementedError("only maximum-size balanced dragonfly")
    is_leaf = np.ones(n, bool)
    level = np.zeros(n, np.int32)
    return _from_edges(
        f"DF(R={p + a - 1 + h},S={n * p})",
        "direct",
        n,
        np.asarray(edges, np.int64),
        is_leaf,
        p,
        level,
        max_ports=a - 1 + h,
        meta={"a": a, "p": p, "h": h, "g": g},
    )


def dragonfly_plus(
    n_groups: int, leaves_per_group: int, spines_per_group: int,
    p: int, global_per_spine: int,
) -> Topology:
    """Dragonfly+ [32]: each group is a complete bipartite leaf-spine;
    spines carry global links, trunked uniformly over peer groups."""
    g = n_groups
    lpg, spg = leaves_per_group, spines_per_group
    n = g * (lpg + spg)

    def leaf_id(grp, i):
        return grp * (lpg + spg) + i

    def spine_id(grp, j):
        return grp * (lpg + spg) + lpg + j

    edges = []
    for grp in range(g):
        for i in range(lpg):
            for j in range(spg):
                edges.append((leaf_id(grp, i), spine_id(grp, j)))
    # global: group pair trunking t = spg*global_per_spine / (g-1)
    total_glob = spg * global_per_spine
    if total_glob % (g - 1) != 0:
        raise ValueError("global links must divide evenly over peer groups")
    trunk = total_glob // (g - 1)
    # distribute: for pair (gi, gj), connect trunk links spread over spines.
    pair_counter = {}
    for gi in range(g):
        for gj in range(gi + 1, g):
            for t in range(trunk):
                idx = pair_counter.get(gi, 0)
                pair_counter[gi] = idx + 1
                idx2 = pair_counter.get(gj, 0)
                pair_counter[gj] = idx2 + 1
                edges.append((spine_id(gi, idx % spg), spine_id(gj, idx2 % spg)))
    is_leaf = np.zeros(n, bool)
    for grp in range(g):
        for i in range(lpg):
            is_leaf[leaf_id(grp, i)] = True
    level = np.where(is_leaf, 0, 1).astype(np.int32)
    return _from_edges(
        f"DF+(R={max(p + spg, lpg + global_per_spine)},S={int(is_leaf.sum()) * p})",
        "indirect",
        n,
        np.asarray(edges, np.int64),
        is_leaf,
        p,
        level,
        meta={"g": g, "lpg": lpg, "spg": spg, "p": p,
              "global_per_spine": global_per_spine, "trunk": trunk},
    )


# ---------------------------------------------------------------------- #
# Jellyfish (random regular graph, Singla et al. — PAPERS.md)
# ---------------------------------------------------------------------- #
def _components(n: int, edges: np.ndarray) -> np.ndarray:
    """Connected-component label per vertex (union-find over edges)."""
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:          # path compression
            parent[x], x = root, parent[x]
        return root

    for a, b in edges:
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[rb] = ra
    return np.asarray([find(i) for i in range(n)], np.int64)


def jellyfish(
    n_switches: int,
    r: int,
    d: int,
    seed: int = 0,
    repair_passes: int = 200,
    name: Optional[str] = None,
) -> Topology:
    """Jellyfish random-regular-graph fabric (Singla et al.).

    ``n_switches`` switches, each with ``r`` ports wired to other switches
    and ``d`` endpoint ports (radix ``R = r + d``; every switch is a leaf,
    like the direct-network Dragonfly).  Construction is the configuration
    model — a seeded random perfect matching of the ``n*r`` port stubs —
    followed by two deterministic repair stages:

    * **simple-graph repair**: self-loops and parallel edges are broken by
      double-edge swaps against randomly chosen partner edges (the swap
      preserves every switch's degree);
    * **connectivity repair**: while more than one component remains, an
      edge inside the largest component and an edge inside another
      component are cross-swapped, merging the components without
      changing any degree.

    The whole pipeline draws from one ``np.random.default_rng(seed)``
    stream, so a (n_switches, r, d, seed) tuple names one exact graph.
    """
    if r < 2:
        raise ValueError(f"jellyfish needs r >= 2 network ports, got {r}")
    if r >= n_switches:
        raise ValueError(
            f"r = {r} must be < n_switches = {n_switches} (simple graph)")
    if (n_switches * r) % 2:
        raise ValueError(
            f"n_switches * r = {n_switches * r} must be even (each link "
            "consumes two port stubs)")
    if d < 1:
        raise ValueError(f"jellyfish needs d >= 1 endpoint ports, got {d}")
    rng = np.random.default_rng(seed)

    if r == n_switches - 1:
        # the only simple r-regular graph on n vertices is K_n — the
        # stub-matching repair cannot reach it, so build it directly
        iu = np.triu_indices(n_switches, k=1)
        edges = np.stack([iu[0], iu[1]], axis=1).astype(np.int64)
        return _from_edges(
            name or f"JF(R={r + d},S={n_switches * d},r={r})",
            "direct", n_switches, edges, np.ones(n_switches, bool), d,
            np.zeros(n_switches, np.int32), max_ports=r,
            meta={"r": r, "d": d, "R": r + d, "n_switches": n_switches,
                  "seed": seed})

    stubs = np.repeat(np.arange(n_switches, dtype=np.int64), r)
    rng.shuffle(stubs)
    edges = stubs.reshape(-1, 2)                  # [n*r/2, 2]

    # simple-graph repair: swap away self-loops and duplicate edges.
    for _ in range(repair_passes):
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n_switches + hi
        order = np.argsort(key, kind="stable")
        sk = key[order]
        bad = edges[:, 0] == edges[:, 1]          # self-loops
        bad[order[1:][sk[1:] == sk[:-1]]] = True  # parallel edges
        bad_idx = np.nonzero(bad)[0]
        if bad_idx.size == 0:
            break
        # double-edge swap: (a,b),(c,e) -> (a,e),(c,b).  Partner edges are
        # drawn at random; degrees are preserved unconditionally, and the
        # next pass re-checks whatever the swap produced.
        partners = rng.integers(0, edges.shape[0], size=bad_idx.size)
        for i, j in zip(bad_idx, partners):
            if i == j:
                continue
            edges[i, 1], edges[j, 1] = edges[j, 1], edges[i, 1]
    else:
        raise ValueError(
            f"jellyfish(n={n_switches}, r={r}, seed={seed}) could not be "
            f"repaired to a simple graph in {repair_passes} passes — the "
            "configuration is too dense; raise n_switches or lower r")

    # connectivity repair: cross-swap an in-component edge with an edge of
    # the largest component until one component remains.
    for _ in range(repair_passes):
        comp = _components(n_switches, edges)
        labels, counts = np.unique(comp, return_counts=True)
        if labels.size == 1:
            break
        main = labels[np.argmax(counts)]
        ec = comp[edges[:, 0]]                    # component of each edge
        inside = np.nonzero(ec != main)[0]
        anchor = np.nonzero(ec == main)[0]
        # swap the second endpoints: (a,b) in minor, (c,e) in main ->
        # (a,e),(c,b) bridges the two components, degrees unchanged.
        i = int(inside[rng.integers(0, inside.size)])
        j = int(anchor[rng.integers(0, anchor.size)])
        # avoid manufacturing a self-loop or duplicate; re-draw next pass
        if (edges[i, 0] == edges[j, 1] or edges[j, 0] == edges[i, 1]):
            continue
        edges[i, 1], edges[j, 1] = edges[j, 1], edges[i, 1]
    else:
        raise ValueError(
            f"jellyfish(n={n_switches}, r={r}, seed={seed}) could not be "
            f"connected in {repair_passes} swap passes")

    is_leaf = np.ones(n_switches, bool)
    level = np.zeros(n_switches, np.int32)
    return _from_edges(
        name or f"JF(R={r + d},S={n_switches * d},r={r})",
        "direct",
        n_switches,
        edges,
        is_leaf,
        d,
        level,
        max_ports=r,
        meta={"r": r, "d": d, "R": r + d, "n_switches": n_switches,
              "seed": seed},
    )
