"""The paper's primary contribution: MRLS topologies, multipass/Polarized
routing, analytic scalability machinery, and collective workloads."""
from .topology import (
    Topology, mrls, fat_tree, oft, dragonfly, dragonfly_plus, rfc, jellyfish,
)
from .routing import (
    bfs_distances, RoutingTables, TableDelta, build_tables, pack_port_masks,
    iter_port_mask_blocks, mask_table_bytes, polarized_port_mask,
    route_packet_host, find_corners, POLICIES, MASK_LAYOUTS,
    DENSE_MASK_LIMIT, UNREACHABLE,
)
from .failures import FailureEvent, FailureSchedule, canonical_link_ids
from .analytics import (
    Metrics, exact_metrics, theta, cost_links, cost_switches,
    mrls_distance_distribution, mrls_expected_A, mrls_expected_A_star,
    prob_dstar_leq, dstar_thresholds, mrls_design,
)
from .collectives import (
    all2all_rounds, rabenseifner_phases, ring_allreduce_phases,
    recursive_doubling_phases,
    all2all_lower_bound_slots, allreduce_lower_bound_slots,
)

# Canonical topology-family table: the string names the declarative layer
# (``repro.api``) resolves NetworkSpec.family against.  Kept here, next to
# the builders, so adding a topology automatically reaches every driver.
TOPOLOGY_BUILDERS = {
    "mrls": mrls,
    "fat_tree": fat_tree,
    "oft": oft,
    "dragonfly": dragonfly,
    "dragonfly_plus": dragonfly_plus,
    "rfc": rfc,
    "jellyfish": jellyfish,
}

__all__ = [k for k in dir() if not k.startswith("_")]
