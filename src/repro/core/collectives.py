"""Collective-communication workloads (Section 5.2.3 of the paper).

These produce *endpoint message programs* consumed by the simulator:

* :func:`all2all_rounds` — the All2All collective: round ``r`` pairs endpoint
  ``i`` with ``(i + r + 1) mod S`` (classic shifted exchange).  The paper runs
  the full ``S-1`` rounds; at 100K endpoints that is ~10^10 packets, so the
  benchmark scales the number of rounds (still globally uniform, completion
  bound) and reports ratios — see EXPERIMENTS.md.
* :func:`rabenseifner_phases` — Allreduce via Rabenseifner's algorithm [33]:
  recursive-halving reduce-scatter then recursive-doubling all-gather, with
  per-phase message sizes and XOR partners.  Ranks map linearly onto
  endpoints, so low-order-bit partners share a leaf switch — the locality
  that favors Fat-Trees (Section 6.1.3).

Also closed-form lower bounds used as sanity checks and by the fabric
planner (``repro.fabric``).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "all2all_rounds",
    "rabenseifner_phases",
    "ring_allreduce_phases",
    "recursive_doubling_phases",
    "all2all_lower_bound_slots",
    "allreduce_lower_bound_slots",
]


def all2all_rounds(S: int, n_rounds: int) -> np.ndarray:
    """[n_rounds, S] destination endpoint of endpoint ``i`` in round ``r``."""
    i = np.arange(S, dtype=np.int64)
    return np.stack([(i + r + 1) % S for r in range(n_rounds)], axis=0)


def rabenseifner_phases(n_ranks: int, vec_packets: int) -> list[dict]:
    """Phases for Rabenseifner's Allreduce over ``n_ranks`` (power of two).

    Returns a list of phases; each phase is
    ``{"partner": [S] endpoint ids, "packets": int}``.
    Message sizes: reduce-scatter phase p sends ``vec / 2^{p+1}``; all-gather
    phase p sends ``vec / 2^{log-p}`` — clamped to >= 1 packet when the
    (scaled) vector no longer divides.
    """
    log = int(np.log2(n_ranks))
    assert 2 ** log == n_ranks, "Rabenseifner requires power-of-two ranks"
    i = np.arange(n_ranks, dtype=np.int64)
    phases = []
    for p in range(log):                      # reduce-scatter (halving)
        phases.append({
            "partner": i ^ (1 << p),
            "packets": max(1, vec_packets >> (p + 1)),
        })
    for p in range(log):                      # all-gather (doubling)
        phases.append({
            "partner": i ^ (1 << (log - 1 - p)),
            "packets": max(1, vec_packets >> (log - p)),
        })
    return phases


def ring_allreduce_phases(n_ranks: int, vec_packets: int) -> list[dict]:
    """Phases for ring Allreduce over ``n_ranks`` (any count >= 2).

    ``2 * (n - 1)`` steps (reduce-scatter ring then all-gather ring); every
    step sends one ``vec / n`` chunk (clamped to >= 1 packet) to the next
    rank on the ring.  Bandwidth-optimal but latency-heavy — the classic
    counterpoint to Rabenseifner's log-depth schedule.
    """
    assert n_ranks >= 2, "ring allreduce needs at least 2 ranks"
    i = np.arange(n_ranks, dtype=np.int64)
    step = {"partner": (i + 1) % n_ranks,
            "packets": max(1, vec_packets // n_ranks)}
    return [dict(step) for _ in range(2 * (n_ranks - 1))]


def recursive_doubling_phases(n_ranks: int, vec_packets: int) -> list[dict]:
    """Phases for recursive-doubling Allreduce over ``n_ranks`` (power of
    two): ``log2(n)`` XOR-partner exchanges of the *full* vector —
    latency-optimal, bandwidth-redundant (the other end of the trade-off
    from :func:`ring_allreduce_phases`).
    """
    log = int(np.log2(n_ranks))
    assert 2 ** log == n_ranks, "recursive doubling requires power-of-two ranks"
    i = np.arange(n_ranks, dtype=np.int64)
    return [{"partner": i ^ (1 << p), "packets": max(1, vec_packets)}
            for p in range(log)]


# ---------------------------------------------------------------------- #
# closed-form bounds (used as sanity floors & by the fabric planner)
# ---------------------------------------------------------------------- #
def all2all_lower_bound_slots(S: int, n_rounds: int, theta: float) -> float:
    """Each endpoint must send+receive ``n_rounds`` packets; the fabric
    sustains at most ``min(1, theta)`` packets/slot/endpoint under uniform
    traffic (Eq. 1)."""
    return n_rounds / min(1.0, theta)


def allreduce_lower_bound_slots(n_ranks: int, vec_packets: int, theta: float) -> float:
    total = sum(ph["packets"] for ph in rabenseifner_phases(n_ranks, vec_packets))
    return total / min(1.0, theta)
