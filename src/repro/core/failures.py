"""Deterministic failure schedules for fault injection.

A :class:`FailureSchedule` is a frozen, JSON-round-tripped list of
:class:`FailureEvent`'s attached to a ``NetworkSpec``.  Each event takes
one element (a link or a switch) down at ``down_slot`` and, optionally,
back up at ``up_slot``.  Schedules are validated against the topology
before any simulator is built: link ids must name real ports, switch ids
must name real *non-leaf* switches (leaves host the inject/eject
endpoints and cannot die — that keeps the engine's inject/eject paths
ungated).

Link identity
-------------
A link id is the flat *directed* port index ``c * P + p`` (switch ``c``,
port ``p``, with ``P = topo.max_ports``).  Either direction of an
undirected link names the same physical link; applying a failure marks
both directions dead via ``topo.nbr_port``.  The random constructors
enumerate each undirected link once, through its canonical direction —
the endpoint whose ``(switch, port)`` pair is lexicographically smaller
(well-defined even for multi-edges, since reciprocity pairs ports).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import numpy as np

__all__ = ["FailureEvent", "FailureSchedule", "canonical_link_ids"]

_KINDS = ("link", "switch")
_POLICIES = ("requeue", "drop")


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One element going down (and optionally back up).

    ``kind``      — ``"link"`` or ``"switch"``.
    ``id``        — flat directed port index ``c*P + p`` for links,
                    switch index for switches.
    ``down_slot`` — slot at whose *boundary* the element goes down
                    (applied before the slot executes).
    ``up_slot``   — slot at whose boundary it comes back up; ``-1``
                    means it never recovers.
    """
    kind: str
    id: int
    down_slot: int
    up_slot: int = -1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if self.id < 0:
            raise ValueError(f"id must be >= 0, got {self.id}")
        if self.down_slot < 0:
            raise ValueError(f"down_slot must be >= 0, got {self.down_slot}")
        if self.up_slot != -1 and self.up_slot <= self.down_slot:
            raise ValueError(
                f"up_slot must be -1 (never) or > down_slot "
                f"({self.down_slot}), got {self.up_slot}")

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "id": self.id, "down_slot": self.down_slot}
        if self.up_slot != -1:
            d["up_slot"] = self.up_slot
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FailureEvent":
        return cls(kind=d["kind"], id=int(d["id"]),
                   down_slot=int(d["down_slot"]),
                   up_slot=int(d.get("up_slot", -1)))


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Frozen, hashable set of failure events plus a packet policy.

    ``policy`` governs packets caught on a downed element:
    ``"requeue"`` leaves them queued (they stall until the element
    recovers or, under ``policy="degraded"`` routing, are re-routed on
    their next hop); ``"drop"`` frees them immediately and counts them
    in the ``fail_drop`` counter.
    """
    events: Tuple[FailureEvent, ...] = ()
    policy: str = "requeue"

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if self.policy not in _POLICIES:
            raise ValueError(
                f"policy must be one of {_POLICIES}, got {self.policy!r}")

    def __len__(self) -> int:
        return len(self.events)

    # -- validation ------------------------------------------------------
    def validate(self, topo) -> "FailureSchedule":
        """Check every event names a real element of ``topo``.

        Returns ``self`` so calls chain.  Raises ``ValueError`` on a bad
        id: link ids must be flat indices of *connected* ports, switch
        ids must be non-leaf switches.
        """
        n, p = topo.n_switches, topo.max_ports
        for ev in self.events:
            if ev.kind == "link":
                if ev.id >= n * p:
                    raise ValueError(
                        f"link id {ev.id} out of range for {n} switches "
                        f"x {p} ports")
                if topo.nbrs[ev.id // p, ev.id % p] < 0:
                    raise ValueError(
                        f"link id {ev.id} names an unconnected port "
                        f"(switch {ev.id // p}, port {ev.id % p})")
            else:
                if ev.id >= n:
                    raise ValueError(
                        f"switch id {ev.id} out of range for {n} switches")
                if topo.is_leaf[ev.id]:
                    raise ValueError(
                        f"switch id {ev.id} is a leaf; leaves host "
                        "endpoints and cannot fail")
        return self

    # -- slot-ordered transitions ---------------------------------------
    def transitions(self):
        """Yield ``(slot, downs, ups)`` sorted by slot.

        ``downs``/``ups`` are tuples of events changing state at that
        slot boundary (an event appears in ``downs`` at its
        ``down_slot`` and in ``ups`` at its ``up_slot``).
        """
        by_slot = {}
        for ev in self.events:
            by_slot.setdefault(ev.down_slot, ([], []))[0].append(ev)
            if ev.up_slot != -1:
                by_slot.setdefault(ev.up_slot, ([], []))[1].append(ev)
        return [(slot, tuple(downs), tuple(ups))
                for slot, (downs, ups) in sorted(by_slot.items())]

    # -- JSON ------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"events": [ev.to_dict() for ev in self.events],
                "policy": self.policy}

    @classmethod
    def from_dict(cls, d: dict) -> "FailureSchedule":
        return cls(events=tuple(FailureEvent.from_dict(e)
                                for e in d.get("events", ())),
                   policy=d.get("policy", "requeue"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FailureSchedule":
        return cls.from_dict(json.loads(s))

    # -- constructors ----------------------------------------------------
    @classmethod
    def random_links(cls, topo, count: int, down_slot: int,
                     up_slot: int = -1, seed: int = 0,
                     policy: str = "requeue") -> "FailureSchedule":
        """``count`` distinct links, uniform over the undirected links,
        all down at ``down_slot`` (and back at ``up_slot`` if given)."""
        ids = canonical_link_ids(topo)
        if count > len(ids):
            raise ValueError(
                f"asked for {count} failed links but topology has only "
                f"{len(ids)}")
        rng = np.random.default_rng(seed)
        pick = rng.choice(len(ids), size=count, replace=False)
        events = tuple(FailureEvent("link", int(ids[i]), down_slot, up_slot)
                       for i in sorted(pick))
        return cls(events=events, policy=policy)

    @classmethod
    def random_ladder(cls, topo, count: int, start_slot: int,
                      step_slots: int, seed: int = 0, up_slot: int = -1,
                      policy: str = "requeue") -> "FailureSchedule":
        """``count`` distinct links going down one at a time: link ``k``
        fails at ``start_slot + k * step_slots``."""
        ids = canonical_link_ids(topo)
        if count > len(ids):
            raise ValueError(
                f"asked for {count} failed links but topology has only "
                f"{len(ids)}")
        if step_slots <= 0:
            raise ValueError(f"step_slots must be > 0, got {step_slots}")
        rng = np.random.default_rng(seed)
        pick = rng.choice(len(ids), size=count, replace=False)
        events = tuple(
            FailureEvent("link", int(ids[i]), start_slot + k * step_slots,
                         up_slot)
            for k, i in enumerate(pick))
        return cls(events=events, policy=policy)


def canonical_link_ids(topo) -> np.ndarray:
    """Flat directed port ids, one per undirected link.

    The canonical direction is the endpoint with the lexicographically
    smaller ``(switch, port)`` pair — well-defined for multi-edges since
    ``nbr_port`` pairs ports one-to-one.
    """
    n, p = topo.n_switches, topo.max_ports
    c = np.repeat(np.arange(n, dtype=np.int64), p)
    pt = np.tile(np.arange(p, dtype=np.int64), n)
    nb = topo.nbrs.reshape(-1).astype(np.int64)
    nbp = topo.nbr_port.reshape(-1).astype(np.int64)
    conn = nb >= 0
    smaller = (c < nb) | ((c == nb) & (pt < nbp))
    return np.nonzero(conn & smaller)[0]
