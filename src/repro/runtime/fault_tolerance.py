"""Fault tolerance for thousand-node runs: checkpoint-restart, straggler
detection, elastic remeshing.

At 100K-endpoint scale (the paper's regime) node failure is the steady
state, not an exception.  The runner treats a training job as a pure
function of (checkpoint, data cursor):

* every ``ckpt_every`` steps: async checkpoint (params, opt state, step);
* on step failure (device loss, NaN-poisoned gradients, injected faults):
  restore the latest checkpoint, rebuild the step data cursor (the data
  pipeline is counter-based, so replay is exact) and continue;
* straggler detection: per-step wall-time EMA + deviation; a step slower
  than ``straggler_z`` sigmas is flagged and counted — the launcher's
  response at scale is re-sharding around the slow host (elastic remesh),
  which is exercised in tests via :func:`elastic_reshard`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpointing.checkpoint import Checkpointer


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff with *deterministic* jitter.

    The delay for a retry is ``base_s * factor**(consecutive-1)`` capped
    at ``cap_s``, scaled by a jitter factor drawn from a PRNG seeded on
    ``(seed, total)`` — the total failure count is a monotonic counter,
    so the decision path contains no wall-clock reads (``time.time()``
    never feeds the schedule) and two runs that fail the same way sleep
    the same amounts.  Jitter de-synchronizes worker herds without
    sacrificing replayability.
    """

    base_s: float = 0.5
    factor: float = 2.0
    cap_s: float = 30.0
    jitter: float = 0.1      # +/- fraction of the delay
    seed: int = 0

    def delay(self, consecutive: int, total: int) -> float:
        """Sleep before retry number ``consecutive`` (1-based, consecutive
        failures since the last success); ``total`` is the lifetime
        failure count, used only to decorrelate the jitter draw."""
        d = min(self.base_s * self.factor ** max(int(consecutive) - 1, 0),
                self.cap_s)
        if self.jitter:
            u = np.random.default_rng((self.seed, int(total))).random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return float(d)


@dataclasses.dataclass
class FTConfig:
    ckpt_every: int = 50
    max_retries: int = 3            # total failures tolerated per run()
    max_consecutive: Optional[int] = None   # default: same as max_retries
    backoff: BackoffPolicy = BackoffPolicy()
    straggler_z: float = 3.0
    ema: float = 0.9

    @property
    def consecutive_limit(self) -> int:
        return (self.max_retries if self.max_consecutive is None
                else self.max_consecutive)


class StragglerDetector:
    WARMUP = 5      # observations before flagging

    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.mean = None
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        sd = max(math.sqrt(self.var), 0.05 * self.mean, 1e-9)
        is_straggler = (self.n > self.WARMUP
                        and dt > self.mean + self.cfg.straggler_z * sd)
        a = self.cfg.ema
        # residual against the PRE-update mean: updating the mean first
        # shrinks the residual by the blend factor and biases the
        # variance EMA low, so slow-but-steady drift never widens sd
        resid = dt - self.mean
        self.mean = a * self.mean + (1 - a) * dt
        self.var = a * self.var + (1 - a) * resid ** 2
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


def schedule_fault_hook(sim, holder, *, slots_per_step: int = 1):
    """Bridge a simulator :class:`repro.core.FailureSchedule` onto
    :attr:`FaultTolerantRunner.fault_hook` — the documented injection
    point for schedule-driven failures.

    ``sim`` must be armed with a non-empty schedule and ``holder`` is a
    one-element list carrying the live simulator state dict (the hook
    replaces it in place, since ``update_tables`` donates).  Before the
    runner executes step ``k``, every failure transition whose slot
    falls at or before ``(k + 1) * slots_per_step`` is applied: routing
    tables are delta-rebuilt on the host and scattered into the device
    state, and under the ``drop`` policy packets stranded on dead
    elements are freed.  The returned hook is what tests (and launchers)
    pass as ``fault_hook=``.
    """
    if not getattr(sim, "has_failures", False):
        raise ValueError("schedule_fault_hook needs a simulator armed "
                         "with a non-empty FailureSchedule")
    trans = sim.failures.transitions()
    drop = sim.failures.policy == "drop"
    cursor = [0]

    def hook(step: int) -> None:
        boundary = (step + 1) * slots_per_step
        while cursor[0] < len(trans) and trans[cursor[0]][0] <= boundary:
            _, downs, ups = trans[cursor[0]]
            delta = sim.tables.apply_failures(down=downs, up=ups)
            holder[0] = sim.update_tables(holder[0], delta)
            if drop and downs:
                holder[0] = sim.drop_dead_packets(holder[0])
            cursor[0] += 1

    return hook


class FaultTolerantRunner:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` with
    checkpoint-restart.  ``state`` is any pytree containing the trainable
    state; ``batch_at(step)`` must be pure (counter-based pipeline).

    ``fault_hook(step)`` runs *before* each step attempt and is the
    injection point for failures: tests raise from it to exercise
    restore, and :func:`schedule_fault_hook` adapts a simulator
    :class:`repro.core.FailureSchedule` to it so link/switch failures
    land on the training-step clock.

    Failures are counted on two clocks: ``total_failures`` (lifetime of
    the ``run()``, bounded by ``cfg.max_retries``) and
    ``consecutive_failures`` (reset by any successful step, bounded by
    ``cfg.max_consecutive``) — a long job that hits scattered transients
    keeps going, while a hard-wedged step still fails fast.  Before each
    restore the runner sleeps ``cfg.backoff.delay(consecutive, total)``
    (deterministic jitter, no wall-clock in the schedule); ``sleep_fn``
    is injectable so tests assert the exact delays without sleeping."""

    def __init__(self, step_fn: Callable, batch_at: Callable,
                 ckpt: Checkpointer, cfg: FTConfig = FTConfig(),
                 fault_hook: Optional[Callable[[int], None]] = None,
                 shardings=None,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.step_fn = step_fn
        self.batch_at = batch_at
        self.ckpt = ckpt
        self.cfg = cfg
        self.fault_hook = fault_hook          # tests inject failures here
        self.shardings = shardings
        self.sleep_fn = sleep_fn
        self.stragglers = StragglerDetector(cfg)
        self.total_failures = 0
        self.consecutive_failures = 0
        self.delays: list[float] = []         # backoff actually applied

    @property
    def restarts(self) -> int:
        """Lifetime failure count (back-compat alias)."""
        return self.total_failures

    def _check_health(self, metrics: dict):
        loss = metrics.get("loss")
        if loss is not None and not np.isfinite(float(loss)):
            raise FloatingPointError(f"non-finite loss {loss}")

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        history = []
        while step < start_step + n_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.perf_counter()
                batch = self.batch_at(step)
                state, metrics = self.step_fn(state, batch)
                self._check_health(metrics)
                dt = time.perf_counter() - t0
                self.stragglers.observe(step, dt)
                history.append({k: float(v) for k, v in metrics.items()})
                step += 1
                self.consecutive_failures = 0
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save_async(step, state)
            except Exception:
                self.total_failures += 1
                self.consecutive_failures += 1
                if (self.total_failures > self.cfg.max_retries
                        or self.consecutive_failures
                        > self.cfg.consecutive_limit):
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise
                delay = self.cfg.backoff.delay(self.consecutive_failures,
                                               self.total_failures)
                self.delays.append(delay)
                if delay > 0:
                    self.sleep_fn(delay)
                state, meta = self.ckpt.restore(state, latest,
                                                self.shardings)
                step = meta["step"]
        self.ckpt.wait()
        return state, step, history


def elastic_reshard(tree, new_sharder, specs):
    """Re-place a state tree onto a (possibly different-size) mesh —
    the recovery path after losing a slice of the machine."""
    from ..models.common import param_shardings
    shd = param_shardings(specs, new_sharder)
    return jax.tree.map(jax.device_put, tree, shd)
