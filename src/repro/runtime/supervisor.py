"""Subprocess supervisor: watchdog + RSS budget + kill-and-resume.

Wraps a worker command (a ``benchmarks/bench_scale.py``-style subprocess
that checkpoints its own progress) with the failure handling a
100k-endpoint run needs:

* **wall-clock watchdog** — a worker that stops making progress is
  SIGKILLed at ``timeout_s``;
* **peak-RSS polling** — ``/proc/<pid>/status`` ``VmRSS``/``VmHWM`` is
  sampled every ``poll_interval_s`` and the worker is SIGKILLed the
  moment resident memory crosses ``rss_budget_bytes`` — the supervisor
  kills one worker instead of letting the kernel OOM-killer pick a
  victim (or the host start swapping);
* **admission preflight** — ``run(..., predicted_bytes=...)`` refuses to
  even start a worker whose predicted footprint exceeds the budget
  (see :mod:`repro.api.admission` for the prediction);
* **retry with deterministic backoff** — failed/killed attempts are
  retried up to ``max_retries`` times, sleeping
  :meth:`BackoffPolicy.delay` between attempts.  Because the worker
  resumes from its checkpoint directory, a retry continues the run
  rather than restarting it — and the resilient drivers make the
  resumed result bitwise-identical.

Chaos hook: ``inject_kill_s`` SIGKILLs the *first* attempt after a fixed
delay — CI uses it to prove the kill-resume path end to end.

Everything is stdlib + ``/proc`` (no psutil dependency).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import time
from typing import Callable, Optional, Sequence

from .fault_tolerance import BackoffPolicy

__all__ = ["SupervisorConfig", "WorkerAttempt", "SupervisedResult",
           "AdmissionRefused", "read_rss", "Supervisor"]


class AdmissionRefused(RuntimeError):
    """The predicted memory footprint exceeds the budget; the worker was
    never started."""


def read_rss(pid: int) -> tuple[Optional[int], Optional[int]]:
    """``(VmRSS, VmHWM)`` in bytes from ``/proc/<pid>/status``; ``(None,
    None)`` once the process is gone (or on non-Linux hosts)."""
    rss = hwm = None
    try:
        with open(f"/proc/{pid}/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    rss = int(line.split()[1]) * 1024
                elif line.startswith("VmHWM:"):
                    hwm = int(line.split()[1]) * 1024
    except OSError:
        pass
    return rss, hwm


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    timeout_s: Optional[float] = None        # wall-clock watchdog per attempt
    rss_budget_bytes: Optional[int] = None   # SIGKILL above this resident set
    poll_interval_s: float = 0.25
    max_retries: int = 3                     # attempts = 1 + max_retries
    backoff: BackoffPolicy = BackoffPolicy()
    inject_kill_s: Optional[float] = None    # chaos: kill attempt 1 after this


@dataclasses.dataclass
class WorkerAttempt:
    """Outcome of one subprocess attempt."""

    returncode: Optional[int]
    wall_s: float
    peak_rss_bytes: Optional[int]
    killed: Optional[str] = None    # None | "timeout" | "rss" | "injected"

    @property
    def ok(self) -> bool:
        return self.returncode == 0 and self.killed is None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SupervisedResult:
    ok: bool
    attempts: list
    total_wall_s: float

    @property
    def retries(self) -> int:
        return max(len(self.attempts) - 1, 0)

    @property
    def peak_rss_bytes(self) -> Optional[int]:
        vals = [a.peak_rss_bytes for a in self.attempts
                if a.peak_rss_bytes is not None]
        return max(vals) if vals else None

    def to_dict(self) -> dict:
        return {"ok": self.ok, "retries": self.retries,
                "total_wall_s": self.total_wall_s,
                "peak_rss_bytes": self.peak_rss_bytes,
                "attempts": [a.to_dict() for a in self.attempts]}


class Supervisor:
    """Run worker commands under watchdog/RSS/retry supervision.

    ``sleep_fn``/``clock`` are injectable for tests (the backoff decision
    path itself is deterministic — see :class:`BackoffPolicy`).
    """

    def __init__(self, cfg: SupervisorConfig = SupervisorConfig(), *,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 popen: Callable = subprocess.Popen):
        self.cfg = cfg
        self.sleep_fn = sleep_fn
        self.popen = popen

    # ------------------------------------------------------------------ #
    def _kill(self, proc) -> None:
        try:
            proc.send_signal(signal.SIGKILL)
        except (ProcessLookupError, OSError):  # pragma: no cover - raced exit
            pass
        proc.wait()

    def _attempt(self, argv: Sequence[str], first: bool, *,
                 env=None, cwd=None) -> WorkerAttempt:
        cfg = self.cfg
        t0 = time.monotonic()
        proc = self.popen(list(argv), env=env, cwd=cwd)
        peak: Optional[int] = None
        injected = cfg.inject_kill_s if first else None
        killed = None
        while True:
            rc = proc.poll()
            if rc is not None:
                break
            rss, hwm = read_rss(proc.pid)
            cand = hwm if hwm is not None else rss
            if cand is not None:
                peak = cand if peak is None else max(peak, cand)
            elapsed = time.monotonic() - t0
            if injected is not None and elapsed >= injected:
                killed = "injected"
            elif (cfg.rss_budget_bytes is not None and cand is not None
                    and cand > cfg.rss_budget_bytes):
                killed = "rss"
            elif cfg.timeout_s is not None and elapsed >= cfg.timeout_s:
                killed = "timeout"
            if killed is not None:
                self._kill(proc)
                rc = proc.returncode
                break
            time.sleep(cfg.poll_interval_s)
        return WorkerAttempt(returncode=rc,
                             wall_s=time.monotonic() - t0,
                             peak_rss_bytes=peak, killed=killed)

    # ------------------------------------------------------------------ #
    def run(self, argv: Sequence[str], *, env=None, cwd=None,
            predicted_bytes: Optional[int] = None) -> SupervisedResult:
        """Run ``argv`` to success, retrying with backoff on failure.

        ``predicted_bytes`` (from admission control) is checked against
        the RSS budget *before* the first attempt: a worker predicted to
        blow the budget raises :class:`AdmissionRefused` instead of being
        started and OOM-killed ``max_retries + 1`` times.

        The command must be idempotent-resumable (e.g. carry a
        ``--ckpt`` directory): the supervisor re-execs the same argv and
        relies on the worker to pick up its own checkpoints.
        """
        cfg = self.cfg
        if (predicted_bytes is not None and cfg.rss_budget_bytes is not None
                and predicted_bytes > cfg.rss_budget_bytes):
            raise AdmissionRefused(
                f"predicted peak RSS {predicted_bytes} B exceeds the "
                f"supervisor budget {cfg.rss_budget_bytes} B; not starting "
                "the worker.  Shrink the spec (fewer replicas, smaller "
                "chunk, masks='blocked') or raise the budget.")
        attempts: list[WorkerAttempt] = []
        total = 0
        t0 = time.monotonic()
        while True:
            att = self._attempt(argv, first=not attempts, env=env, cwd=cwd)
            attempts.append(att)
            if att.ok:
                return SupervisedResult(ok=True, attempts=attempts,
                                        total_wall_s=time.monotonic() - t0)
            total += 1
            if total > cfg.max_retries:
                return SupervisedResult(ok=False, attempts=attempts,
                                        total_wall_s=time.monotonic() - t0)
            self.sleep_fn(cfg.backoff.delay(total, total))
