"""Resumable run-to-completion drivers: kill -9 loses a segment, not a run.

The engine's device loops (``run_program`` / ``run_completion`` /
``run_chunk``) donate their state pytrees — once a ``lax.while_loop``
owns the buffers, the host has nothing to save and nothing to resume.
This module re-drives those loops in **bounded segments**
(``budget_chunks`` chunk bodies, or a fixed slot count for the windowed
metrics) and snapshots the state dict through
:class:`repro.checkpointing.Checkpointer` at every segment boundary —
atomic rename, bounded retention, dtype-view handling for the bit-packed
``p_sd``/``p_bh`` and ``uint32`` mask arrays.

Bitwise contract
----------------
A bounded segment's chunk body is byte-for-byte the unbounded loop's
(the budget only adds an iteration counter to the carry), and the
snapshot is taken from the *returned* state before the next donating
call, so:

* a chain of segments equals one unbounded call, bitwise;
* a run SIGKILLed between (or during) segments and resumed from the
  latest checkpoint replays the remaining segments bitwise — the PRNG
  ``key``, phase pointers, queue rings, and free-list all ride in the
  snapshot;
* a checkpoint interrupted mid-write is discarded by the atomic-rename
  protocol, so resume falls back to the previous boundary.

What is (and is not) in a snapshot: the full engine state dict (plus the
``done`` completion-slot array for ``run_completion`` and the
measurement-window base counters for the windowed drivers) — but never
the routing tables of an *unarmed* simulator, the compiled program
arrays' identity, or the jit cache; those are rebuilt deterministically
from the spec on resume.  A fingerprint of the run configuration is
stored in the checkpoint meta and validated on restore, so resuming with
a different spec fails loudly instead of silently diverging.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import numpy as np

from ..checkpointing.checkpoint import Checkpointer
from ..simulator.engine import LATENCY_QS, Traffic, percentiles

__all__ = ["ResilientConfig", "open_checkpointer", "run_program_resumable",
           "run_completion_resumable", "run_window_resumable"]


@dataclasses.dataclass(frozen=True)
class ResilientConfig:
    """Segmenting/retention knobs shared by the resumable drivers.

    ``every`` is the segment length: chunk bodies per device call for the
    program/completion loops, slots per device call for the windowed
    metrics.  Smaller = finer resume granularity, more host round-trips
    and snapshot I/O; the results are bitwise identical either way.
    """

    every: int = 64
    keep: int = 3

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")


def open_checkpointer(ckpt: Union[str, Checkpointer],
                      keep: int = 3) -> Checkpointer:
    if isinstance(ckpt, Checkpointer):
        return ckpt
    return Checkpointer(ckpt, keep=keep)


def _traffic_desc(traffic: Traffic) -> str:
    # Traffic is a frozen dataclass of scalars: repr is deterministic and
    # captures every field that shapes the run
    return repr(traffic)


def _seed_desc(seed: int, seeds) -> Union[int, list]:
    return [int(s) for s in seeds] if seeds is not None else int(seed)


def _check_fingerprint(meta: dict, fp: dict, where: str) -> None:
    got = meta.get("fingerprint")
    if got != fp:
        diff = {k: (got.get(k) if isinstance(got, dict) else None, fp[k])
                for k in fp
                if not isinstance(got, dict) or got.get(k) != fp[k]}
        raise ValueError(
            f"checkpoint in {where} was written by a different run "
            f"configuration; refusing to resume (mismatched fields: "
            f"{diff}).  Point --ckpt-dir at a fresh directory or rerun "
            "with the original spec.")


def _host_state(st: dict) -> dict:
    """One host transfer of a state dict (fresh numpy buffers — the
    device state is about to be donated to the next segment)."""
    return {k: np.asarray(v) for k, v in jax.device_get(st).items()}


# ---------------------------------------------------------------------- #
# collective programs
# ---------------------------------------------------------------------- #
def run_program_resumable(sim, program, *, ckpt, chunk: int = 16,
                          max_slots: int = 60_000, seed: int = 0,
                          seeds=None,
                          config: ResilientConfig = ResilientConfig()) -> dict:
    """:meth:`Simulator.run_program`, checkpointed at every ``every``-chunk
    boundary.  Returns the engine result dict plus ``segments`` (device
    calls this invocation) and ``resumed_from`` (checkpoint step picked
    up, ``None`` for a fresh run).  Bitwise identical to the unbounded
    call, interrupted or not.
    """
    ck = open_checkpointer(ckpt, config.keep)
    fp = {"kind": "program", "chunk": int(chunk),
          "max_slots": int(max_slots), "every": int(config.every),
          "schedule": program.schedule, "window": int(program.window),
          "n_phases": int(program.n_phases), "S": int(sim.S),
          "seed": _seed_desc(seed, seeds)}
    st0 = (sim.make_program_batch_state(program, seeds)
           if seeds is not None else sim.make_program_state(program, seed))
    latest = ck.latest_step()
    seg, resumed = 0, None
    if latest is not None:
        tree, meta = ck.restore({"state": st0}, latest)
        _check_fingerprint(meta, fp, ck.dir)
        st, seg, resumed = tree["state"], int(meta["segment"]), latest
    else:
        st = st0
    running = True
    while running:
        r = sim.run_program(program, chunk=chunk, max_slots=max_slots,
                            state=st, budget_chunks=config.every)
        st, running = r["state"], r["running"]
        seg += 1
        ck.save(seg, {"state": _host_state(st)},
                meta={"fingerprint": fp, "segment": seg,
                      "running": bool(running)})
    out = dict(r)
    out["segments"] = seg
    out["resumed_from"] = resumed
    return out


# ---------------------------------------------------------------------- #
# free-running completion (legacy all2all)
# ---------------------------------------------------------------------- #
def run_completion_resumable(sim, traffic: Traffic, expected: int, *, ckpt,
                             chunk: int = 128, max_slots: int = 100_000,
                             seed: int = 0, seeds=None,
                             config: ResilientConfig = ResilientConfig()
                             ) -> dict:
    """:meth:`Simulator.run_completion` in checkpointed segments.  The
    per-replica ``done`` completion-slot array is part of every snapshot,
    so a resumed run keeps the exact slots already recorded."""
    ck = open_checkpointer(ckpt, config.keep)
    fp = {"kind": "completion", "chunk": int(chunk),
          "max_slots": int(max_slots), "every": int(config.every),
          "expected": int(expected), "S": int(sim.S),
          "traffic": _traffic_desc(traffic),
          "seed": _seed_desc(seed, seeds)}
    st0 = (sim.make_batch_state(traffic, seeds) if seeds is not None
           else sim.make_state(traffic, seed))
    done0 = np.full_like(np.asarray(st0["ejected"]), -1)
    latest = ck.latest_step()
    seg, resumed = 0, None
    if latest is not None:
        tree, meta = ck.restore({"state": st0, "done": done0}, latest)
        _check_fingerprint(meta, fp, ck.dir)
        st, done = tree["state"], tree["done"]
        seg, resumed = int(meta["segment"]), latest
    else:
        st, done = st0, done0
    running = True
    while running:
        r = sim.run_completion(traffic, expected, chunk=chunk,
                               max_slots=max_slots, state=st,
                               budget_chunks=config.every, done=done)
        st, done, running = r["state"], r["done"], r["running"]
        seg += 1
        ck.save(seg, {"state": _host_state(st), "done": np.asarray(done)},
                meta={"fingerprint": fp, "segment": seg,
                      "running": bool(running)})
    out = dict(r)
    out["segments"] = seg
    out["resumed_from"] = resumed
    return out


# ---------------------------------------------------------------------- #
# windowed metrics (throughput / latency / serving)
# ---------------------------------------------------------------------- #
# every window metric's base snapshot is a subset of these state counters
_WINDOW_COUNTERS = ("ejected", "hop_sum", "pool_stall", "lat_hist",
                    "arrived", "arr_drop")
_SERVING_KEYS = ("lat_hist", "ejected", "arrived", "arr_drop", "pool_stall")


def run_window_resumable(sim, traffic: Traffic, *, metric: str, ckpt,
                         warm: int = 200, measure: int = 400, seed: int = 0,
                         seeds=None,
                         config: ResilientConfig = ResilientConfig()) -> dict:
    """``run_throughput`` / ``run_latency`` / ``run_serving`` in
    checkpointed ``every``-slot segments.

    The warm/measure structure is preserved exactly: segments never cross
    the warm boundary, the base counter snapshot taken there is part of
    every later checkpoint, and the final window deltas are computed from
    the same integer counters the engine drivers subtract on device — so
    the returned metrics match the one-shot drivers bitwise.
    """
    if metric not in ("throughput", "latency", "serving"):
        raise ValueError(f"run_window_resumable supports "
                         f"throughput/latency/serving, got {metric!r}")
    if metric == "serving" and traffic.pattern != "arrival":
        raise ValueError(f"serving needs Traffic('arrival'), got "
                         f"{traffic.pattern!r}")
    ck = open_checkpointer(ckpt, config.keep)
    batched = seeds is not None
    fp = {"kind": "window", "metric": metric, "warm": int(warm),
          "measure": int(measure), "every": int(config.every),
          "S": int(sim.S), "traffic": _traffic_desc(traffic),
          "seed": _seed_desc(seed, seeds)}
    st0 = (sim.make_batch_state(traffic, seeds) if batched
           else sim.make_state(traffic, seed))
    keys = tuple(k for k in _WINDOW_COUNTERS if k in st0)
    base0 = {k: np.zeros_like(np.asarray(st0[k])) for k in keys}
    latest = ck.latest_step()
    cursor, seg, resumed, base = 0, 0, None, None
    if latest is not None:
        tree, meta = ck.restore({"state": st0, "base": base0}, latest)
        _check_fingerprint(meta, fp, ck.dir)
        st = tree["state"]
        base = tree["base"] if meta["has_base"] else None
        cursor, seg, resumed = int(meta["cursor"]), int(meta["segment"]), \
            latest
    else:
        st = st0
    advance = sim.run_chunk_batch if batched else sim.run_chunk
    total = warm + measure

    def save(running: bool):
        ck.save(seg, {"state": _host_state(st), "base": base or base0},
                meta={"fingerprint": fp, "segment": seg, "cursor": cursor,
                      "has_base": base is not None,
                      "running": bool(running)})

    while True:
        if cursor >= warm and base is None:
            # the measurement-window base: same counters the engine
            # drivers snapshot (`st[k] + 0`) before the measure chunk
            base = {k: np.asarray(jax.device_get(st[k])) for k in keys}
            seg += 1
            save(running=cursor < total)
        if cursor >= total:
            break
        bound = warm if cursor < warm else total
        n = min(config.every, bound - cursor)
        st = advance(st, traffic, n)
        cursor += n
        if cursor < warm or base is not None:
            # (at the warm boundary the save above covers this segment)
            seg += 1
            save(running=cursor < total)

    sth = _host_state(st)
    m = {k: sth[k] - base[k] for k in keys}
    S = sim.S
    extra = {"state": st, "segments": seg, "resumed_from": resumed}
    if metric == "throughput":
        e, h = m["ejected"], m["hop_sum"]
        if batched:
            return {"throughput": e / (S * measure),
                    "avg_hops": h / np.maximum(e, 1),
                    "ejected": sth["ejected"],
                    "pool_stall": m["pool_stall"], **extra}
        return {"throughput": int(e) / (S * measure),
                "avg_hops": int(h) / max(int(e), 1),
                "ejected": int(sth["ejected"]),
                "pool_stall": int(m["pool_stall"]), **extra}
    if metric == "latency":
        hist = m["lat_hist"]
        if batched:
            per = [percentiles(row, LATENCY_QS) for row in hist]
            out = {"hist": hist, **extra}
            for q in LATENCY_QS:
                k = f"p{q}"
                out[k] = np.asarray([p[k] for p in per])
            return out
        return {"hist": hist, **percentiles(hist, LATENCY_QS), **extra}
    serving = {k: m[k] for k in _SERVING_KEYS}
    return {**sim._serving_metrics(serving, S, measure), **extra}
