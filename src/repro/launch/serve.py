"""Batched serving driver: prefill + decode loop with a KV/SSM cache.

``ServeSession`` holds the jitted prefill/decode steps; ``generate`` runs
greedy decoding for a batch of prompts (one shared position cursor —
continuous batching is approximated by fixed-width batches, the same
simplification the decode shape cells use).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced as reduce_cfg
from ..models.common import init_params
from ..models.model import build_specs, prefill, decode_step
from ..parallel.sharding import Sharder
from .mesh import make_test_mesh


class ServeSession:
    def __init__(self, cfg, sh: Sharder, params=None, key=None):
        self.cfg, self.sh = cfg, sh
        self.params = params if params is not None else init_params(
            build_specs(cfg), key or jax.random.PRNGKey(0), sh)
        self._prefill = jax.jit(lambda p, b: prefill(p, b, cfg, sh))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, sh))

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 ctx=None) -> np.ndarray:
        """prompts: [B, S] int32 -> [B, max_new] greedy tokens."""
        batch = {"tokens": jnp.asarray(prompts)}
        if ctx is not None:
            batch["ctx"] = ctx
        logits, cache = self._prefill(self.params, batch)
        pos = prompts.shape[1]
        tok = jnp.argmax(logits[:, -1:, : self.cfg.vocab], axis=-1)
        out = [tok]
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         tok.astype(jnp.int32),
                                         jnp.int32(pos + i))
            tok = jnp.argmax(logits[:, :, : self.cfg.vocab], axis=-1)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_test_mesh()
    sh = Sharder(mesh)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    ctx = None
    if cfg.n_ctx_tokens:
        ctx = jnp.asarray(rng.normal(size=(args.batch, cfg.n_ctx_tokens,
                                           cfg.d_model)), jnp.bfloat16)
    with jax.set_mesh(mesh):
        sess = ServeSession(cfg, sh)
        t0 = time.time()
        toks = sess.generate(prompts, args.max_new, ctx)
    print(json.dumps({"arch": cfg.name, "generated": toks.shape,
                      "wall_s": round(time.time() - t0, 1),
                      "sample": toks[0][:8].tolist()}, default=str))


if __name__ == "__main__":
    main()
