"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis crosses the DCN fabric whose topology the paper optimizes (MRLS);
``repro.fabric`` consumes the dry-run's cross-pod collective bytes to pick
the pod-axis strategy.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

from .._jax_compat import AxisType  # also polyfills jax.set_mesh/shard_map


def _mesh_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_test_mesh(shape=(1, 1, 1), axes=("pod", "data", "model")):
    """Tiny mesh for CPU smoke tests (1 device)."""
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))
