"""Post-optimization HLO cost accounting with loop trip-count multipliers.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (verified in
this repo — a 10-iteration scan reports 1x flops), which would undercount a
scanned-layer model by ~n_layers.  This module parses ``compiled.as_text()``
(the per-device, post-SPMD module), walks the call graph (while bodies/
conditions, fusions, to_apply reducers), extracts per-while trip counts from
the condition's loop-bound constant, and accumulates:

  * ``flops``            — dot/convolution FLOPs (MXU work)
  * ``bytes``            — operand+result bytes of top-level instructions
                           (fusion internals excluded: a fusion reads its
                           params and writes its result — the HBM-traffic
                           model for a fused TPU kernel)
  * ``collective_bytes`` — per collective type, operand bytes

All values are per-device (the HLO is the per-device SPMD module).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (older
    jax returns a one-element list of dicts, newer a dict)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")


def parse_shape(s: str) -> tuple[int, tuple]:
    """'bf16[32,256]{1,0}' -> (bytes, dims).  Tuples sum; scalars = dtype."""
    s = s.strip()
    if s.startswith("("):
        # tuple — split top-level commas
        depth, parts, cur = 0, [], ""
        for ch in s[1:-1]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(cur); cur = ""
            else:
                cur += ch
        parts.append(cur)
        total = sum(parse_shape(p)[0] for p in parts if p.strip())
        return total, ()
    m = _SHAPE_RE.match(s)
    if not m:
        return 0, ()
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return 0, ()
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    n = 1
    for d in shape:
        n *= d
    return n * DTYPE_BYTES[dt], shape


class Instruction:
    __slots__ = ("name", "op", "result_type", "operands", "attrs", "line")

    def __init__(self, name, op, result_type, operands, attrs, line):
        self.name, self.op = name, op
        self.result_type, self.operands = result_type, operands
        self.attrs, self.line = attrs, line


_OP_NAME = re.compile(r"([\w\-]+)\((.*)$", re.S)


def _split_type_op(rest: str):
    """'(s32[], bf16[2]{0}) while(%t), cond=...' -> (type, op, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype, tail = rest[: i + 1], rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, tail = rest[:sp], rest[sp + 1:].strip()
    m = _OP_NAME.match(tail)
    if not m:
        return None
    return rtype, m.group(1), m.group(2)


def _split_operands(s: str) -> tuple[list[str], str]:
    """Split 'a, b, c), attr=...' -> ([a,b,c], rest)."""
    depth, parts, cur = 0, [], ""
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                if cur.strip():
                    parts.append(cur.strip())
                return parts, s[i + 1:]
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur.strip()); cur = ""
        else:
            cur += ch
    return parts, ""


def parse_module(txt: str) -> dict[str, list[Instruction]]:
    comps: dict[str, list[Instruction]] = {}
    params: dict[str, dict[str, str]] = {}
    cur = None
    entry = None
    for line in txt.splitlines():
        ls = line.rstrip()
        hdr = _COMP_HDR.match(ls)
        if hdr and ls.endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            params[cur] = {}
            # top-level comma split (param types may be nested tuples)
            depth, parts, curtok = 0, [], ""
            for ch in hdr.group(2):
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                if ch == "," and depth == 0:
                    parts.append(curtok); curtok = ""
                else:
                    curtok += ch
            parts.append(curtok)
            for p in parts:
                p = p.strip()
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    params[cur][pname.strip().lstrip("%")] = ptype.strip()
            if ls.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if ls.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(ls)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _split_type_op(rest)
        if om is None:
            continue
        rtype, op, tail = om
        operands, attrs = _split_operands(tail)
        comps[cur].append(Instruction(name, op, rtype, operands, attrs, ls))
    comps["__params__"] = params        # type: ignore
    comps["__entry__"] = entry          # type: ignore
    return comps


def _symbol_types(comp: list[Instruction], params: dict[str, str]) -> dict:
    table = dict(params)
    for ins in comp:
        table[ins.name] = ins.result_type
    return table


def _operand_name(operand: str) -> str:
    """Operand token -> symbol name.  Handles both HLO text styles:
    bare ``%name`` and typed ``f32[128,256]{1,0} %name`` (older jax)."""
    parts = operand.strip().split()
    return parts[-1].lstrip("%") if parts else ""


def _operand_bytes(operand: str, table: dict) -> int:
    t = table.get(_operand_name(operand))
    if t is None:
        return 0
    return parse_shape(t)[0]


_TRIP_RE = re.compile(r"constant\((\d+)\)")


def trip_count(cond_comp: list[Instruction]) -> int:
    """Loop bound from the condition computation's integer constant."""
    best = 1
    for ins in cond_comp:
        if ins.op == "constant" or "constant(" in ins.line:
            for m in _TRIP_RE.finditer(ins.line):
                best = max(best, int(m.group(1)))
    return best


_CALL_ATTRS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

# "copy" is excluded: post-SPMD CPU HLO inserts whole-buffer copies for
# while-carry aliasing that a TPU buffer-assignment aliases away.
SKIP_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "constant",
                  "bitcast", "while", "conditional", "call", "after-all",
                  "partition-id", "replica-id", "iota", "reshape",
                  "transpose", "copy"}


def _instr_bytes(ins, table, comps) -> float:
    """HBM-traffic model for one instruction.

    In-place buffer updates (scan stacking / KV-cache writes) must NOT be
    charged the whole carried buffer every iteration — XLA aliases loop
    carries, so traffic is the touched slice:
      * dynamic-update-slice: 2x update operand (read-modify-write slice)
      * dynamic-slice / gather: 2x result
      * fusion whose called computation updates an aliased operand
        (an operand the same size as the result): small operands x2
    Everything else: operands + result.
    """
    rbytes = parse_shape(ins.result_type)[0]
    ops_b = [_operand_bytes(o, table) for o in ins.operands]
    if ins.op == "dynamic-update-slice":
        return 2.0 * (ops_b[1] if len(ops_b) > 1 else rbytes)
    if ins.op in ("dynamic-slice", "gather"):
        return 2.0 * rbytes
    if ins.op == "scatter":
        return 3.0 * (ops_b[2] if len(ops_b) > 2 else rbytes)
    if ins.op == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", ins.line)
        called = comps.get(m.group(1), []) if m else []
        inner_table = {i.name: i.result_type for i in called}
        ds_read = sum(parse_shape(i.result_type)[0] for i in called
                      if i.op == "dynamic-slice")
        dus_write = 0.0
        for i in called:
            if i.op == "dynamic-update-slice" and len(i.operands) > 1:
                upd = _operand_name(i.operands[1])
                dus_write += 2.0 * parse_shape(inner_table.get(upd, ""))[0]
        has_slice = ds_read > 0 or dus_write > 0
        if has_slice:
            # big operands are aliased/sliced buffers: charge the touched
            # slices, not the carried buffer, per loop iteration.
            thresh = rbytes if dus_write else 2 * rbytes
            small = sum(b for b in ops_b if b < thresh)
            out_b = 0.0 if dus_write else rbytes
            return small + out_b + ds_read + dus_write
    return rbytes + sum(ops_b)


def analyze(txt: str, fused_scopes: tuple = ()) -> dict:
    """``fused_scopes``: named-scope substrings whose interior instructions
    are modeled as VMEM-resident (the Pallas-kernel cost model): their dot
    FLOPs still count, their HBM byte charges do not — boundary tensors are
    charged by the producing/consuming instructions outside the scope."""
    comps = parse_module(txt)
    params = comps.pop("__params__")
    entry = comps.pop("__entry__")
    out = {
        "flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
        "collective_bytes": defaultdict(float),
        "collective_count": defaultdict(int),
        "while_trips": {},
    }

    def dot_flops(ins: Instruction, table) -> float:
        rbytes, rshape = parse_shape(ins.result_type)
        n_out = 1
        for d in rshape:
            n_out *= d
        lhs_t = table.get(_operand_name(ins.operands[0]), "")
        _, lshape = parse_shape(lhs_t)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        k = 1
        if m and lshape:
            for d in m.group(1).split(","):
                if d:
                    k *= lshape[int(d)]
        return 2.0 * n_out * k

    visited_stack = set()

    def walk(comp_name: str, mult: float, count_bytes: bool):
        if comp_name not in comps or comp_name in visited_stack:
            return
        visited_stack.add(comp_name)
        table = _symbol_types(comps[comp_name], params.get(comp_name, {}))
        producers = {i.name: i for i in comps[comp_name]}
        for ins in comps[comp_name]:
            op = ins.op
            in_fused = bool(fused_scopes) and any(
                s in ins.line for s in fused_scopes)
            cb = count_bytes and not in_fused
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                mt = re.search(r"known_trip_count[^0-9]*(\d+)", ins.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = trip_count(comps.get(cond, [])) if cond else 1
                out["while_trips"][body or "?"] = trips
                if body:
                    walk(body, mult * trips, cb)
                continue
            if op == "conditional":
                mbr = _BRANCHES.search(ins.line)
                if mbr:
                    for b in mbr.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, count_bytes)
                continue
            if op == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if m:
                    walk(m.group(1), mult, count_bytes)
                continue
            if op == "convert":
                continue        # dtype-promotion artifact (CPU f32 dots)
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m:
                    walk(m.group(1), mult, False)   # flops only inside
                if cb and not ins.name.startswith("wrapped_convert"):
                    out["bytes"] += mult * _instr_bytes(ins, table, comps)
                continue
            if op == "dot" or op == "convolution":
                out["flops"] += mult * dot_flops(ins, table)
                if cb:
                    # charge operands at source dtype when produced by a
                    # convert (XLA:CPU promotes bf16 dots to f32; TPU won't)
                    b = parse_shape(ins.result_type)[0]
                    for o in ins.operands:
                        ob = _operand_bytes(o, table)
                        prod = producers.get(_operand_name(o))
                        if prod is not None and "convert" in prod.name:
                            src_b = sum(_operand_bytes(po, table)
                                        for po in prod.operands)
                            ob = min(ob, src_b) if src_b else ob
                        b += ob
                    out["bytes"] += mult * b
                continue
            if op == "custom-call" and ("matmul" in ins.line or "dot" in ins.line):
                out["flops"] += mult * dot_flops(ins, table)
            is_coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if is_coll:
                # XLA:CPU promotes bf16 dots AND all-reduces to f32, so big
                # f32 collective operands are a backend artifact: every large
                # activation/grad collective in this framework is bf16-intent
                # (the TPU target keeps bf16).  Charge f32 operands > 1 MiB
                # at bf16 size; small f32 (norm stats, scalars) unchanged.
                b = 0.0
                for o in ins.operands:
                    ob = _operand_bytes(o, table)
                    t = table.get(_operand_name(o), "")
                    if t.startswith("f32") and ob > (1 << 20):
                        ob //= 2
                    b += ob
                out["collective_bytes"][is_coll] += mult * b
                out["collective_count"][is_coll] += int(mult)
                if cb:
                    out["bytes"] += mult * 2 * b
                continue
            if cb and op not in SKIP_BYTES_OPS:
                out["bytes"] += mult * _instr_bytes(ins, table, comps)
        visited_stack.discard(comp_name)

    if entry:
        walk(entry, 1.0, True)
    out["collective_bytes"] = dict(out["collective_bytes"])
    out["collective_count"] = dict(out["collective_count"])
    out["collective_total"] = sum(out["collective_bytes"].values())
    return out
