"""Step builders + abstract input specs for every (arch x shape) cell.

``make_train_step`` returns the full production step (fwd + bwd + clip +
AdamW update); ``make_prefill_step`` / ``make_decode_step`` are the serving
entry points.  ``input_structs`` builds the ShapeDtypeStruct stand-ins (with
attached shardings — no allocation) used by the dry-run and by tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ShapeCell
from ..models import model as M
from ..models.common import abstract_params
from ..optim.adamw import AdamWConfig, adamw_update, opt_specs
from ..parallel.sharding import Sharder


def default_opt(cfg: M.ModelConfig) -> AdamWConfig:
    """bf16 moments for >=100B params (fits 16GB/chip v5e), else f32."""
    big = cfg.param_count() > 100e9
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def make_train_step(cfg: M.ModelConfig, sh: Sharder, opt: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg, sh))(params)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(cfg: M.ModelConfig, sh: Sharder):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, sh)
    return prefill_step


def make_decode_step(cfg: M.ModelConfig, sh: Sharder):
    def decode_step(params, cache, tokens, pos):
        return M.decode_step(params, cache, tokens, pos, cfg, sh)
    return decode_step


# ---------------------------------------------------------------------- #
# abstract inputs
# ---------------------------------------------------------------------- #
def _tok_struct(sh: Sharder, batch, seq):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                sharding=_safe(sh, (batch, seq), ("dp", None)))


def _safe(sh: Sharder, shape, axes):
    return sh.sharding(axes, shape)


def input_structs(cfg: M.ModelConfig, cell: ShapeCell, sh: Sharder,
                  opt: Optional[AdamWConfig] = None) -> dict:
    """Abstract inputs for the cell's step function.

    train  -> {params, opt_state, batch}
    prefill-> {params, batch}
    decode -> {params, cache, tokens, pos}
    """
    specs = M.build_specs(cfg)
    params = abstract_params(specs, sh)
    B, S = cell.batch, cell.seq
    out = {"params": params}

    def ctx_struct():
        return jax.ShapeDtypeStruct(
            (B, cfg.n_ctx_tokens, cfg.d_model), jnp.bfloat16,
            sharding=_safe(sh, (B, cfg.n_ctx_tokens, cfg.d_model),
                           ("dp", None, None)))

    if cell.kind == "train":
        out["opt_state"] = abstract_params(opt_specs(specs, opt), sh)
        batch = {"tokens": _tok_struct(sh, B, S),
                 "labels": _tok_struct(sh, B, S)}
        if cfg.n_ctx_tokens:
            batch["ctx"] = ctx_struct()
        out["batch"] = batch
    elif cell.kind == "prefill":
        batch = {"tokens": _tok_struct(sh, B, S)}
        if cfg.n_ctx_tokens:
            batch["ctx"] = ctx_struct()
        out["batch"] = batch
    else:  # decode
        out["cache"] = M.cache_struct(cfg, B, S, sh)
        out["tokens"] = _tok_struct(sh, B, 1)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
