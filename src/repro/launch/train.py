"""Production training driver.

Wires together: config registry, mesh/sharder, synthetic data pipeline with
prefetch, AdamW (sharded states), fault-tolerant runner (checkpoint-restart,
straggler detection), and the fabric planner's pod-axis advice.

Usage (CPU-scale example — examples/train_lm.py drives a ~100M model):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 100 --seq 512 --global-batch 8 --mesh 1,1,1 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced as reduce_cfg
from ..data.pipeline import DataConfig, SyntheticLM
from ..checkpointing.checkpoint import Checkpointer
from ..models.common import init_params, param_shardings
from ..models.model import build_specs
from ..optim.adamw import AdamWConfig, opt_specs, warmup_cosine
from ..parallel.sharding import Sharder
from ..runtime.fault_tolerance import FaultTolerantRunner, FTConfig
from .mesh import make_test_mesh
from . import steps as ST


def build_training(cfg, sh: Sharder, opt: AdamWConfig, ckpt_dir: str,
                   data: SyntheticLM, ft: FTConfig = FTConfig(),
                   fault_hook=None):
    specs = build_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0), sh)
    from ..optim.adamw import init_opt
    opt_state = init_opt(specs, opt, sh)
    raw_step = ST.make_train_step(cfg, sh, opt)

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = raw_step(params, opt_state, batch)
        return (params, opt_state), metrics

    ckpt = Checkpointer(ckpt_dir)
    runner = FaultTolerantRunner(step_fn, data.batch_at, ckpt, ft,
                                 fault_hook=fault_hook)
    return (params, opt_state), runner, ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1,1,1")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_test_mesh(shape)
    sh = Sharder(mesh)
    opt = AdamWConfig(lr=args.lr,
                      schedule=warmup_cosine(args.steps // 10, args.steps))
    data = SyntheticLM(DataConfig(cfg.vocab, args.seq, args.global_batch), sh)

    with jax.set_mesh(mesh):
        state, runner, ckpt = build_training(
            cfg, sh, opt, args.ckpt_dir, data)
        t0 = time.time()
        state, step, history = runner.run(state, 0, args.steps)
    print(json.dumps({
        "arch": cfg.name, "steps": step,
        "first_loss": history[0]["loss"], "last_loss": history[-1]["loss"],
        "wall_s": round(time.time() - t0, 1),
        "stragglers": len(runner.stragglers.flagged),
        "restarts": runner.restarts,
    }))


if __name__ == "__main__":
    main()
