import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# TPU-intent numerics in the lowered HLO (bf16 dots, f32 accumulation);
# nothing in the dry-run is ever executed.
os.environ.setdefault("REPRO_STRICT_BF16", "1")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on 512 placeholder devices that the distribution
config is coherent (shardings consistent, collectives legal, memory fits)
and extracts the roofline terms:

  compute   = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 / chip)
  memory    = HLO_bytes / HBM_bw                (819 GB/s / chip)
  collective= collective_bytes / link_bw        (~50 GB/s ICI link / chip)

(all per-device — the analyzed module is the per-device SPMD module; see
``hlo_stats`` for the loop-trip-count-aware accounting).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--out-dir results/dryrun]
"""
import argparse
import json
import time
import traceback


PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link


def active_param_count(cfg) -> int:
    """6*N*D counts only routed-active expert params for MoE."""
    import jax
    from repro.models.model import build_specs
    from repro.models.common import is_spec
    import numpy as np
    specs = build_specs(cfg)
    total = 0
    paths = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)[0]
    for path, spec in paths:
        n = int(np.prod(spec.shape))
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if cfg.moe is not None and "/moe/w" in "/" + keys:
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


FUSED_SCOPES = ("flash_tile", "ssm_chunk")


def run_cell(arch: str, shape: str, multi_pod: bool,
             mesh=None, overrides: dict | None = None,
             fused: bool = False) -> dict:
    import jax
    from repro.configs import get_config, SHAPES, supports
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps as ST
    from repro.launch import hlo_stats
    from repro.parallel.sharding import Sharder
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    ok, why = supports(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    from repro.parallel.sharding import ShardingRules
    sh = Sharder(mesh, ShardingRules.for_mesh(
        mesh, sequence_parallel=cfg.seq_parallel))
    n_chips = mesh.size
    t0 = time.time()

    if cell.kind == "train":
        opt = ST.default_opt(cfg)
        structs = ST.input_structs(cfg, cell, sh, opt)
        step = ST.make_train_step(cfg, sh, opt)
        args = (structs["params"], structs["opt_state"], structs["batch"])
    elif cell.kind == "prefill":
        structs = ST.input_structs(cfg, cell, sh)
        step = ST.make_prefill_step(cfg, sh)
        args = (structs["params"], structs["batch"])
    else:
        structs = ST.input_structs(cfg, cell, sh)
        step = ST.make_decode_step(cfg, sh)
        args = (structs["params"], structs["cache"], structs["tokens"],
                structs["pos"])

    with jax.set_mesh(mesh):
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = hlo_stats.cost_analysis_dict(compiled)
    stats = hlo_stats.analyze(compiled.as_text(),
                              FUSED_SCOPES if fused else ())

    flops = stats["flops"]                     # per device
    byts = stats["bytes"]
    coll = stats["collective_total"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    n_active = active_param_count(cfg)
    model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
    hlo_global = flops * n_chips
    rec.update(
        status="ok",
        kind=cell.kind,
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        per_device={
            "flops": flops,
            "bytes": byts,
            "collective_bytes": stats["collective_bytes"],
            "collective_count": stats["collective_count"],
        },
        xla_cost_analysis={"flops_1iter": ca.get("flops"),
                           "bytes_1iter": ca.get("bytes accessed")},
        memory_analysis={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        roofline={**{k: round(v, 6) for k, v in terms.items()},
                  "dominant": dominant,
                  "bound_s": round(max(terms.values()), 6)},
        model_flops=model_flops,
        n_active_params=n_active,
        hlo_flops_global=hlo_global,
        useful_flops_ratio=round(model_flops / max(hlo_global, 1), 4),
        roofline_fraction=round(
            (model_flops / PEAK_FLOPS / n_chips)
            / max(max(terms.values()), 1e-12), 4),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--override", default="",
                    help="comma k=v model-config overrides (perf experiments)")
    ap.add_argument("--fused", action="store_true",
                    help="Pallas-kernel cost model: flash/ssm tile interiors "
                         "are VMEM-resident (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            try:
                v = json.loads(v)
            except json.JSONDecodeError:
                pass
            overrides[k] = v

    os.makedirs(args.out_dir, exist_ok=True)

    def one(arch, shape, multipod):
        tag = f"{arch}_{shape}_{'2x16x16' if multipod else '16x16'}"
        if overrides:
            tag += "_" + "-".join(f"{k}={v}" for k, v in overrides.items())
        if args.fused:
            tag += "_fused"
        path = os.path.join(args.out_dir, tag + ".json")
        try:
            rec = run_cell(arch, shape, multipod, overrides=overrides or None,
                           fused=args.fused)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "mesh": "2x16x16" if multipod else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
        print(json.dumps({k: rec.get(k) for k in
                          ("arch", "shape", "mesh", "status", "compile_s",
                           "roofline", "useful_flops_ratio",
                           "roofline_fraction", "error")}, default=float))

    if args.all:
        from repro.configs import ARCHS, SHAPES
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    one(arch, shape, mp)
    else:
        one(args.arch, args.shape, args.multipod)


if __name__ == "__main__":
    main()
