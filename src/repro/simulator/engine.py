"""Cycle-level interconnection-network simulator in JAX (CAMINOS-equivalent).

Model (documented deviations from the paper's flit-level CAMINOS setup in
docs/DESIGN.md): slotted time — one slot = one 16-flit packet serialization
on a link.  Input-queued switches with ``V`` virtual channels per port and
``Q``-packet queues, credit-based flow control (a packet advances only if the
downstream input queue for its next VC has room), separable random-priority
output arbitration (one grant per output port per slot), per-input-port VC
pre-arbitration (one candidate VC per input port per slot), unbounded
ejection, per-endpoint injection queues (one NIC per endpoint, one packet
injected per slot max).

Routing is evaluated *inside* the jitted step on compact precomputed tables:

* ``polarized``        — the paper's adapted Polarized routing (Section 4.3.2)
  with VC = updown-phase = hops // 2 (1 VC per Up-Down pass — the halved
  deadlock resources of Section 4.3).  Consumes two int16 distance rows
  (to source and to target) per requester.
* ``minimal_adaptive`` — adaptive minimal (Fat-Tree / OFT "MIN").
* ``ksp``              — randomized minimal-DAG walk (models KSP's random
  choice among precomputed shortest paths).
* ``ugal``             — UGAL-L with Valiant intermediate leaf (Dragonfly).
* ``valiant``          — always-Valiant.

The minimal policies never gather ``[P]``-wide distance rows: the candidate
port set for (switch, target leaf) is static, so ``build_tables`` packs it
into uint32 bitmasks (``RoutingTables.min_mask``) and the step does one
word gather plus a bit test per requester.

The step is engineered to be compute-bound, not gather/scatter-bound:

* **O(S) packet free-list** — the pool allocator is a ring buffer
  (``fl_buf``/``fl_head``/``fl_len``) with O(S) pops at inject and O(NR)
  pushes at eject, replacing the per-slot ``jnp.nonzero`` scan over the
  whole (up to 2M-entry) pool.  The free *set* is the ring window
  (``Simulator.free_ids``); in-flight count is ``pool - fl_len``.
  Per-packet attributes are bit-packed (``p_sd`` = src leaf << 16 | dst
  leaf, ``p_bh`` = born slot << 8 | hops) to halve pool scatter/gather
  traffic.
* **Donated buffers** — ``run_chunk`` / ``run_chunk_batch`` /
  ``_completion_loop`` donate the state pytree, so chunked runs update
  state in place instead of double-buffering the whole simulator.  A state
  dict passed to any of these is *consumed*: do not reuse it afterwards
  (keep the returned dict instead).
* **Pluggable arbitration backend** — ``SimConfig.backend`` selects
  ``"xla"`` (default, inline jnp) or ``"pallas"`` (the fused per-switch
  arbitration kernel in ``repro.kernels.switch_arb``, interpret-mode on
  CPU).  Both backends are bitwise-identical per replica.

Everything is fixed-shape; throughput/latency runs are jitted ``lax.scan``
chunks, and completion runs are a single device-side ``lax.while_loop``
over chunks (the ``ejected >= expected`` check never round-trips to the
host, and the exact completion slot is recorded from the ejection-counter
crossing).  Replication is a first-class compiled axis: ``make_batch_state``
stacks R independently-seeded states along a leading replica dimension and
``run_*_batch`` drive all replicas through one ``jax.vmap``-ed executable.

Collectives execute as compiled workload programs (``repro.workloads``):
``Traffic("program")`` carries the static schedule shape, the compiled
``partner``/``packets``/``expected`` arrays ride in the state, and
``run_program`` drives every phase of every replica through one
``lax.while_loop`` with an on-device phase scheduler
(``_advance_program``) — ``schedule="barrier"`` replays the legacy
per-phase host loop bitwise, ``schedule="window"`` pipelines rounds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.routing import RoutingTables
from ..workloads.patterns import (ARRIVAL_PATTERNS, BERNOULLI_PATTERNS,
                                  bounded_pareto_mean, check_arrival,
                                  check_pattern)

BIG = jnp.float32(1e9)

BACKENDS = ("xla", "pallas")

# percentile ladder of the latency-family drivers: median, p99, and the
# serving-SLO tails (p999 / p9999)
LATENCY_QS = (0.5, 0.99, 0.999, 0.9999)


@contextlib.contextmanager
def _quiet_cpu_donation():
    """Buffer donation is a no-op on CPU backends; jax warns once per
    compile, which would drown test output for the (CPU-only) tier-1
    suite.  Scoped to this engine's own compiles — the process-global
    filter is left alone so callers' unrelated donation diagnostics
    still surface."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SimConfig:
    policy: str = "polarized"
    vcs: int = 4                 # V
    queue_depth: int = 8         # Q packets per (port, VC) at input
    out_queue: int = 4           # packets per (port, VC) at output
    speedup: int = 2             # crossbar sub-rounds per slot
    endpoint_queue: int = 4      # QE packets per NIC
    max_hops: int = 8            # routing hop bound (2D* - 2 for polarized)
    deroute_penalty: float = 8.0
    pool: Optional[int] = None   # packet pool size (default: auto)
    hist_bins: int = 4096        # latency histogram bins (slots)
    seed: int = 0
    backend: str = "xla"         # "xla" | "pallas" arbitration backend


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Traffic program.  ``pattern`` is validated against the shared
    workload-pattern registry (:mod:`repro.workloads.patterns`): the
    Bernoulli families (uniform | rep | rsp | bu | mice_elephant | tornado
    | shift | hotspot | bursty), ``all2all``, or the engine-level
    ``phase`` / ``program`` patterns.  Unknown names raise here, at
    construction — never at trace time.

    * Bernoulli patterns use ``load`` (packets/slot/endpoint).  The
      adversarial families add: ``shift`` (static permutation
      ``(e + shift) mod S``), ``tornado`` (leaf-level half-rotation),
      ``hotspot`` (``hot_frac`` of messages incast onto endpoints
      ``0..hot_count-1``), ``bursty`` (on-off Markov modulation with mean
      burst length ``burst_len`` slots and in-burst intensity
      ``burst_load``; long-run offered load stays ``load``).
    * ``arrival``: open-loop serving source.  ``process`` picks the
      arrival generator (``poisson`` — Bernoulli(load) single-packet
      arrivals; ``pareto`` — bounded-Pareto batch sizes (shape
      ``pareto_alpha``, cap ``pareto_cap``) with the arrival probability
      calibrated so the long-run offered load stays ``load``; ``diurnal``
      — sinusoidal rate modulation with relative amplitude
      ``diurnal_amp`` and period ``diurnal_period`` slots).  Each endpoint
      holds an ``arr_depth``-deep FIFO of pending request batches;
      arrivals that find it full are dropped (``arr_drop``) instead of
      back-pressuring the source — that open loop is what distinguishes
      serving traffic from the Bernoulli families, whose idle-endpoint
      gating silently caps offered load at service capacity.  Packet
      latency is measured from the batch's *arrival* slot (``msg_birth``),
      so source queueing shows up in the histogram.
    * ``all2all``: each endpoint sends ``rounds`` single-packet messages to
      (e + r + 1) mod S, free-running (no round synchronization).
    * ``phase``: each endpoint sends ``phase_packets`` packets to
      ``partner[e]`` (the legacy hand-patched single-exchange idiom).
    * ``program``: a compiled :class:`repro.workloads.CompiledProgram` of
      ``n_phases`` phases executed by the on-device phase scheduler under
      ``schedule`` (``"barrier"`` replays the host loop bitwise;
      ``"window"`` lets endpoints run ``window`` phases ahead of the
      globally-completed phase).  The program arrays live in the *state*
      (``make_program_state``); only the static shape/schedule lives here,
      so runs of same-shaped programs share one compiled executable.
    """
    pattern: str = "uniform"
    load: float = 1.0
    rounds: int = 0
    phase_packets: int = 0
    elephant_frac: float = 0.1   # fraction of messages that are elephants
    elephant_size: int = 16
    # adversarial Bernoulli knobs
    shift: int = 1               # shift: dst = (e + shift) mod S
    hot_frac: float = 0.1        # hotspot: fraction of incast messages
    hot_count: int = 1           # hotspot: number of hot endpoints
    burst_len: float = 8.0       # bursty: mean ON duration (slots)
    burst_load: float = 1.0      # bursty: injection probability while ON
    # open-loop arrival source ("arrival" pattern) knobs
    process: str = "poisson"     # poisson | pareto | diurnal
    pareto_alpha: float = 1.5    # bounded-Pareto shape (> 1)
    pareto_cap: int = 64         # bounded-Pareto batch-size cap (packets)
    diurnal_amp: float = 0.5     # relative rate-modulation amplitude [0,1]
    diurnal_period: int = 512    # modulation period (slots, >= 2)
    arr_depth: int = 8           # per-endpoint pending-batch FIFO depth
    # compiled workload program (schedule shape; arrays live in the state)
    n_phases: int = 0
    schedule: str = "barrier"    # "barrier" | "window"
    window: int = 1              # lookahead depth for schedule="window"

    def __post_init__(self):
        check_pattern(self.pattern, engine=True)
        if self.pattern == "arrival" and self.process not in ARRIVAL_PATTERNS:
            raise ValueError(f"unknown arrival process {self.process!r}; "
                             f"expected one of {ARRIVAL_PATTERNS}")


# Donated row scatters for in-place device-table updates: the old table
# buffer is consumed and rewritten rather than double-buffered — at paper
# scale the mask tables are the largest device arrays, so the delta path
# must never hold two copies.
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(table, rows, vals):
    return table.at[rows].set(vals)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_batch(table, rows, vals):
    return table.at[:, rows].set(vals[None])


class Simulator:
    def __init__(self, tables: RoutingTables, cfg: SimConfig,
                 failures=None):
        if cfg.backend not in BACKENDS:
            raise ValueError(f"unknown backend {cfg.backend!r}; "
                             f"expected one of {BACKENDS}")
        topo = tables.topo
        self.tables, self.cfg = tables, cfg
        # failure machinery is a *static* branch: with no schedule (or an
        # empty one) every step traces exactly as before — routing tables
        # stay closure-captured constants and no live masks ride in the
        # state, so the parity goldens are bitwise-untouched.  With a
        # schedule, the tables move into the state (``tbl_min`` /
        # ``tbl_away`` / ``tbl_dist`` + ``link_up`` / ``switch_up``) so
        # ``update_tables`` can rewrite them mid-run without recompiling.
        self.failures = failures
        self.has_failures = failures is not None and len(failures.events) > 0
        if failures is not None:
            failures.validate(topo)
        self.N = topo.n_switches
        self.P = topo.max_ports
        self.V = cfg.vcs
        self.Q = cfg.queue_depth
        self.QE = cfg.endpoint_queue
        self.n1 = topo.n_leaves
        self.d_leaf = topo.endpoints_per_leaf
        self.S = topo.n_endpoints
        self.NQ = self.N * self.P * self.V
        self.pool = cfg.pool or int(min(2_000_000, max(1 << 14, self.S * 6)))

        self.nbrs = jnp.asarray(topo.nbrs, jnp.int32)            # [N,P]
        self.nbr_port = jnp.asarray(topo.nbr_port, jnp.int32)    # [N,P]
        self.valid_port = self.nbrs >= 0
        self.nbrs0 = jnp.maximum(self.nbrs, 0)
        assert (tables.dist_leaf >= 0).all(), "disconnected topology"
        # int16 distance table: the rows Polarized gathers per sub-round are
        # half the width of the old int32 table; all consumers use the
        # values in comparisons / tiny products, where int16 is exact.
        self.dist = jnp.asarray(tables.dist_leaf, jnp.int16)     # [N1,N]
        self.leaf_ids = jnp.asarray(topo.leaf_ids, jnp.int32)    # [N1]
        # compact port bitmasks [N1*N, W]: one uint32-word gather + bit
        # test replaces a [P]-wide distance-row gather per requester
        # (toward-bits drive the minimal policies; toward+away together
        # encode the full Polarized classification).  Built by streaming
        # leaf blocks — with blocked tables the dense numpy arrays are
        # never materialized on the host.
        self.W = (self.P + 31) // 32
        self.min_mask, self.away_mask = self._build_device_masks(tables)
        self._w_idx = jnp.asarray(np.arange(self.P) // 32, np.int32)
        self._b_idx = jnp.asarray(np.arange(self.P) % 32, np.uint32)

        # bit-packing bounds: p_sd packs two leaf ranks into 16 bits each,
        # p_bh keeps hops in the low byte (born slot above it); flat index
        # spaces (mask rows, queue buffers, pool) must fit int32 — audited
        # here so a 1M-endpoint spec fails loudly at construction instead
        # of silently wrapping gather indices at runtime
        assert self.n1 < (1 << 16), "leaf rank overflows the p_sd packing"
        assert cfg.max_hops < 255, "hop count overflows the p_bh packing"
        assert self.n1 * self.N < (1 << 31), \
            "mask-table row index overflows int32"
        assert self.NQ * max(self.Q, cfg.out_queue) < (1 << 31), \
            "flat queue-buffer index overflows int32"
        assert self.pool < (1 << 31), "pool index overflows int32"

        self._init_requester_geometry(topo)
        self._sharded_cache: dict = {}
        self._closed = False

    def _build_device_masks(self, tables: RoutingTables):
        """Device mask tables ``[N1*N, W]``, assembled from streamed leaf
        blocks (:meth:`RoutingTables.mask_blocks`).

        Works for both table layouts.  With ``mask_layout="blocked"`` the
        dense numpy arrays are never built: numpy peak is one
        ``[leaf_block, N, W]`` pair, and *retained* memory is the device
        tables alone.  The assembly itself still peaks at ~2x one
        policy's tables while ``jnp.concatenate`` copies the collected
        blocks into the flat arrays (buffer donation is a no-op on the
        CPU backends this targets, so a true in-place stream is not
        available) — the blocked layout's durable win is retention, not
        the assembly transient.  Only Polarized keeps the away bits — the
        minimal policies never read them, and a second [N1*N, W] device
        table is 100s of MB at paper scale.
        """
        need_away = self.cfg.policy in ("polarized", "degraded")
        mins, aways = [], []
        for _lo, _hi, min_b, away_b in tables.mask_blocks():
            mins.append(jnp.asarray(min_b.reshape(-1, self.W)))
            if need_away:
                aways.append(jnp.asarray(away_b.reshape(-1, self.W)))
            del min_b, away_b
        min_mask = mins[0] if len(mins) == 1 else jnp.concatenate(mins)
        away_mask = None
        if need_away:
            away_mask = aways[0] if len(aways) == 1 else jnp.concatenate(aways)
        return min_mask, away_mask

    def _init_requester_geometry(self, topo) -> None:
        """Static per-requester index tables for the crossbar hot path.

        Requester rows are ``[N*P network inputs] ++ [S endpoint NICs]``.
        Everything here depends only on the topology, so it is baked into
        the compiled step as constants instead of being recomputed from
        ``nbrs``/``nbr_port`` every sub-round.
        """
        N, P, V, S, d = self.N, self.P, self.V, self.S, self.d_leaf
        nbrs = np.asarray(topo.nbrs)
        nbr_port = np.asarray(topo.nbr_port)
        leaf_ids = np.asarray(topo.leaf_ids)

        cur_net = np.repeat(np.arange(N, dtype=np.int32), P)
        cur_ep = leaf_ids[np.arange(S, dtype=np.int32) // d]
        cur = np.concatenate([cur_net, cur_ep])                  # [NR]
        self.NR = NR = cur.shape[0]
        self.cur = jnp.asarray(cur)
        ports = np.arange(P, dtype=np.int32)
        # V-major occupancy layout: row (switch * V + vc) holds the [P]
        # occupancy vector every requester of that switch with that flight
        # VC needs, so the per-requester congestion lookup is a contiguous
        # row gather indexed by cur * V + next_vc — no [NR, P] index
        # matrices and no random-element gathers in the hot path.
        self._dq_perm = jnp.asarray(
            ((np.maximum(nbrs, 0) * P + np.maximum(nbr_port, 0))
             [:, None, :] * V
             + np.arange(V, dtype=np.int32)[None, :, None]
             ).reshape(-1).astype(np.int32))                     # [N*V*P]
        # UGAL source-switch occupancy (flat qlen index, VC 0)
        if self.cfg.policy == "ugal":
            sw = leaf_ids[np.arange(S, dtype=np.int32) // d]
            self._ugal_occ_idx = jnp.asarray(
                (np.maximum(nbrs, 0)[sw] * P + nbr_port[sw]) * V)  # [S,P]
        # dense per-switch requester layout (pallas kernel + the scatter-free
        # grant inversion).  Row r of switch n is net in-port r (r < P) or
        # NIC slot r - P (leaf switches only); ``row_of`` maps flat
        # requester index -> dense row.
        self.R_max = P + d
        net_rows = cur_net.astype(np.int64) * self.R_max + np.tile(
            ports, N)
        ep_rows = (cur_ep.astype(np.int64) * self.R_max + P
                   + np.arange(S, dtype=np.int64) % d)
        self._row_of = jnp.asarray(
            np.concatenate([net_rows, ep_rows]).astype(np.int32))
        self._lo = jnp.arange(NR, dtype=jnp.int32)
        # static flat -> dense-row gather (the inverse of row_of, with a
        # harmless duplicate fill for rows no requester occupies): lets the
        # XLA backend run the same dense per-switch segmented reduction the
        # Pallas kernel uses, without any scatter
        inv = np.zeros(N * self.R_max, np.int64)
        inv[np.concatenate([net_rows, ep_rows])] = np.arange(NR)
        self._dense_src = jnp.asarray(inv.astype(np.int32))      # [N*R_max]
        occupied = np.zeros(N * self.R_max, bool)
        occupied[np.concatenate([net_rows, ep_rows])] = True
        self._dense_valid = jnp.asarray(occupied.reshape(N, self.R_max))
        # link reversal: the input port (n', p') is fed by exactly one
        # output port — static, so receives invert sends with a gather
        rev = (np.maximum(nbrs, 0) * P + np.maximum(nbr_port, 0))
        self._rev_idx = jnp.asarray(rev.reshape(-1).astype(np.int32))

    # ------------------------------------------------------------------ #
    # lifetime: compiled step functions are jit-cached with ``self`` as a
    # static argument, so long-lived suites (~25 instances) accumulate
    # executables until the host OOMs.  ``close()`` makes the teardown that
    # callers used to do by hand (``del sim; jax.clear_caches()``) explicit
    # and idempotent; the context-manager form scopes it.
    # ------------------------------------------------------------------ #
    def close(self, clear: bool = True) -> None:
        """Mark the simulator dead and (by default) clear jax's jit caches.

        jax has no per-instance executable eviction, so ``clear=True`` is a
        process-global ``jax.clear_caches()`` — other live simulators will
        recompile on next use.  Batch teardowns (``SimulatorCache.close``)
        pass ``clear=False`` per instance and clear once at the end.
        """
        if self._closed:
            return
        self._closed = True
        self._sharded_cache.clear()
        if clear:
            jax.clear_caches()

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "Simulator":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------------ #
    def init_state(self, traffic: Traffic, seed_arrays: dict) -> dict:
        f32, i32 = jnp.float32, jnp.int32
        Z = lambda *s: jnp.zeros(s, i32)
        st = {
            "qbuf": jnp.full((self.NQ, self.Q), -1, i32),
            "qhead": Z(self.NQ), "qlen": Z(self.NQ),
            "oq_buf": jnp.full((self.NQ, self.cfg.out_queue), -1, i32),
            "oq_head": Z(self.NQ), "oq_len": Z(self.NQ),
            "eq_buf": jnp.full((self.S, self.QE), -1, i32),
            "eq_head": Z(self.S), "eq_len": Z(self.S),
            # packet pool + ring-buffer free-list (all pool slots free);
            # pops at inject are O(S), pushes at eject O(NR) — no per-slot
            # nonzero scan over the pool.  There is no free bitmap in the
            # hot path: free = the fl_buf ring window (see free_ids()).
            # Per-packet attributes are bit-packed to halve the pool
            # scatters/gathers: p_sd = src_leaf << 16 | dst_leaf,
            # p_bh = born_slot << 8 | hops.
            "fl_buf": jnp.arange(self.pool, dtype=i32),
            "fl_head": Z(), "fl_len": jnp.asarray(self.pool, i32),
            "p_sd": Z(self.pool),
            "p_mid": jnp.full(self.pool, -1, i32),
            "p_bh": Z(self.pool),
            # endpoint message program
            "msg_rem": Z(self.S), "msg_dst": Z(self.S), "prog": Z(self.S),
            # stats
            "ejected": Z(), "created": Z(), "hop_sum": Z(),
            "pool_stall": Z(),
            "lat_hist": Z(self.cfg.hist_bins),
            "slot": Z(),
            "key": jax.random.PRNGKey(self.cfg.seed),
        }
        if self.has_failures:
            # routing tables ride in the (donated) state so update_tables
            # can rewrite rows mid-run.  jnp.array copies — never aliases
            # of the closure constants, which would be consumed with the
            # first donated chunk.
            st["tbl_min"] = jnp.array(self.min_mask)
            if self.away_mask is not None:
                st["tbl_away"] = jnp.array(self.away_mask)
            st["tbl_dist"] = jnp.array(self.dist.reshape(-1))
            st["link_up"] = jnp.array(self.valid_port.reshape(-1))
            st["switch_up"] = jnp.ones(self.N, bool)
            st["fail_drop"] = Z()
        st.update({k: jnp.asarray(v) for k, v in seed_arrays.items()})
        return st

    # ------------------------------------------------------------------ #
    def _port_bits(self, table, t_lr, cur):
        """[len(t_lr), P] bool port mask from a packed table: one
        uint32-word gather per requester instead of a [P] distance row.
        Invalid ports are already zero in the packed words."""
        words = table[t_lr * self.N + cur]                       # [.,W]
        return ((words[:, self._w_idx] >> self._b_idx) & 1).astype(bool)

    # ------------------------------------------------------------------ #
    def _inject(self, st, key, traffic: Traffic):
        """Start messages + push one packet per eligible endpoint."""
        S, d = self.S, self.d_leaf
        e = jnp.arange(S, dtype=jnp.int32)
        k1, k2, k3, k4 = jax.random.split(key, 4)

        idle = st["msg_rem"] == 0
        pat = traffic.pattern
        burst_new = None
        if pat in BERNOULLI_PATTERNS:
            if pat == "bursty":
                # two-state Markov (on-off) modulation: in-burst injection
                # probability is ``burst_load``, mean burst length is
                # ``burst_len`` slots, and the idle->burst rate is set so
                # the long-run offered load equals ``load``
                rho = min(traffic.load / traffic.burst_load, 0.999)
                p_off = 1.0 / max(traffic.burst_len, 1.0)
                p_on = min(1.0, p_off * rho / max(1.0 - rho, 1e-9))
                ka, kb = jax.random.split(k3)
                was_on = st["burst"] > 0
                on = jnp.where(was_on,
                               jax.random.uniform(ka, (S,)) >= p_off,
                               jax.random.uniform(kb, (S,)) < p_on)
                burst_new = on.astype(jnp.int32)
                start = idle & on & (jax.random.uniform(k1, (S,)) <
                                     traffic.burst_load)
            else:
                start = idle & (jax.random.uniform(k1, (S,)) <
                                traffic.load / self._mean_msg(traffic))
            if pat in ("uniform", "mice_elephant", "bursty"):
                dst = jax.random.randint(k2, (S,), 0, S)
            elif pat == "rep":
                dst = st["perm"]
            elif pat == "rsp":
                dst = st["sigma"][e // d] * d + (e % d)
            elif pat == "bu":  # two halves exchange uniformly
                half = S // 2
                lower = e < half
                r = jax.random.randint(k2, (S,), 0, half)
                dst = jnp.where(lower, half + r, r % half)
            elif pat == "tornado":
                # adversarial leaf-level half-rotation: every leaf targets
                # the leaf halfway around the leaf ranking (same slot
                # offset within the leaf) — zero locality, maximal
                # pressure on the non-minimal path diversity
                dst = ((e // d + self.n1 // 2) % self.n1) * d + e % d
            elif pat == "shift":
                dst = (e + traffic.shift) % S
            else:  # hotspot — incast a fraction onto a few hot endpoints
                kh, ki = jax.random.split(k3)
                hot = jax.random.uniform(kh, (S,)) < traffic.hot_frac
                dst = jnp.where(
                    hot, jax.random.randint(ki, (S,), 0, traffic.hot_count),
                    jax.random.randint(k2, (S,), 0, S))
            size = jnp.ones((S,), jnp.int32)
            if pat == "mice_elephant":
                size = jnp.where(jax.random.uniform(k3, (S,)) < traffic.elephant_frac,
                                 traffic.elephant_size, 1)
        elif pat == "arrival":
            # open-loop serving source: generate at most one request batch
            # per endpoint per slot, queue it in the per-endpoint FIFO
            # (dropping on overflow — the source never back-pressures),
            # then let idle endpoints pop their head batch.  All of this is
            # behind a static Python branch: existing patterns trace
            # exactly as before (parity goldens stay bitwise).
            proc = traffic.process
            D = traffic.arr_depth
            u_arr = jax.random.uniform(k1, (S,))
            if proc == "poisson":
                arrive = u_arr < traffic.load
                batch = jnp.ones((S,), jnp.int32)
            elif proc == "pareto":
                # bounded-Pareto batch sizes via inverse CDF; the arrival
                # probability is divided by the exact discrete batch mean
                # so the long-run offered load calibrates to ``load``
                alpha = traffic.pareto_alpha
                cap = traffic.pareto_cap
                arrive = u_arr < traffic.load / bounded_pareto_mean(alpha,
                                                                    cap)
                if cap <= 1:
                    batch = jnp.ones((S,), jnp.int32)
                else:
                    u = jax.random.uniform(k3, (S,))
                    x = (1.0 - u * (1.0 - float(cap) ** -alpha)) \
                        ** (-1.0 / alpha)
                    batch = jnp.clip(jnp.floor(x), 1, cap).astype(jnp.int32)
            else:  # diurnal — sinusoidal rate modulation around ``load``
                w = 2.0 * np.pi / traffic.diurnal_period
                rate = traffic.load * (
                    1.0 + traffic.diurnal_amp
                    * jnp.sin(w * st["slot"].astype(jnp.float32)))
                arrive = u_arr < rate
                batch = jnp.ones((S,), jnp.int32)
            room = st["arr_len"] < D
            push = arrive & room
            tail = (st["arr_head"] + st["arr_len"]) % D
            hot = push[:, None] & (jnp.arange(D, dtype=jnp.int32)[None, :]
                                   == tail[:, None])
            arr_times = jnp.where(hot, st["slot"], st["arr_times"])
            arr_sizes = jnp.where(hot, batch[:, None], st["arr_sizes"])
            arr_len = st["arr_len"] + push.astype(jnp.int32)
            # pop: idle endpoints start serving their head batch (a batch
            # arriving this slot may pop immediately — zero source
            # queueing keeps the latency-1 floor of the local fast path)
            start = idle & (arr_len > 0)
            headi = e * D + st["arr_head"]
            size = jnp.maximum(arr_sizes.reshape(-1)[headi], 1)
            birth = arr_times.reshape(-1)[headi]
            dst = jax.random.randint(k2, (S,), 0, S)
            arrival_updates = {
                "arr_times": arr_times,
                "arr_sizes": arr_sizes,
                "arr_head": jnp.where(start, (st["arr_head"] + 1) % D,
                                      st["arr_head"]),
                "arr_len": arr_len - start.astype(jnp.int32),
                "arrived": st["arrived"]
                + jnp.where(push, batch, 0).sum(dtype=jnp.int32),
                "arr_drop": st["arr_drop"]
                + jnp.where(arrive & ~room, batch, 0).sum(dtype=jnp.int32),
                "msg_birth": jnp.where(start, birth, st["msg_birth"]),
            }
        elif pat == "all2all":
            start = idle & (st["prog"] < traffic.rounds)
            dst = (e + st["prog"] + 1) % S
            size = jnp.ones((S,), jnp.int32)
        elif pat == "phase":
            start = idle & (st["prog"] < 1)
            dst = st["partner"]
            size = jnp.full((S,), traffic.phase_packets, jnp.int32)
        elif pat == "program":
            NP = traffic.n_phases
            if traffic.schedule == "window":
                # windowed/pipelined rounds: st["prog"] is the per-endpoint
                # phase pointer; an endpoint may start its phase-p message
                # once p is within ``window`` of the globally-completed
                # phase count
                ncomp = jnp.sum((st["phase_done"] >= 0).astype(jnp.int32))
                pe = st["prog"]
                start = idle & (pe < jnp.minimum(ncomp + traffic.window, NP))
                idx = jnp.clip(pe, 0, NP - 1) * S + e
                dst = st["prog_partner"].reshape(-1)[idx]
                size = st["prog_packets"].reshape(-1)[idx]
            else:
                # barrier: one message per endpoint per phase, rows gathered
                # from the current phase of the compiled program — bitwise
                # the legacy "phase" inject while a phase is active
                ph = jnp.minimum(st["phase"], NP - 1)
                start = idle & (st["prog"] < 1) & (st["phase"] < NP)
                dst = st["prog_partner"][ph]
                size = st["prog_packets"][ph]
        else:
            raise ValueError(pat)

        msg_rem = jnp.where(start, size, st["msg_rem"])
        msg_dst = jnp.where(start, dst, st["msg_dst"])
        prog = st["prog"] + start.astype(jnp.int32)

        # one packet per endpoint with pending message + NIC room
        want = (msg_rem > 0) & (st["eq_len"] < self.QE)
        src_lr = e // d
        dst_lr = msg_dst // d
        local = src_lr == dst_lr
        # same-leaf fast path: delivered without entering the network.
        deliver_local = want & local
        want_net = want & ~local

        # O(S) free-list pop: requester with rank r takes the r-th entry of
        # the ring buffer; requesters past the free count get the -1
        # sentinel (pool_stall) rather than an aliased packet id.
        rank = jnp.cumsum(want_net.astype(jnp.int32)) - 1
        ok = want_net & (rank < st["fl_len"])
        slot_idx = (st["fl_head"] + jnp.maximum(rank, 0)) % self.pool
        pid = jnp.where(ok, st["fl_buf"][slot_idx], -1)
        n_pop = ok.sum(dtype=jnp.int32)

        # UGAL/Valiant: sample intermediate leaf & (UGAL) compare queue depths
        mid = jnp.full((S,), -1, jnp.int32)
        if self.cfg.policy in ("ugal", "valiant"):
            mid_lr = jax.random.randint(k4, (S,), 0, self.n1)
            if self.cfg.policy == "ugal":
                sw = self.leaf_ids[src_lr]
                occ0 = st["qlen"][self._ugal_occ_idx]             # [S,P]
                if self.has_failures:
                    # state-resident tables + live-port gating; float32
                    # products because UNREACHABLE distances would wrap
                    # the int32 q*d score
                    live_sw = st["link_up"].reshape(self.N, self.P)[sw]
                    dflat = st["tbl_dist"]
                    def best(t_lr):
                        m = self._port_bits(st["tbl_min"], t_lr, sw) & live_sw
                        return jnp.min(jnp.where(m, occ0, 1 << 20), axis=1)
                    q_min = best(dst_lr)
                    q_val = best(mid_lr)
                    d_min = dflat[dst_lr * self.N + sw]
                    d_val = (dflat[mid_lr * self.N + sw]
                             + dflat[dst_lr * self.N + self.leaf_ids[mid_lr]])
                    take_val = (q_min.astype(jnp.float32) * d_min
                                > q_val.astype(jnp.float32) * d_val)
                else:
                    def best(t_lr):
                        m = self._port_bits(self.min_mask, t_lr, sw)
                        return jnp.min(jnp.where(m, occ0, 1 << 20), axis=1)
                    q_min = best(dst_lr)
                    q_val = best(mid_lr)
                    d_min = self.dist[dst_lr, sw]
                    d_val = self.dist[mid_lr, sw] + self.dist[dst_lr, self.leaf_ids[mid_lr]]
                    take_val = q_min * d_min > q_val * d_val
                mid = jnp.where(take_val, mid_lr, -1)
            else:
                mid = mid_lr

        # sentinel index == pool size -> dropped writes for non-injectors
        widx = jnp.where(ok, jnp.maximum(pid, 0), self.pool)
        st = dict(st)
        if burst_new is not None:
            st["burst"] = burst_new
        if pat == "arrival":
            st.update(arrival_updates)
        st["fl_head"] = (st["fl_head"] + n_pop) % self.pool
        st["fl_len"] = st["fl_len"] - n_pop
        st["p_sd"] = st["p_sd"].at[widx].set((src_lr << 16) | dst_lr,
                                             mode="drop")
        if self.cfg.policy in ("ugal", "valiant"):
            st["p_mid"] = st["p_mid"].at[widx].set(mid, mode="drop")
        # arrival packets are born at their batch's *arrival* slot, so
        # source queueing shows up in the latency histogram
        born = st["msg_birth"] if pat == "arrival" else st["slot"]
        st["p_bh"] = st["p_bh"].at[widx].set(born << 8, mode="drop")
        # push into NIC queue (dense one-hot write — one row per endpoint)
        pos = (st["eq_head"] + st["eq_len"]) % self.QE
        slot_hot = ok[:, None] & (jnp.arange(self.QE, dtype=jnp.int32)[None, :]
                                  == pos[:, None])
        st["eq_buf"] = jnp.where(slot_hot, jnp.maximum(pid, 0)[:, None],
                                 st["eq_buf"])
        st["eq_len"] = st["eq_len"] + ok.astype(jnp.int32)

        consumed = ok | deliver_local
        st["msg_rem"] = msg_rem - consumed.astype(jnp.int32)
        st["msg_dst"] = msg_dst
        st["prog"] = prog
        n_local = deliver_local.sum(dtype=jnp.int32)
        st["created"] = st["created"] + ok.sum(dtype=jnp.int32) + n_local
        st["ejected"] = st["ejected"] + n_local
        st["pool_stall"] = st["pool_stall"] + (want_net & ~ok).sum(dtype=jnp.int32)
        if pat == "arrival":
            # local fast-path deliveries also measure from the batch's
            # arrival slot, not the fixed 1-slot bin
            lat_loc = jnp.clip(st["slot"] - st["msg_birth"] + 1, 0,
                               self.cfg.hist_bins - 1)
            st["lat_hist"] = st["lat_hist"].at[
                jnp.where(deliver_local, lat_loc, 0)].add(
                jnp.where(deliver_local, 1, 0))
        else:
            st["lat_hist"] = st["lat_hist"].at[1].add(n_local)
        return st

    def _mean_msg(self, t: Traffic) -> float:
        if t.pattern == "mice_elephant":
            return (1 - t.elephant_frac) * 1.0 + t.elephant_frac * t.elephant_size
        return 1.0

    # ------------------------------------------------------------------ #
    def _crossbar_round(self, st, key, ep_active: bool):
        """One crossbar sub-round: VC pre-arbitration, routing, output
        arbitration, input-queue -> output-queue moves, ejections."""
        N, P, V, Q, S = self.N, self.P, self.V, self.Q, self.S
        OQ = self.cfg.out_queue
        k_vc, k_tie, k_arb = jax.random.split(key, 3)
        pallas = self.cfg.backend == "pallas"

        qlen3 = st["qlen"].reshape(N, P, V)
        # ---- VC pre-arbitration: one candidate VC per (switch, in-port) ----
        vc_rand = jax.random.uniform(k_vc, (N, P, V))
        if pallas:
            from ..kernels.switch_arb.ops import vc_prearb_op
            vc_sel, has_pkt = vc_prearb_op(qlen3, vc_rand)
        else:
            vc_prio = jnp.where(qlen3 > 0, vc_rand, -1.0)
            vc_sel = jnp.argmax(vc_prio, axis=2)                 # [N,P]
            # the selected VC holds a packet iff any VC does
            has_pkt = jnp.max(vc_prio, axis=2) >= 0.0

        q_idx = (jnp.arange(N * P, dtype=jnp.int32).reshape(N, P) * V
                 + vc_sel.astype(jnp.int32)).reshape(-1)           # [N*P]
        head = st["qbuf"].reshape(-1)[q_idx * Q + st["qhead"][q_idx]]
        net_pkt = jnp.where(has_pkt.reshape(-1), head, -1)

        # endpoint (NIC) heads — only in sub-round 0 (NIC link rate = 1/slot)
        ep_head = st["eq_buf"].reshape(-1)[
            jnp.arange(S, dtype=jnp.int32) * self.QE + st["eq_head"]]
        ep_pkt = jnp.where((st["eq_len"] > 0) & ep_active, ep_head, -1)

        # ---- unified requester table (static geometry from __init__) ----
        cur = self.cur                                             # [NR]
        pkt = jnp.concatenate([net_pkt, ep_pkt])
        NR = self.NR
        valid = pkt >= 0
        pkt0 = jnp.maximum(pkt, 0)

        bh = st["p_bh"][pkt0]
        hops = bh & 0xFF
        sd = st["p_sd"][pkt0]
        t_lr = sd & 0xFFFF
        # destination switch is a pure function of the destination leaf:
        # a cache-resident [N1] gather, not another pool-wide attribute
        eject = valid & (cur == self.leaf_ids[t_lr])
        route = valid & ~eject
        pol = self.cfg.policy
        hf = self.has_failures
        if hf:
            # live tables from the state; live_row gates every policy's
            # candidate set to live ports (dead switches contribute
            # all-dead rows, so their packets freeze until drop/restore)
            tmin = st["tbl_min"]
            taway = st.get("tbl_away")
            dflat = st["tbl_dist"]
            live_row = st["link_up"].reshape(N, P)[cur]            # [NR,P]
        else:
            tmin = self.min_mask
            taway = self.away_mask
            dflat = self.dist.reshape(-1)
            live_row = None
        if pol == "polarized":
            # full Polarized classification from toward/away bits alone:
            # Forward = away-from-s & toward-t, Expansion = away & away
            # (while d_cs < d_ct), Contraction = toward & toward (once
            # d_cs >= d_ct); d(n,t) for the hop budget is d(c,t)+away-toward
            s_lr = sd >> 16
            dn_t = self._port_bits(tmin, t_lr, cur)
            up_t = self._port_bits(taway, t_lr, cur)
            dn_s = self._port_bits(tmin, s_lr, cur)
            up_s = self._port_bits(taway, s_lr, cur)
            d_ct = dflat[t_lr * N + cur]
            d_cs = dflat[s_lr * N + cur]
            src_side = (d_cs < d_ct)[:, None]
            deroute = (up_s & up_t & src_side) | (dn_s & dn_t & ~src_side)
            d_nt = (d_ct[:, None] + up_t.astype(jnp.int16)
                    - dn_t.astype(jnp.int16))
            budget_ok = (hops[:, None] + 1 + d_nt) <= self.cfg.max_hops
            allowed = (up_s & dn_t) | (deroute & budget_ok)
            next_vc = jnp.minimum(hops // 2, V - 1)
        elif pol == "degraded":
            # FatPaths-style layered recovery: minimal toward ports while
            # any are live; when failures kill them all, fall back to live
            # away ports (one layer up, +2 hops round trip) within the hop
            # budget.  On a pristine fabric the fallback never fires, so
            # degraded == minimal_adaptive bit for bit.
            toward = self._port_bits(tmin, t_lr, cur)
            away = self._port_bits(taway, t_lr, cur)
            if hf:
                toward = toward & live_row
                away = away & live_row
            d_ct = dflat[t_lr * N + cur]
            no_min = ~jnp.any(toward, axis=1)
            budget_ok = (hops[:, None] + 2 + d_ct[:, None]) <= self.cfg.max_hops
            fallback = no_min[:, None] & away & budget_ok
            deroute = fallback
            allowed = toward | fallback
            next_vc = jnp.minimum(hops // 2, V - 1)
        elif pol in ("minimal_adaptive", "ksp"):
            allowed = self._port_bits(tmin, t_lr, cur)
            deroute = jnp.zeros_like(allowed)
            next_vc = jnp.minimum(hops // 2, V - 1)
        elif pol in ("ugal", "valiant"):
            mid_lr = st["p_mid"][pkt0]
            tgt = jnp.where(mid_lr >= 0, mid_lr, t_lr)
            allowed = self._port_bits(tmin, tgt, cur)
            deroute = jnp.zeros_like(allowed)
            next_vc = jnp.minimum(hops, V - 1)
        else:
            raise ValueError(pol)
        if hf and pol != "degraded":   # degraded gated its layers above
            allowed = allowed & live_row

        # congestion signal: local output queue + downstream input queue for
        # the flight VC.  Credit = room in the local output queue.  Both
        # lookups are contiguous row gathers from the V-major layout
        # (row = switch * V + flight VC), built once per round.
        oq_v = st["oq_len"].reshape(N, P, V).transpose(0, 2, 1) \
            .reshape(N * V, P)
        qd_v = st["qlen"][self._dq_perm].reshape(N * V, P)
        occ_row = cur * V + next_vc                                # [NR]
        oq_occ = oq_v[occ_row]                                     # [NR,P]
        occ = oq_occ + qd_v[occ_row]
        credit = oq_occ < OQ
        tie = jax.random.uniform(k_tie, (NR, P))
        rnd = jax.random.randint(k_arb, (NR,), 0, 1 << 8, dtype=jnp.int32)
        mask = allowed & credit
        if pol == "ksp":        # random walk: score is the tiebreak alone
            occ = jnp.zeros_like(occ)
            deroute = jnp.zeros_like(deroute)
        if pallas:
            # fused score-evaluation + segmented output arbitration kernel
            from ..kernels.switch_arb.ops import switch_arbitrate_flat
            port, win, seg = switch_arbitrate_flat(
                occ, deroute, mask, tie, route, rnd, self._lo,
                penalty=float(self.cfg.deroute_penalty),
                row_of=self._row_of, n_switches=N, r_max=self.R_max)
        else:
            score = (occ.astype(jnp.float32)
                     + self.cfg.deroute_penalty * deroute + tie)
            score = jnp.where(mask, score, BIG)
            port = jnp.argmin(score, axis=1).astype(jnp.int32)
            can_move = route & (jnp.min(score, axis=1) < BIG)

            # ---- output arbitration: one grant per (switch, out-port) ----
            out_key = cur * P + port                               # [NR]
            # unique int32 priorities: 8 random high bits | requester index
            prio = (rnd << 23) | self._lo
            prio = jnp.where(can_move, prio, -1)
            # dense per-switch segmented max — the same scatter-free
            # reduction the Pallas kernel runs (static row gathers; rows
            # with no requester carry priority -1)
            prio_d = jnp.where(self._dense_valid,
                               prio[self._dense_src].reshape(N, self.R_max),
                               -1)
            port_d = port[self._dense_src].reshape(N, self.R_max)
            hot = ((port_d[:, :, None]
                    == jnp.arange(P, dtype=jnp.int32))
                   & (prio_d >= 0)[:, :, None])                    # [N,R,P]
            seg = jnp.max(jnp.where(hot, prio_d[:, :, None], -1),
                          axis=1).reshape(-1)                      # [N*P]
            win = can_move & (seg[out_key] == prio)

        # ---- moves: input queue -> output queue ----
        # XLA CPU scatters serialize element by element, so the queue
        # updates are phrased as gathers + dense one-hot selects instead:
        # the winning priority word per output port *is* the inverted grant
        # (its low 23 bits are the unique flat requester index).
        exist = seg >= 0                                           # [N*P]
        wlo = jnp.where(exist, seg & ((1 << 23) - 1), 0)
        win_pkt = pkt0[wlo]                                        # [N*P]
        win_vc = next_vc[wlo]
        v_ids = jnp.arange(V, dtype=jnp.int32)
        push = (exist[:, None] & (win_vc[:, None] == v_ids)).reshape(-1)
        pos = (st["oq_head"] + st["oq_len"]) % OQ                  # [NQ]
        slot_hot = push[:, None] & (jnp.arange(OQ, dtype=jnp.int32)[None, :]
                                    == pos[:, None])
        win_pkt_q = jnp.broadcast_to(win_pkt[:, None],
                                     (N * P, V)).reshape(-1)       # [NQ]
        oq_buf = jnp.where(slot_hot, win_pkt_q[:, None], st["oq_buf"])
        oq_len = st["oq_len"] + push.astype(jnp.int32)

        # pops: winners + ejectors leave their input queues (each
        # (switch, in-port) pops at most its one pre-arbitrated VC — dense)
        leave = win | eject
        net_leave = leave[: N * P]
        pop = (net_leave[:, None]
               & (vc_sel.reshape(-1).astype(jnp.int32)[:, None] == v_ids)
               ).reshape(-1).astype(jnp.int32)                     # [NQ]
        qhead = (st["qhead"] + pop) % Q
        qlen = st["qlen"] - pop
        ep_leave = leave[N * P:]
        eq_head = (st["eq_head"] + ep_leave.astype(jnp.int32)) % self.QE
        eq_len = st["eq_len"] - ep_leave.astype(jnp.int32)

        # ejections: free pool (O(N*P) free-list push), record stats.  Only
        # network input ports can eject (same-leaf traffic never enters the
        # network), so the pool scatters index the net rows alone.
        ej_n = eject[: N * P]
        pkt_n = pkt0[: N * P]
        erank = jnp.cumsum(ej_n.astype(jnp.int32)) - 1
        fpos = (st["fl_head"] + st["fl_len"] + jnp.maximum(erank, 0)) % self.pool
        fl_buf = st["fl_buf"].at[jnp.where(ej_n, fpos, self.pool)].set(
            pkt_n, mode="drop")
        fl_len = st["fl_len"] + ej_n.sum(dtype=jnp.int32)
        lat = jnp.clip(st["slot"] - (bh[: N * P] >> 8) + 1, 0,
                       self.cfg.hist_bins - 1)
        lat_hist = st["lat_hist"].at[jnp.where(ej_n, lat, 0)].add(
            jnp.where(ej_n, 1, 0))

        st = dict(st)
        st["oq_buf"] = oq_buf.reshape(self.NQ, OQ)
        st["oq_len"] = oq_len
        st["qhead"], st["qlen"] = qhead, qlen
        st["eq_head"], st["eq_len"] = eq_head, eq_len
        st["fl_buf"], st["fl_len"] = fl_buf, fl_len
        st["lat_hist"] = lat_hist
        st["ejected"] = st["ejected"] + eject.sum(dtype=jnp.int32)
        st["hop_sum"] = st["hop_sum"] + jnp.where(eject, hops, 0).sum(dtype=jnp.int32)
        return st

    def _link_phase(self, st, key):
        """Move one packet per link: output-queue head -> downstream input
        queue (credit-checked), incrementing hop counts and assigning the
        packet to the downstream switch."""
        N, P, V, Q = self.N, self.P, self.V, self.Q
        OQ = self.cfg.out_queue
        # pick one non-empty output VC per (switch, port) with downstream room
        oq_len3 = st["oq_len"].reshape(N, P, V)
        np_idx = jnp.arange(N * P, dtype=jnp.int32)
        sw = np_idx // P
        pt = np_idx % P
        nb = self.nbrs0[sw, pt]                                     # [N*P]
        nbp = self.nbr_port[sw, pt]
        link_ok = self.valid_port[sw, pt]
        if self.has_failures:
            link_ok = link_ok & st["link_up"]
        # downstream input queue per VC
        dq = (nb[:, None] * P + nbp[:, None]) * V + jnp.arange(V, dtype=jnp.int32)
        room = st["qlen"][dq] < Q                                   # [N*P,V]
        nonempty = oq_len3.reshape(N * P, V) > 0
        cand = nonempty & room & link_ok[:, None]
        prio = jnp.where(cand, jax.random.uniform(key, (N * P, V)), -1.0)
        vcs = jnp.argmax(prio, axis=1).astype(jnp.int32)
        send = jnp.take_along_axis(cand, vcs[:, None], 1)[:, 0]

        src_q = np_idx * V + vcs
        pkt = st["oq_buf"].reshape(-1)[src_q * OQ + st["oq_head"][src_q]]
        pkt0 = jnp.maximum(pkt, 0)

        # scatter-free queue updates: each (switch, port) pops at most one
        # VC (dense one-hot), and each *input* port receives from exactly
        # one static upstream output port, so receives are a gather through
        # the link-reversal map instead of a scatter through ``dq``.
        v_ids = jnp.arange(V, dtype=jnp.int32)
        pop = (send[:, None] & (vcs[:, None] == v_ids)
               ).reshape(-1).astype(jnp.int32)                      # [NQ]
        oq_head = (st["oq_head"] + pop) % OQ
        oq_len = st["oq_len"] - pop
        recv = send[self._rev_idx] & self.valid_port.reshape(-1)    # [N*P]
        recv_vc = vcs[self._rev_idx]
        recv_pkt = pkt0[self._rev_idx]
        push = (recv[:, None] & (recv_vc[:, None] == v_ids)).reshape(-1)
        qpos = (st["qhead"] + st["qlen"]) % Q                       # [NQ]
        slot_hot = push[:, None] & (jnp.arange(Q, dtype=jnp.int32)[None, :]
                                    == qpos[:, None])
        recv_pkt_q = jnp.broadcast_to(recv_pkt[:, None],
                                      (N * P, V)).reshape(-1)
        qbuf = jnp.where(slot_hot, recv_pkt_q[:, None], st["qbuf"])
        qlen = st["qlen"] + push.astype(jnp.int32)

        # hop increment on the packed born|hops word (hops are the low byte)
        p_bh = st["p_bh"].at[jnp.where(send, pkt0, self.pool)].add(
            1, mode="drop")
        # clear UGAL/Valiant intermediate when the packet reaches it (the
        # other policies never set p_mid, so they skip the bookkeeping)
        if self.cfg.policy in ("ugal", "valiant"):
            mid_lr = st["p_mid"][pkt0]
            reached_mid = send & (mid_lr >= 0) & (
                nb == self.leaf_ids[jnp.maximum(mid_lr, 0)])
            p_mid = st["p_mid"].at[jnp.where(reached_mid, pkt0, self.pool)
                                   ].set(-1, mode="drop")
        else:
            p_mid = st["p_mid"]

        st = dict(st)
        st["qbuf"] = qbuf
        st["qlen"] = qlen
        st["oq_head"], st["oq_len"] = oq_head, oq_len
        st["p_bh"], st["p_mid"] = p_bh, p_mid
        return st

    def _step(self, st, traffic: Traffic, chunk=None, max_slots=None):
        key, k_inj, k_link, *k_xb = jax.random.split(
            st["key"], 3 + self.cfg.speedup)
        st = dict(st)
        st["key"] = key
        st = self._inject(st, k_inj, traffic)
        for r in range(self.cfg.speedup):
            st = self._crossbar_round(st, k_xb[r], ep_active=True)
        st = self._link_phase(st, k_link)
        st["slot"] = st["slot"] + 1
        if traffic.pattern == "program":
            st = self._advance_program(st, traffic, chunk, max_slots)
        return st

    # ------------------------------------------------------------------ #
    # on-device phase scheduler for compiled workload programs
    # ------------------------------------------------------------------ #
    def _advance_program(self, st, traffic: Traffic, chunk, max_slots):
        """Per-slot phase bookkeeping for ``Traffic("program")``.

        ``barrier``: when the running phase's ejection target is met (or
        its chunk-granular ``max_slots`` budget expires), record the exact
        completion slot in ``phase_done``, bump ``phase``, and reset the
        transient state (queues' heads/lens, free-list, PRNG key, slot,
        per-endpoint message program) to what a fresh ``make_state`` would
        hold — so every phase is bitwise-identical to a standalone
        host-loop ``run_completion`` and ``phase_done`` holds per-phase
        durations.

        ``window``: no resets; ejections are cumulative, and phase ``p``
        completes once total deliveries reach ``expected_cum[p]``
        (``phase_done`` holds cumulative completion slots).
        """
        NP = traffic.n_phases
        pids = jnp.arange(NP, dtype=jnp.int32)
        st = dict(st)
        if traffic.schedule == "window":
            newly = (st["phase_done"] < 0) & (
                st["ejected"] >= st["prog_expected_cum"])
            st["phase_done"] = jnp.where(newly, st["slot"], st["phase_done"])
            st["phase_ok"] = st["phase_ok"] | newly
            st["phase"] = jnp.sum((st["phase_done"] >= 0).astype(jnp.int32))
            return st

        ph = st["phase"]
        active = ph < NP
        exp = st["prog_expected"][jnp.minimum(ph, NP - 1)]
        natural = active & (st["ejected"] >= exp)
        if max_slots is not None:
            # mirror the host loop's timeout semantics: it only notices a
            # stuck phase at a chunk boundary past max_slots, and records
            # that chunk-granular slot
            budget_gone = st["slot"] >= max_slots
            if chunk is not None:
                budget_gone &= st["slot"] % chunk == 0
            forced = active & budget_gone & ~natural
            crossed = natural | forced
        else:
            crossed = natural
        hot = (pids == ph) & crossed
        st["phase_done"] = jnp.where(hot, st["slot"], st["phase_done"])
        st["phase_ok"] = st["phase_ok"] | (hot & natural)
        st["phase"] = ph + crossed.astype(jnp.int32)
        # fresh-state reset: only what the next phase can observe — queue
        # buffers keep stale ids (unreachable at length 0) and pool
        # attributes keep stale packets (unreachable once the free-list is
        # re-initialized), exactly as behaviour-neutral as in a fresh state
        zero = lambda k: jnp.where(crossed, 0, st[k])
        st["slot"] = zero("slot")
        st["ejected"] = zero("ejected")
        st["prog"] = zero("prog")
        st["msg_rem"] = zero("msg_rem")
        for k in ("qhead", "qlen", "oq_head", "oq_len", "eq_head", "eq_len",
                  "fl_head"):
            st[k] = zero(k)
        st["fl_buf"] = jnp.where(crossed,
                                 jnp.arange(self.pool, dtype=jnp.int32),
                                 st["fl_buf"])
        st["fl_len"] = jnp.where(crossed, self.pool, st["fl_len"])
        st["key"] = jnp.where(crossed, st["key0"], st["key"])
        return st

    # ------------------------------------------------------------------ #
    # ``donate_argnums=(1,)``: the state pytree is updated in place by the
    # runtime instead of double-buffering every array per chunk.  The input
    # dict is CONSUMED — callers must keep using the returned state.
    # ------------------------------------------------------------------ #
    @functools.partial(jax.jit, static_argnums=(0, 2, 3), donate_argnums=(1,))
    def _run_chunk_jit(self, st, traffic: Traffic, n_slots: int):
        def body(carry, _):
            return self._step(carry, traffic), None
        st, _ = jax.lax.scan(body, st, None, length=n_slots)
        return st

    def run_chunk(self, st, traffic: Traffic, n_slots: int):
        """Advance ``n_slots`` slots.  ``st`` is donated (consumed)."""
        with _quiet_cpu_donation():
            return self._run_chunk_jit(st, traffic, n_slots)

    @functools.partial(jax.jit, static_argnums=(0, 2, 3), donate_argnums=(1,))
    def _run_chunk_batch_jit(self, st, traffic: Traffic, n_slots: int):
        def one(s):
            def body(carry, _):
                return self._step(carry, traffic), None
            return jax.lax.scan(body, s, None, length=n_slots)[0]
        return jax.vmap(one)(st)

    def run_chunk_batch(self, st, traffic: Traffic, n_slots: int):
        """``run_chunk`` vmapped over a leading ``[R]`` replica axis.
        ``st`` is donated (consumed)."""
        with _quiet_cpu_donation():
            return self._run_chunk_batch_jit(st, traffic, n_slots)

    # ------------------------------------------------------------------ #
    # sharded execution (the repro.parallel.sharding simulator profile)
    # ------------------------------------------------------------------ #
    def batch_pspecs(self, st, replica_axis: str) -> dict:
        """Per-entry ``PartitionSpec``s sharding the leading replica dim.

        Replica-invariant program arrays (``_PROG_SHARED``, one device
        copy in a batched state) stay replicated; everything else shards
        dim 0 over ``replica_axis``.
        """
        from jax.sharding import PartitionSpec as P
        specs = {}
        for k, v in st.items():
            nd = jnp.asarray(v).ndim
            if nd == self._PROG_SHARED.get(k, -1):
                specs[k] = P(*([None] * nd))
            else:
                specs[k] = P(replica_axis, *([None] * (nd - 1)))
        return specs

    def _sharded_chunk_fn(self, traffic: Traffic, n_slots: int, mesh,
                          replica_axis: str, spec_items):
        """Compiled ``shard_map``-over-replicas chunk executable.

        Cached per instance on the static shape of the call (traffic,
        slot count, mesh, state layout) — NOT in a class-level lru_cache,
        which would pin ``self`` (and its multi-hundred-MB device mask
        tables at paper scale) past :meth:`close` for the life of the
        process.  ``close()`` drops the cache with the instance.
        """
        key = (traffic, n_slots, mesh, replica_axis, spec_items)
        cached = self._sharded_cache.get(key)
        if cached is not None:
            return cached
        from .. import _jax_compat  # noqa: F401 — polyfills jax.shard_map
        specs = dict(spec_items)
        # shared (replicated) entries ride the inner vmap unbatched
        axes = {k: 0 if (len(p) and p[0] == replica_axis) else None
                for k, p in specs.items()}

        def chunk(s):
            def body(carry, _):
                return self._step(carry, traffic), None
            return jax.lax.scan(body, s, None, length=n_slots)[0]

        local = jax.vmap(chunk, in_axes=(axes,), out_axes=axes)
        shmapped = jax.shard_map(local, mesh=mesh, in_specs=(specs,),
                                 out_specs=specs, check_vma=False)
        fn = jax.jit(shmapped, donate_argnums=(0,))
        self._sharded_cache[key] = fn
        return fn

    def run_chunk_sharded(self, st, traffic: Traffic, n_slots: int,
                          sharder):
        """``run_chunk_batch`` with the replica axis split over the
        devices of ``sharder.mesh`` via ``jax.shard_map``.

        Replicas are fully independent, so each device steps its own
        ``R / n_devices`` slice with zero cross-device traffic and every
        replica is **bitwise identical** to the single-device
        ``run_chunk_batch`` result (locked by
        ``tests/test_sharded_engine.py``).  ``st`` is donated (consumed).
        ``sharder`` is a :class:`repro.parallel.sharding.Sharder` with the
        simulator profile (``Sharder.for_simulator()``); the replica count
        must divide evenly over the mesh's ``replica`` axis.
        """
        axis = sharder.rules.replica
        if axis is None:
            raise ValueError("sharder has no replica axis; build it with "
                             "Sharder.for_simulator()")
        n_dev = sharder.mesh.shape[axis]
        r = st["ejected"].shape[0] if st["ejected"].ndim else None
        if r is None:
            raise ValueError("run_chunk_sharded needs a batched state "
                             "(make_batch_state)")
        if r % n_dev:
            raise ValueError(f"{r} replicas do not divide over {n_dev} "
                             f"devices on mesh axis {axis!r}")
        specs = self.batch_pspecs(st, axis)
        fn = self._sharded_chunk_fn(traffic, n_slots, sharder.mesh, axis,
                                    tuple(sorted(specs.items())))
        with _quiet_cpu_donation():
            return fn(st)

    def state_shardings(self, st, sharder) -> dict:
        """Per-entry :class:`NamedSharding` for the per-switch layout.

        Queue-major arrays (leading dim ``N*P*V`` — input/output queues)
        and endpoint-major arrays (leading dim ``S`` — NIC queues,
        message programs) shard dim 0 over the mesh's ``switch`` axis
        (endpoints are leaf-major, so an endpoint split is a switch
        split); pool-indexed and scalar entries are replicated, since
        packets cross switch shards at the link phase.  Dims that the
        device count does not divide fall back to replicated (the
        ``constrain_safe`` rule).
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        axis = sharder.rules.switch
        if axis is None:
            raise ValueError("sharder has no switch axis; build it with "
                             "Sharder.for_simulator(axis='switch')")
        n_dev = sharder.mesh.shape[axis]
        switch_major = {self.NQ, self.S}
        out = {}
        for k, v in st.items():
            arr = jnp.asarray(v)
            shard = (arr.ndim >= 1 and arr.shape[0] in switch_major
                     and arr.shape[0] % n_dev == 0)
            spec = (P(axis, *([None] * (arr.ndim - 1))) if shard
                    else P(*([None] * arr.ndim)))
            out[k] = NamedSharding(sharder.mesh, spec)
        return out

    def shard_state(self, st, sharder) -> dict:
        """Place a scalar state onto the ``switch``-axis layout.

        The jitted step functions then run under GSPMD partitioning —
        same computation, communication inserted where packets cross
        shards — so results stay bitwise-identical to the unsharded run.
        """
        shardings = self.state_shardings(st, sharder)
        return {k: jax.device_put(jnp.asarray(v), shardings[k])
                for k, v in st.items()}

    @functools.partial(jax.jit, static_argnums=(0, 2, 4, 5),
                       donate_argnums=(1,))
    def _completion_loop(self, st, traffic: Traffic, expected,
                         chunk: int, max_slots: int):
        """Device-side completion detection: a ``lax.while_loop`` over
        ``chunk``-slot scans that stops once every replica has ejected
        ``expected`` packets (or ``max_slots`` elapsed).  ``done`` records
        the *exact* slot at which each replica's ejection counter crossed
        ``expected`` (-1 while still running) — completion resolution is one
        slot, not one chunk, and there are no per-chunk host syncs.

        Works on scalar state (0-d ``ejected``) and batched state alike:
        the step is vmapped when a replica axis is present.
        """
        batched = st["ejected"].ndim == 1
        step = lambda s: self._step(s, traffic)
        if batched:
            step = jax.vmap(step)
        expected = jnp.asarray(expected, jnp.int32)

        def slot_body(carry, _):
            s, done = carry
            s = step(s)
            newly = (s["ejected"] >= expected) & (done < 0)
            done = jnp.where(newly, s["slot"], done)
            return (s, done), None

        def chunk_body(carry):
            return jax.lax.scan(slot_body, carry, None, length=chunk)[0]

        def cond(carry):
            s, done = carry
            running = ~jnp.all(done >= 0)
            return running & (jnp.max(s["slot"]) < max_slots)

        done0 = jnp.full_like(st["ejected"], -1)
        return jax.lax.while_loop(cond, chunk_body, (st, done0))

    @functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6),
                       donate_argnums=(1, 2))
    def _completion_loop_bounded(self, st, done, traffic: Traffic, expected,
                                 chunk: int, max_slots: int, budget: int):
        """:meth:`_completion_loop` with a chunk *budget*: runs at most
        ``budget`` chunk bodies, then returns control to the host — the
        checkpointable chunk boundary.  The chunk body is byte-for-byte
        the unbounded loop's, so a sequence of bounded segments (resumed
        from snapshots of ``(state, done)``) replays the uninterrupted
        ``_completion_loop`` bitwise.  ``done`` is carried explicitly so a
        resumed run keeps the exact completion slots already recorded.
        """
        batched = st["ejected"].ndim == 1
        step = lambda s: self._step(s, traffic)
        if batched:
            step = jax.vmap(step)
        expected = jnp.asarray(expected, jnp.int32)

        def slot_body(carry, _):
            s, done = carry
            s = step(s)
            newly = (s["ejected"] >= expected) & (done < 0)
            done = jnp.where(newly, s["slot"], done)
            return (s, done), None

        def chunk_body(carry):
            s, done, it = carry
            (s, done), _ = jax.lax.scan(slot_body, (s, done), None,
                                        length=chunk)
            return (s, done, it + 1)

        def cond(carry):
            s, done, it = carry
            running = ~jnp.all(done >= 0)
            return (running & (jnp.max(s["slot"]) < max_slots)
                    & (it < budget))

        st, done, _ = jax.lax.while_loop(
            cond, chunk_body, (st, done, jnp.zeros((), jnp.int32)))
        return st, done

    # ------------------------------------------------------------------ #
    # high-level drivers
    # ------------------------------------------------------------------ #
    @staticmethod
    def _check_arrival(traffic: Traffic) -> None:
        # one validator for spec layer and engine (repro.workloads.patterns)
        check_arrival(traffic.process, traffic.load,
                      pareto_alpha=traffic.pareto_alpha,
                      pareto_cap=traffic.pareto_cap,
                      diurnal_amp=traffic.diurnal_amp,
                      diurnal_period=traffic.diurnal_period,
                      arr_depth=traffic.arr_depth)

    def make_state(self, traffic: Traffic, seed: int = 0) -> dict:
        if self._closed:
            raise RuntimeError("Simulator is closed")
        if traffic.pattern == "shift" and traffic.shift % self.S == 0:
            raise ValueError(
                f"shift offset {traffic.shift} is 0 mod {self.S} endpoints "
                "(every message would be self-addressed)")
        if traffic.pattern == "tornado" and self.n1 < 2:
            raise ValueError("tornado needs at least 2 leaves")
        if traffic.pattern == "hotspot" and traffic.hot_count > self.S:
            raise ValueError(
                f"hot_count {traffic.hot_count} > endpoints {self.S} "
                "(out-of-range destinations would silently clamp)")
        if traffic.pattern == "bursty":
            if traffic.load > traffic.burst_load:
                raise ValueError(
                    f"bursty load {traffic.load} exceeds burst_load "
                    f"{traffic.burst_load}: the long-run offered load can "
                    "never exceed the in-burst intensity")
            duty_max = traffic.burst_len / (traffic.burst_len + 1.0)
            if traffic.load > traffic.burst_load * duty_max:
                raise ValueError(
                    f"bursty duty cycle {traffic.load / traffic.burst_load:.3f} "
                    f"is unreachable: with mean burst length "
                    f"{traffic.burst_len} the ON fraction tops out at "
                    f"{duty_max:.3f} (even at p_on = 1), so the long-run "
                    "offered load would silently undershoot `load` — "
                    "raise burst_len or burst_load")
        if traffic.pattern == "arrival":
            self._check_arrival(traffic)
        rng = np.random.default_rng(seed)
        seed_arrays = {}
        if traffic.pattern == "rep":
            seed_arrays["perm"] = rng.permutation(self.S).astype(np.int32)
        if traffic.pattern == "rsp":
            seed_arrays["sigma"] = rng.permutation(self.n1).astype(np.int32)
        if traffic.pattern == "bursty":
            seed_arrays["burst"] = np.zeros(self.S, np.int32)  # all OFF
        if traffic.pattern == "phase":
            seed_arrays["partner"] = np.zeros(self.S, np.int32)  # set by caller
        if traffic.pattern == "arrival":
            D = traffic.arr_depth
            seed_arrays["arr_times"] = np.zeros((self.S, D), np.int32)
            seed_arrays["arr_sizes"] = np.zeros((self.S, D), np.int32)
            seed_arrays["arr_head"] = np.zeros(self.S, np.int32)
            seed_arrays["arr_len"] = np.zeros(self.S, np.int32)
            seed_arrays["msg_birth"] = np.zeros(self.S, np.int32)
            seed_arrays["arrived"] = np.zeros((), np.int32)
            seed_arrays["arr_drop"] = np.zeros((), np.int32)
        st = self.init_state(traffic, seed_arrays)
        if seed:  # thread the run seed into the sim PRNG (seed=0: legacy key)
            # fold_in, not key arithmetic: PRNGKey(cfg.seed + (seed << 16))
            # collides distinct (cfg.seed, seed) pairs, e.g. (65536, 0) with
            # (0, 1)
            st["key"] = jax.random.fold_in(
                jax.random.PRNGKey(self.cfg.seed), seed)
        return st

    def make_batch_state(self, traffic: Traffic, seeds) -> dict:
        """Stack R independently-seeded states on a leading replica axis.

        Each replica's slice is exactly the state ``make_state(traffic, s)``
        would produce — seed-dependent traffic permutations (``rep``/``rsp``)
        and the PRNG stream both vary per replica — so a vmapped run is
        replica-for-replica identical to R scalar runs.
        """
        states = [self.make_state(traffic, seed=int(s)) for s in seeds]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)

    @staticmethod
    def free_ids(st) -> np.ndarray:
        """Host-side view of the free packet ids (the fl_buf ring window)
        of a scalar state.  ``pool - fl_len`` packets are in flight."""
        buf = np.asarray(st["fl_buf"])
        head, n = int(st["fl_head"]), int(st["fl_len"])
        return buf[(head + np.arange(n)) % buf.shape[0]]

    @staticmethod
    def arrival_backlog(st) -> int:
        """Host-side sum of packets still queued in the arrival FIFOs of a
        scalar ``Traffic("arrival")`` state (the live ring windows).  With
        ``sum(msg_rem)`` (popped but not yet injected) this closes the
        open-loop conservation ledger:
        ``arrived == backlog + sum(msg_rem) + created``."""
        sizes = np.asarray(st["arr_sizes"])
        head = np.asarray(st["arr_head"])
        ln = np.asarray(st["arr_len"])
        D = sizes.shape[1]
        idx = (head[:, None] + np.arange(D)[None, :]) % D
        live = np.arange(D)[None, :] < ln[:, None]
        return int(np.take_along_axis(sizes, idx, 1)[live].sum())

    @staticmethod
    def _counter_snapshot(st) -> dict:
        # fresh device buffers (`x + 0`), not views: the source state is
        # about to be donated to the measurement chunk
        return {k: st[k] + 0 for k in ("ejected", "hop_sum", "pool_stall")}

    def run_throughput(self, traffic: Traffic, warm: int = 200,
                       measure: int = 400, seed: int = 0) -> dict:
        st = self.make_state(traffic, seed)
        st = self.run_chunk(st, traffic, warm)
        base = self._counter_snapshot(st)
        st = self.run_chunk(st, traffic, measure)
        # warm/measure deltas computed on device, fetched in ONE transfer
        # (the old path issued three blocking int() syncs per phase)
        m = jax.device_get({k: st[k] - base[k] for k in base}
                           | {"ejected_total": st["ejected"]})
        return {
            "throughput": int(m["ejected"]) / (self.S * measure),
            # steady-state window only: the cumulative ratio used to fold
            # warmup transients into the reported hop count
            "avg_hops": int(m["hop_sum"]) / max(int(m["ejected"]), 1),
            "ejected": int(m["ejected_total"]),
            "pool_stall": int(m["pool_stall"]),
            "state": st,
        }

    def run_throughput_batch(self, traffic: Traffic, seeds,
                             warm: int = 200, measure: int = 400,
                             sharder=None) -> dict:
        """Batched ``run_throughput``: one compiled executable, R replicas.

        Returns per-replica ``[R]`` arrays for every metric.  With a
        ``sharder`` (simulator profile, replica axis) the replica batch is
        split over the mesh devices via :meth:`run_chunk_sharded` — same
        per-replica results, bitwise.
        """
        if sharder is not None:
            chunk = lambda s, n: self.run_chunk_sharded(s, traffic, n,
                                                        sharder)
        else:
            chunk = lambda s, n: self.run_chunk_batch(s, traffic, n)
        st = self.make_batch_state(traffic, seeds)
        st = chunk(st, warm)
        base = self._counter_snapshot(st)
        st = chunk(st, measure)
        m = jax.device_get({k: st[k] - base[k] for k in base}
                           | {"ejected_total": st["ejected"]})
        e, h = np.asarray(m["ejected"]), np.asarray(m["hop_sum"])
        return {
            "throughput": e / (self.S * measure),
            "avg_hops": h / np.maximum(e, 1),
            "ejected": np.asarray(m["ejected_total"]),
            "pool_stall": np.asarray(m["pool_stall"]),
            "state": st,
        }

    def run_latency(self, traffic: Traffic, warm: int = 200,
                    measure: int = 600, seed: int = 0) -> dict:
        st = self.make_state(traffic, seed)
        st = self.run_chunk(st, traffic, warm)
        base = st["lat_hist"] + 0            # fresh buffer; st is donated
        st = self.run_chunk(st, traffic, measure)
        hist = np.asarray(jax.device_get(st["lat_hist"] - base))
        return {"hist": hist, **percentiles(hist, LATENCY_QS)}

    def run_latency_batch(self, traffic: Traffic, seeds,
                          warm: int = 200, measure: int = 600) -> dict:
        """Batched ``run_latency``: per-replica histograms and percentile
        lists (``{"p0.5": [R floats], ...}``; NaN where a replica ejected
        nothing in the window)."""
        st = self.make_batch_state(traffic, seeds)
        st = self.run_chunk_batch(st, traffic, warm)
        base = st["lat_hist"] + 0
        st = self.run_chunk_batch(st, traffic, measure)
        hist = np.asarray(jax.device_get(st["lat_hist"] - base))  # [R, bins]
        per = [percentiles(row, LATENCY_QS) for row in hist]
        out = {"hist": hist}
        for q in LATENCY_QS:
            k = f"p{q}"
            out[k] = np.asarray([p[k] for p in per])
        return out

    # ------------------------------------------------------------------ #
    # open-loop serving drivers (Traffic("arrival"))
    # ------------------------------------------------------------------ #
    def _serving_snapshot(self, st) -> dict:
        # fresh buffers (`+ 0`): the state is about to be donated
        return {k: st[k] + 0 for k in ("lat_hist", "ejected", "arrived",
                                       "arr_drop", "pool_stall")}

    @staticmethod
    def _serving_metrics(m: dict, S: int, measure: int) -> dict:
        """Window deltas -> serving record (offered/delivered in
        packets/slot/endpoint, latency percentiles incl. the SLO tail)."""
        hist = np.asarray(m["lat_hist"])
        delivered = np.asarray(m["ejected"], np.int64)
        accepted = np.asarray(m["arrived"], np.int64)
        dropped = np.asarray(m["arr_drop"], np.int64)
        denom = float(S * measure)
        out = {
            "hist": hist,
            "offered": (accepted + dropped) / denom,
            "delivered": delivered / denom,
            "dropped": dropped,
            "pool_stall": np.asarray(m["pool_stall"], np.int64),
        }
        if hist.ndim == 1:
            out.update(percentiles(hist, LATENCY_QS))
            out["offered"] = float(out["offered"])
            out["delivered"] = float(out["delivered"])
            out["dropped"] = int(out["dropped"])
            out["pool_stall"] = int(out["pool_stall"])
        else:
            per = [percentiles(row, LATENCY_QS) for row in hist]
            for q in LATENCY_QS:
                k = f"p{q}"
                out[k] = np.asarray([p[k] for p in per])
        return out

    def run_serving(self, traffic: Traffic, warm: int = 200,
                    measure: int = 600, seed: int = 0) -> dict:
        """Open-loop load-latency measurement: warm the arrival source,
        then measure offered vs delivered rate, source drops, and the
        latency histogram (birth-slot based, so source queueing counts)
        over ``measure`` slots.  One device fetch, like the other
        drivers."""
        if traffic.pattern != "arrival":
            raise ValueError(f"run_serving needs Traffic('arrival'), got "
                             f"{traffic.pattern!r}")
        st = self.make_state(traffic, seed)
        st = self.run_chunk(st, traffic, warm)
        base = self._serving_snapshot(st)
        st = self.run_chunk(st, traffic, measure)
        m = jax.device_get({k: st[k] - base[k] for k in base})
        return {**self._serving_metrics(m, self.S, measure), "state": st}

    def run_serving_batch(self, traffic: Traffic, seeds, warm: int = 200,
                          measure: int = 600) -> dict:
        """Batched ``run_serving``: per-replica ``[R]`` arrays (percentile
        entries NaN where a replica delivered nothing in the window)."""
        if traffic.pattern != "arrival":
            raise ValueError(f"run_serving needs Traffic('arrival'), got "
                             f"{traffic.pattern!r}")
        st = self.make_batch_state(traffic, seeds)
        st = self.run_chunk_batch(st, traffic, warm)
        base = self._serving_snapshot(st)
        st = self.run_chunk_batch(st, traffic, measure)
        m = jax.device_get({k: st[k] - base[k] for k in base})
        return {**self._serving_metrics(m, self.S, measure), "state": st}

    # ------------------------------------------------------------------ #
    # fault injection: live table updates + resilience driver
    # ------------------------------------------------------------------ #
    def update_tables(self, st, delta):
        """Scatter a :class:`repro.core.routing.TableDelta` into the
        state-resident device tables **in place** (donation-safe: the old
        table buffers are consumed).  Works on scalar and batched states;
        ``st`` is consumed — keep the returned dict.
        """
        if not self.has_failures:
            raise RuntimeError(
                "update_tables needs a Simulator built with a failure "
                "schedule (failures=...)")
        st = dict(st)
        batched = st["ejected"].ndim == 1
        n, w = self.N, self.W
        link_up = jnp.asarray(delta.link_up.reshape(-1))
        switch_up = jnp.asarray(delta.switch_up)
        if batched:
            r = st["ejected"].shape[0]
            link_up = jnp.tile(link_up[None], (r, 1))
            switch_up = jnp.tile(switch_up[None], (r, 1))
        st["link_up"], st["switch_up"] = link_up, switch_up
        k = delta.n_affected
        if k:
            rows = jnp.asarray(
                (delta.leaf_rows.astype(np.int64)[:, None] * n
                 + np.arange(n)[None, :]).reshape(-1).astype(np.int32))
            scatter = _scatter_rows_batch if batched else _scatter_rows
            with _quiet_cpu_donation():
                st["tbl_min"] = scatter(
                    st["tbl_min"], rows,
                    jnp.asarray(delta.min_rows.reshape(k * n, w)))
                if "tbl_away" in st:
                    st["tbl_away"] = scatter(
                        st["tbl_away"], rows,
                        jnp.asarray(delta.away_rows.reshape(k * n, w)))
                st["tbl_dist"] = scatter(
                    st["tbl_dist"], rows,
                    jnp.asarray(delta.dist_rows.reshape(-1)))
        return st

    def drop_dead_packets(self, st):
        """Free every packet stranded on a dead element (the
        ``policy="drop"`` schedule option): whole input+output queues of
        dead switches and whole output queues feeding dead links — every
        packet there is unreachable until restore, so the drop is exact.
        Freed ids return to the free-list ring; ``fail_drop`` counts them.
        Host-side surgery on a **scalar** state (called at failure slots,
        never in the hot path)."""
        if st["ejected"].ndim != 0:
            raise ValueError("drop_dead_packets works on scalar states")
        N, P, V = self.N, self.P, self.V
        link_up = np.asarray(st["link_up"]).reshape(N, P)
        switch_up = np.asarray(st["switch_up"])
        # output queues die with their link (covers dead switches — all
        # their links are down); input queues die only with their switch
        # (packets already received at a live switch can still route out)
        dead_out_q = np.repeat(~link_up.reshape(-1), V)            # [NQ]
        dead_in_q = np.repeat(~switch_up, P * V)                   # [NQ]
        freed = []

        def clear(buf, head, ln, depth, dead):
            rows = np.nonzero(dead & (ln > 0))[0]
            for qi in rows:
                idx = (head[qi] + np.arange(ln[qi])) % depth
                freed.extend(int(x) for x in buf[qi, idx])
                ln[qi] = 0
            return ln

        qlen = np.array(st["qlen"])
        oq_len = np.array(st["oq_len"])
        qlen = clear(np.asarray(st["qbuf"]), np.asarray(st["qhead"]),
                     qlen, self.Q, dead_in_q)
        oq_len = clear(np.asarray(st["oq_buf"]), np.asarray(st["oq_head"]),
                       oq_len, self.cfg.out_queue, dead_out_q)
        st = dict(st)
        if freed:
            fl_buf = np.array(st["fl_buf"])
            head, ln = int(st["fl_head"]), int(st["fl_len"])
            pos = (head + ln + np.arange(len(freed))) % self.pool
            fl_buf[pos] = freed
            st["fl_buf"] = jnp.asarray(fl_buf)
            st["fl_len"] = jnp.asarray(ln + len(freed), jnp.int32)
            st["fail_drop"] = st["fail_drop"] + jnp.int32(len(freed))
        st["qlen"] = jnp.asarray(qlen)
        st["oq_len"] = jnp.asarray(oq_len)
        return st

    def run_resilience(self, traffic: Traffic, warm: int = 200,
                       measure: int = 400, seed: int = 0,
                       chunk: int = 32) -> dict:
        """Throughput + latency under the attached failure schedule.

        Advances in ``chunk``-slot jitted runs plus single-slot remainder
        steps (compile set = {chunk, 1}, independent of where events
        land), applying each schedule transition at its slot boundary via
        :meth:`RoutingTables.apply_failures` → :meth:`update_tables`
        (+ :meth:`drop_dead_packets` under the ``"drop"`` policy).
        Transitions at the warm boundary apply before the snapshot.  On
        return the host tables are restored to pristine, so cached
        simulators stay reusable (BFS is deterministic — restoration is
        exact).
        """
        if not self.has_failures:
            raise ValueError(
                "run_resilience needs a Simulator built with a non-empty "
                "FailureSchedule (failures=...); use run_throughput for "
                "pristine fabrics")
        sched = self.failures
        drop = sched.policy == "drop"
        trans = sched.transitions()
        st = self.make_state(traffic, seed)
        now = 0
        ti = 0
        active: list = []

        def advance_to(st, target):
            nonlocal now
            while now + chunk <= target:
                st = self.run_chunk(st, traffic, chunk)
                now += chunk
            while now < target:
                st = self.run_chunk(st, traffic, 1)
                now += 1
            return st

        def apply_due(st, boundary):
            nonlocal ti
            while ti < len(trans) and trans[ti][0] <= boundary:
                slot, downs, ups = trans[ti]
                st = advance_to(st, slot)
                delta = self.tables.apply_failures(down=downs, up=ups)
                st = self.update_tables(st, delta)
                active.extend(downs)
                for ev in ups:
                    if ev in active:
                        active.remove(ev)
                if drop and downs:
                    st = self.drop_dead_packets(st)
                ti += 1
            return st

        try:
            st = apply_due(st, warm)
            st = advance_to(st, warm)
            base = {k: st[k] + 0 for k in ("ejected", "hop_sum",
                                           "pool_stall", "fail_drop",
                                           "lat_hist")}
            st = apply_due(st, warm + measure)
            st = advance_to(st, warm + measure)
            m = jax.device_get({k: st[k] - base[k] for k in base}
                               | {"ejected_total": st["ejected"]})
        finally:
            if active or ti:
                # exact pristine restore (BFS is deterministic), so the
                # shared host tables are clean for the next caller
                self.tables.apply_failures(up=tuple(active))
        hist = np.asarray(m["lat_hist"])
        return {
            "throughput": int(m["ejected"]) / (self.S * measure),
            "avg_hops": int(m["hop_sum"]) / max(int(m["ejected"]), 1),
            "ejected": int(m["ejected_total"]),
            "pool_stall": int(m["pool_stall"]),
            "fail_drop": int(m["fail_drop"]),
            "hist": hist,
            **percentiles(hist, LATENCY_QS),
            "state": st,
        }

    def run_completion(self, traffic: Traffic, expected: int,
                       chunk: int = 128, max_slots: int = 100_000,
                       seed: int = 0, state: Optional[dict] = None,
                       budget_chunks: Optional[int] = None,
                       done=None) -> dict:
        """Run until all ``expected`` packets are delivered (collectives).

        The chunk loop runs entirely on device (``lax.while_loop``); the
        reported ``slots`` is the exact slot the ejection counter crossed
        ``expected``, not the enclosing chunk boundary.  Accepts scalar or
        batched (``make_batch_state``) state; with a replica axis, ``slots``
        / ``completed`` / ``pool_stall`` come back as per-replica arrays and
        the loop stops once *all* replicas have completed.

        A caller-provided ``state`` is consumed (its buffers are donated to
        the device loop) — reuse the returned ``state`` instead.

        ``budget_chunks=B`` bounds one call to at most ``B`` chunk bodies —
        the checkpointable segment used by
        :mod:`repro.runtime.resilient`.  The result then carries
        ``running`` (True while delivery is still in progress) and
        ``done`` (the per-replica completion-slot array to thread into the
        next segment alongside ``state``); a chain of bounded segments is
        bitwise-identical to one unbounded call.
        """
        st = state if state is not None else self.make_state(traffic, seed)
        # p_bh packs the born slot above the hop byte; past 2^23 slots the
        # shifted value would wrap int32 and corrupt latency measurement
        assert max_slots < (1 << 23), \
            "max_slots overflows the p_bh born-slot packing (< 2^23)"
        st = {k: jnp.asarray(v) for k, v in st.items()}
        with _quiet_cpu_donation():
            if budget_chunks is None:
                st, done = self._completion_loop(st, traffic, expected,
                                                 chunk, max_slots)
            else:
                done = (jnp.full_like(st["ejected"], -1) if done is None
                        else jnp.asarray(done, jnp.int32))
                st, done = self._completion_loop_bounded(
                    st, done, traffic, expected, chunk, max_slots,
                    int(budget_chunks))
        done = np.asarray(done)
        final = np.asarray(st["slot"])
        slots = np.where(done >= 0, done, final)
        completed = done >= 0
        out = {"state": st}
        if budget_chunks is not None:
            out["done"] = done
            out["running"] = bool((~(done >= 0)).any()
                                  and final.max() < max_slots)
        if done.ndim == 0:
            return {"slots": int(slots), "completed": bool(completed),
                    "pool_stall": int(st["pool_stall"]), **out}
        return {"slots": slots, "completed": completed,
                "pool_stall": np.asarray(st["pool_stall"]), **out}

    def run_completion_batch(self, traffic: Traffic, expected: int, seeds,
                             chunk: int = 128,
                             max_slots: int = 100_000) -> dict:
        """Batched ``run_completion`` over fresh per-seed replica states."""
        return self.run_completion(
            traffic, expected, chunk=chunk, max_slots=max_slots,
            state=self.make_batch_state(traffic, seeds))

    # ------------------------------------------------------------------ #
    # compiled workload programs (repro.workloads)
    # ------------------------------------------------------------------ #
    @staticmethod
    def program_traffic(program) -> Traffic:
        """The static :class:`Traffic` shape of a
        :class:`repro.workloads.CompiledProgram` — only phase count and
        schedule; the arrays ride in the state, so same-shaped programs
        share one compiled executable."""
        return Traffic("program", n_phases=program.n_phases,
                       schedule=program.schedule, window=program.window)

    def make_program_state(self, program, seed: int = 0) -> dict:
        """State for a compiled program run: the base simulator state plus
        the device-resident schedule arrays and the scheduler registers
        (``phase`` counter, per-phase ``phase_done`` completion slots,
        ``phase_ok`` flags, and the phase-reset key ``key0``)."""
        if program.n_endpoints != self.S:
            raise ValueError(
                f"program compiled for {program.n_endpoints} endpoints, "
                f"fabric has {self.S}")
        i32 = jnp.int32
        st = self.make_state(self.program_traffic(program), seed)
        # copies, not aliases: the state pytree is donated to the program
        # loop, and donating the CompiledProgram's own arrays would consume
        # them after one run
        st["prog_partner"] = jnp.array(program.partner, i32)
        st["prog_packets"] = jnp.array(program.packets, i32)
        st["prog_expected"] = jnp.array(program.expected, i32)
        st["prog_expected_cum"] = jnp.array(program.expected_cum, i32)
        st["phase"] = jnp.zeros((), i32)
        st["phase_done"] = jnp.full((program.n_phases,), -1, i32)
        st["phase_ok"] = jnp.zeros((program.n_phases,), bool)
        # fresh buffer (`+ 0`), not an alias: the whole state pytree is
        # donated to the program loop, and a donated buffer may only
        # appear once
        st["key0"] = st["key"] + 0
        return st

    # compiled-schedule arrays that are replica-invariant: one device copy
    # shared across the vmap axis (key -> unbatched ndim, used to detect
    # whether a caller-supplied state left them unstacked)
    _PROG_SHARED = {"prog_partner": 2, "prog_packets": 2,
                    "prog_expected": 1, "prog_expected_cum": 1}

    def make_program_batch_state(self, program, seeds) -> dict:
        """``make_program_state`` stacked on a leading replica axis.

        The compiled schedule arrays (``prog_partner`` etc.) are identical
        for every replica, so they are kept as **one** shared copy instead
        of being stacked ``R``-fold — on a rounds-heavy program at paper
        scale the ``[n_phases, S]`` tables are the largest state entries,
        and the program loop vmaps them with ``in_axes=None``.
        """
        states = [self.make_program_state(program, seed=int(s))
                  for s in seeds]
        shared = {k: states[0][k] for k in self._PROG_SHARED}
        for st in states:
            for k in self._PROG_SHARED:
                del st[k]
        batch = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
        batch.update(shared)
        return batch

    @functools.partial(jax.jit, static_argnums=(0, 2, 3, 4),
                       donate_argnums=(1,))
    def _program_loop(self, st, traffic: Traffic, chunk: int,
                      max_slots: int):
        """Device-side program executor: one ``lax.while_loop`` drives all
        phases of all replicas — the phase counter, per-phase ejection
        targets, and exact completion slots all live on device, so an
        R-replica, P-phase collective is one device computation with zero
        per-phase host round-trips."""
        batched = st["ejected"].ndim == 1
        step = lambda s: self._step(s, traffic, chunk=chunk,
                                    max_slots=max_slots)
        if batched:
            # replica-invariant schedule arrays ride unbatched
            # (in_axes/out_axes None): one shared device copy, no R-fold
            # gather traffic.  A caller-built state that did stack them is
            # detected by ndim and mapped normally.
            axes = {k: None if st[k].ndim == self._PROG_SHARED.get(k, -1)
                    else 0 for k in st}
            step = jax.vmap(step, in_axes=(axes,), out_axes=axes)

        def chunk_body(s):
            return jax.lax.scan(lambda c, _: (step(c), None), s, None,
                                length=chunk)[0]

        if traffic.schedule == "window":
            def cond(s):
                running = ~jnp.all(s["phase_done"][..., -1] >= 0)
                return running & (jnp.max(s["slot"]) < max_slots)
        else:
            # barrier phases force-advance at their chunk-granular
            # max_slots budget, so the phase counter always reaches
            # n_phases eventually
            def cond(s):
                return ~jnp.all(s["phase"] >= traffic.n_phases)

        return jax.lax.while_loop(cond, chunk_body, st)

    @functools.partial(jax.jit, static_argnums=(0, 2, 3, 4, 5),
                       donate_argnums=(1,))
    def _program_loop_bounded(self, st, traffic: Traffic, chunk: int,
                              max_slots: int, budget: int):
        """:meth:`_program_loop` with a chunk *budget*: at most ``budget``
        chunk bodies per call, then control returns to the host — the
        checkpointable chunk boundary for resumable collective runs.  The
        chunk body and the program-completion condition are byte-for-byte
        the unbounded loop's (the budget only adds an iteration counter to
        the carry), so a chain of bounded segments — including segments
        re-entered from a restored snapshot — replays the uninterrupted
        ``run_program`` bitwise.
        """
        batched = st["ejected"].ndim == 1
        step = lambda s: self._step(s, traffic, chunk=chunk,
                                    max_slots=max_slots)
        if batched:
            axes = {k: None if st[k].ndim == self._PROG_SHARED.get(k, -1)
                    else 0 for k in st}
            step = jax.vmap(step, in_axes=(axes,), out_axes=axes)

        def chunk_body(carry):
            s, it = carry
            s = jax.lax.scan(lambda c, _: (step(c), None), s, None,
                             length=chunk)[0]
            return s, it + 1

        if traffic.schedule == "window":
            def running(s):
                live = ~jnp.all(s["phase_done"][..., -1] >= 0)
                return live & (jnp.max(s["slot"]) < max_slots)
        else:
            def running(s):
                return ~jnp.all(s["phase"] >= traffic.n_phases)

        def cond(carry):
            s, it = carry
            return running(s) & (it < budget)

        st, _ = jax.lax.while_loop(cond, chunk_body,
                                   (st, jnp.zeros((), jnp.int32)))
        return st

    def _program_running(self, st, traffic: Traffic,
                         max_slots: int) -> bool:
        """Host-side mirror of the program loop's continue condition."""
        if traffic.schedule == "window":
            live = bool((np.asarray(st["phase_done"])[..., -1] < 0).any())
            return live and int(np.asarray(st["slot"]).max()) < max_slots
        return bool((np.asarray(st["phase"]) < traffic.n_phases).any())

    def run_program(self, program, *, chunk: int = 16,
                    max_slots: int = 60_000, seed: int = 0, seeds=None,
                    state: Optional[dict] = None,
                    budget_chunks: Optional[int] = None) -> dict:
        """Run a compiled :class:`repro.workloads.CompiledProgram` to
        completion, entirely on device.

        One of ``seed`` (scalar run), ``seeds`` (fresh batched run), or
        ``state`` (pre-built scalar/batched state — consumed, like
        ``run_completion``).  Returns ``slots`` (total), ``completed``,
        ``pool_stall``, and ``phase_slots`` (``[..., n_phases]`` — exact
        per-phase durations under ``barrier``, cumulative completion slots
        under ``window``); per-replica arrays when batched.

        ``budget_chunks=B`` bounds one call to at most ``B`` chunk bodies
        (the checkpointable segment used by
        :mod:`repro.runtime.resilient`); the result then carries
        ``running`` — True while the program has phases left — and the
        other fields are partial until it flips False.  A chain of bounded
        segments over the same ``state`` is bitwise-identical to one
        unbounded call.
        """
        assert max_slots < (1 << 23), \
            "max_slots overflows the p_bh born-slot packing (< 2^23)"
        traffic = self.program_traffic(program)
        if state is not None:
            st = state
        elif seeds is not None:
            st = self.make_program_batch_state(program, seeds)
        else:
            st = self.make_program_state(program, seed)
        st = {k: jnp.asarray(v) for k, v in st.items()}
        with _quiet_cpu_donation():
            if budget_chunks is None:
                st = self._program_loop(st, traffic, chunk, max_slots)
            else:
                st = self._program_loop_bounded(st, traffic, chunk,
                                                max_slots,
                                                int(budget_chunks))
        done = np.asarray(st["phase_done"])
        ok = np.asarray(st["phase_ok"])
        if traffic.schedule == "window":
            # phases the run never completed report the final slot
            final = np.asarray(st["slot"])[..., None]
            done = np.where(done >= 0, done, final)
            slots = done[..., -1]
        else:
            slots = done.sum(axis=-1)
        completed = ok.all(axis=-1)
        out = {"phase_slots": done, "state": st}
        if budget_chunks is not None:
            out["running"] = self._program_running(st, traffic, max_slots)
        if completed.ndim == 0:
            return {"slots": int(slots), "completed": bool(completed),
                    "pool_stall": int(st["pool_stall"]), **out}
        return {"slots": slots, "completed": completed,
                "pool_stall": np.asarray(st["pool_stall"]), **out}


def percentiles(hist: np.ndarray, qs) -> dict:
    """Latency percentiles from a histogram whose bin index *is* the latency
    in slots (packets are recorded at ``clip(slot - born + 1, ...)``).

    Uniformly ``float`` valued: completed bins return ``float(bin)`` and
    empty histograms ``float("nan")`` — downstream aggregation never sees a
    mixed int/float stream.
    """
    total = hist.sum()
    out = {}
    if total == 0:
        return {f"p{q}": float("nan") for q in qs}
    cum = np.cumsum(hist)
    for q in qs:
        out[f"p{q}"] = float(np.searchsorted(cum, q * total))
    return out
