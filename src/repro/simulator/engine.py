"""Cycle-level interconnection-network simulator in JAX (CAMINOS-equivalent).

Model (documented deviations from the paper's flit-level CAMINOS setup in
DESIGN.md): slotted time — one slot = one 16-flit packet serialization on a
link.  Input-queued switches with ``V`` virtual channels per port and
``Q``-packet queues, credit-based flow control (a packet advances only if the
downstream input queue for its next VC has room), separable random-priority
output arbitration (one grant per output port per slot), per-input-port VC
pre-arbitration (one candidate VC per input port per slot), unbounded
ejection, per-endpoint injection queues (one NIC per endpoint, one packet
injected per slot max).

Routing is evaluated *inside* the jitted step on precomputed leaf-distance
tables:

* ``polarized``        — the paper's adapted Polarized routing (Section 4.3.2)
  with VC = updown-phase = hops // 2 (1 VC per Up-Down pass — the halved
  deadlock resources of Section 4.3).
* ``minimal_adaptive`` — adaptive minimal (Fat-Tree / OFT "MIN").
* ``ksp``              — randomized minimal-DAG walk (models KSP's random
  choice among precomputed shortest paths).
* ``ugal``             — UGAL-L with Valiant intermediate leaf (Dragonfly).
* ``valiant``          — always-Valiant.

Everything is fixed-shape; throughput/latency runs are jitted ``lax.scan``
chunks, and completion runs are a single device-side ``lax.while_loop``
over chunks (the ``ejected >= expected`` check never round-trips to the
host, and the exact completion slot is recorded from the ejection-counter
crossing).  Replication is a first-class compiled axis: ``make_batch_state``
stacks R independently-seeded states along a leading replica dimension and
``run_*_batch`` drive all replicas through one ``jax.vmap``-ed executable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.routing import RoutingTables, polarized_port_mask

BIG = jnp.float32(1e9)


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SimConfig:
    policy: str = "polarized"
    vcs: int = 4                 # V
    queue_depth: int = 8         # Q packets per (port, VC) at input
    out_queue: int = 4           # packets per (port, VC) at output
    speedup: int = 2             # crossbar sub-rounds per slot
    endpoint_queue: int = 4      # QE packets per NIC
    max_hops: int = 8            # routing hop bound (2D* - 2 for polarized)
    deroute_penalty: float = 8.0
    pool: Optional[int] = None   # packet pool size (default: auto)
    hist_bins: int = 4096        # latency histogram bins (slots)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Traffic program.  ``pattern`` one of:
    uniform | rep | rsp | bu | mice_elephant | all2all | phase.

    * Bernoulli patterns use ``load`` (packets/slot/endpoint).
    * ``all2all``: each endpoint sends ``rounds`` single-packet messages to
      (e + r + 1) mod S.
    * ``phase``: each endpoint sends ``phase_packets`` packets to
      ``partner[e]`` (used for Rabenseifner phases).
    """
    pattern: str = "uniform"
    load: float = 1.0
    rounds: int = 0
    phase_packets: int = 0
    elephant_frac: float = 0.1   # fraction of messages that are elephants
    elephant_size: int = 16


class Simulator:
    def __init__(self, tables: RoutingTables, cfg: SimConfig):
        topo = tables.topo
        self.tables, self.cfg = tables, cfg
        self.N = topo.n_switches
        self.P = topo.max_ports
        self.V = cfg.vcs
        self.Q = cfg.queue_depth
        self.QE = cfg.endpoint_queue
        self.n1 = topo.n_leaves
        self.d_leaf = topo.endpoints_per_leaf
        self.S = topo.n_endpoints
        self.NQ = self.N * self.P * self.V
        self.pool = cfg.pool or int(min(2_000_000, max(1 << 14, self.S * 6)))

        self.nbrs = jnp.asarray(topo.nbrs, jnp.int32)            # [N,P]
        self.nbr_port = jnp.asarray(topo.nbr_port, jnp.int32)    # [N,P]
        self.valid_port = self.nbrs >= 0
        self.nbrs0 = jnp.maximum(self.nbrs, 0)
        assert (tables.dist_leaf >= 0).all(), "disconnected topology"
        self.dist = jnp.asarray(tables.dist_leaf, jnp.int32)     # [N1,N]
        self.leaf_ids = jnp.asarray(topo.leaf_ids, jnp.int32)    # [N1]
        self._closed = False

    # ------------------------------------------------------------------ #
    # lifetime: compiled step functions are jit-cached with ``self`` as a
    # static argument, so long-lived suites (~25 instances) accumulate
    # executables until the host OOMs.  ``close()`` makes the teardown that
    # callers used to do by hand (``del sim; jax.clear_caches()``) explicit
    # and idempotent; the context-manager form scopes it.
    # ------------------------------------------------------------------ #
    def close(self, clear: bool = True) -> None:
        """Mark the simulator dead and (by default) clear jax's jit caches.

        jax has no per-instance executable eviction, so ``clear=True`` is a
        process-global ``jax.clear_caches()`` — other live simulators will
        recompile on next use.  Batch teardowns (``SimulatorCache.close``)
        pass ``clear=False`` per instance and clear once at the end.
        """
        if self._closed:
            return
        self._closed = True
        if clear:
            jax.clear_caches()

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "Simulator":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------------ #
    def init_state(self, traffic: Traffic, seed_arrays: dict) -> dict:
        f32, i32 = jnp.float32, jnp.int32
        Z = lambda *s: jnp.zeros(s, i32)
        st = {
            "qbuf": jnp.full((self.NQ, self.Q), -1, i32),
            "qhead": Z(self.NQ), "qlen": Z(self.NQ),
            "oq_buf": jnp.full((self.NQ, self.cfg.out_queue), -1, i32),
            "oq_head": Z(self.NQ), "oq_len": Z(self.NQ),
            "eq_buf": jnp.full((self.S, self.QE), -1, i32),
            "eq_head": Z(self.S), "eq_len": Z(self.S),
            # packet pool
            "p_free": jnp.ones(self.pool, bool),
            "p_src": Z(self.pool), "p_dst": Z(self.pool),
            "p_dst_sw": Z(self.pool), "p_mid": jnp.full(self.pool, -1, i32),
            "p_born": Z(self.pool), "p_hops": Z(self.pool),
            # endpoint message program
            "msg_rem": Z(self.S), "msg_dst": Z(self.S), "prog": Z(self.S),
            # stats
            "ejected": Z(), "created": Z(), "hop_sum": Z(),
            "pool_stall": Z(),
            "lat_hist": Z(self.cfg.hist_bins),
            "slot": Z(),
            "key": jax.random.PRNGKey(self.cfg.seed),
        }
        st.update({k: jnp.asarray(v) for k, v in seed_arrays.items()})
        return st

    # ------------------------------------------------------------------ #
    def _inject(self, st, key, traffic: Traffic):
        """Start messages + push one packet per eligible endpoint."""
        S, d = self.S, self.d_leaf
        e = jnp.arange(S, dtype=jnp.int32)
        k1, k2, k3, k4 = jax.random.split(key, 4)

        idle = st["msg_rem"] == 0
        pat = traffic.pattern
        if pat in ("uniform", "rep", "rsp", "bu", "mice_elephant"):
            start = idle & (jax.random.uniform(k1, (S,)) <
                            traffic.load / self._mean_msg(traffic))
            if pat == "uniform" or pat == "mice_elephant":
                dst = jax.random.randint(k2, (S,), 0, S)
            elif pat == "rep":
                dst = st["perm"]
            elif pat == "rsp":
                dst = st["sigma"][e // d] * d + (e % d)
            else:  # bu — two halves exchange uniformly
                half = S // 2
                lower = e < half
                r = jax.random.randint(k2, (S,), 0, half)
                dst = jnp.where(lower, half + r, r % half)
            size = jnp.ones((S,), jnp.int32)
            if pat == "mice_elephant":
                size = jnp.where(jax.random.uniform(k3, (S,)) < traffic.elephant_frac,
                                 traffic.elephant_size, 1)
        elif pat == "all2all":
            start = idle & (st["prog"] < traffic.rounds)
            dst = (e + st["prog"] + 1) % S
            size = jnp.ones((S,), jnp.int32)
        elif pat == "phase":
            start = idle & (st["prog"] < 1)
            dst = st["partner"]
            size = jnp.full((S,), traffic.phase_packets, jnp.int32)
        else:
            raise ValueError(pat)

        msg_rem = jnp.where(start, size, st["msg_rem"])
        msg_dst = jnp.where(start, dst, st["msg_dst"])
        prog = st["prog"] + start.astype(jnp.int32)

        # one packet per endpoint with pending message + NIC room
        want = (msg_rem > 0) & (st["eq_len"] < self.QE)
        src_lr = e // d
        dst_lr = msg_dst // d
        local = src_lr == dst_lr
        # same-leaf fast path: delivered without entering the network.
        deliver_local = want & local
        want_net = want & ~local

        rank = jnp.cumsum(want_net.astype(jnp.int32)) - 1
        free_idx = jnp.nonzero(st["p_free"], size=min(S, self.pool),
                               fill_value=-1)[0].astype(jnp.int32)
        # overflow requesters (rank beyond the free list) get the -1 sentinel
        # rather than the clipped last entry — clipping aliased two endpoints
        # onto one packet id and corrupted the pool when cfg.pool < S.
        in_free = rank < free_idx.shape[0]
        pid = jnp.where(want_net & in_free,
                        free_idx[jnp.clip(rank, 0, free_idx.shape[0] - 1)], -1)
        ok = want_net & (pid >= 0)

        # UGAL/Valiant: sample intermediate leaf & (UGAL) compare queue depths
        mid = jnp.full((S,), -1, jnp.int32)
        if self.cfg.policy in ("ugal", "valiant"):
            mid_lr = jax.random.randint(k4, (S,), 0, self.n1)
            if self.cfg.policy == "ugal":
                sw = self.leaf_ids[src_lr]
                nb = self.nbrs0[sw]                                   # [S,P]
                occ0 = st["qlen"].reshape(self.N, self.P, self.V)[nb, self.nbr_port[sw], 0]
                vp = self.valid_port[sw]
                def best(t_lr):
                    d_n = self.dist[t_lr[:, None], nb]
                    d_c = self.dist[t_lr, sw]
                    m = vp & (d_n == d_c[:, None] - 1)
                    return jnp.min(jnp.where(m, occ0, 1 << 20), axis=1)
                q_min = best(dst_lr)
                q_val = best(mid_lr)
                d_min = self.dist[dst_lr, sw]
                d_val = self.dist[mid_lr, sw] + self.dist[dst_lr, self.leaf_ids[mid_lr]]
                take_val = q_min * d_min > q_val * d_val
                mid = jnp.where(take_val, mid_lr, -1)
            else:
                mid = mid_lr

        # sentinel index == pool size -> dropped writes for non-injectors
        widx = jnp.where(ok, jnp.maximum(pid, 0), self.pool)
        st = dict(st)
        st["p_free"] = st["p_free"].at[widx].set(False, mode="drop")
        st["p_src"] = st["p_src"].at[widx].set(src_lr, mode="drop")
        st["p_dst"] = st["p_dst"].at[widx].set(dst_lr, mode="drop")
        st["p_dst_sw"] = st["p_dst_sw"].at[widx].set(self.leaf_ids[dst_lr], mode="drop")
        st["p_mid"] = st["p_mid"].at[widx].set(mid, mode="drop")
        st["p_born"] = st["p_born"].at[widx].set(st["slot"], mode="drop")
        st["p_hops"] = st["p_hops"].at[widx].set(0, mode="drop")
        # push into NIC queue (e is unique per row -> no collisions)
        pos = (st["eq_head"] + st["eq_len"]) % self.QE
        st["eq_buf"] = st["eq_buf"].at[e, jnp.where(ok, pos, self.QE)].set(
            jnp.maximum(pid, 0), mode="drop")
        st["eq_len"] = st["eq_len"] + ok.astype(jnp.int32)

        consumed = ok | deliver_local
        st["msg_rem"] = msg_rem - consumed.astype(jnp.int32)
        st["msg_dst"] = msg_dst
        st["prog"] = prog
        n_local = deliver_local.sum(dtype=jnp.int32)
        st["created"] = st["created"] + ok.sum(dtype=jnp.int32) + n_local
        st["ejected"] = st["ejected"] + n_local
        st["pool_stall"] = st["pool_stall"] + (want_net & ~ok).sum(dtype=jnp.int32)
        st["lat_hist"] = st["lat_hist"].at[1].add(n_local)
        return st

    def _mean_msg(self, t: Traffic) -> float:
        if t.pattern == "mice_elephant":
            return (1 - t.elephant_frac) * 1.0 + t.elephant_frac * t.elephant_size
        return 1.0

    # ------------------------------------------------------------------ #
    def _crossbar_round(self, st, key, ep_active: bool):
        """One crossbar sub-round: VC pre-arbitration, routing, output
        arbitration, input-queue -> output-queue moves, ejections."""
        N, P, V, Q, S = self.N, self.P, self.V, self.Q, self.S
        OQ = self.cfg.out_queue
        k_vc, k_tie, k_arb = jax.random.split(key, 3)

        qlen3 = st["qlen"].reshape(N, P, V)
        # ---- VC pre-arbitration: one candidate VC per (switch, in-port) ----
        vc_prio = jax.random.uniform(k_vc, (N, P, V))
        vc_prio = jnp.where(qlen3 > 0, vc_prio, -1.0)
        vc_sel = jnp.argmax(vc_prio, axis=2)                       # [N,P]
        has_pkt = jnp.take_along_axis(qlen3, vc_sel[:, :, None], 2)[:, :, 0] > 0

        q_idx = (jnp.arange(N * P, dtype=jnp.int32).reshape(N, P) * V
                 + vc_sel.astype(jnp.int32)).reshape(-1)           # [N*P]
        head = st["qbuf"].reshape(-1)[q_idx * Q + st["qhead"][q_idx]]
        net_pkt = jnp.where(has_pkt.reshape(-1), head, -1)

        # endpoint (NIC) heads — only in sub-round 0 (NIC link rate = 1/slot)
        ep_head = st["eq_buf"].reshape(-1)[
            jnp.arange(S, dtype=jnp.int32) * self.QE + st["eq_head"]]
        ep_pkt = jnp.where((st["eq_len"] > 0) & ep_active, ep_head, -1)

        # ---- unified requester table ----
        cur_net = jnp.repeat(jnp.arange(N, dtype=jnp.int32), P)
        cur_ep = self.leaf_ids[jnp.arange(S, dtype=jnp.int32) // self.d_leaf]
        cur = jnp.concatenate([cur_net, cur_ep])                    # [NR]
        pkt = jnp.concatenate([net_pkt, ep_pkt])
        NR = cur.shape[0]
        valid = pkt >= 0
        pkt0 = jnp.maximum(pkt, 0)

        s_lr, t_lr = st["p_src"][pkt0], st["p_dst"][pkt0]
        hops = st["p_hops"][pkt0]
        dst_sw = st["p_dst_sw"][pkt0]
        mid_lr = st["p_mid"][pkt0]

        eject = valid & (cur == dst_sw)
        route = valid & ~eject

        nb = self.nbrs0[cur]                                        # [NR,P]
        vp = self.valid_port[cur]
        dflat = self.dist.reshape(-1)
        d_ct = dflat[t_lr * N + cur]
        d_nt = dflat[(t_lr * N)[:, None] + nb]

        pol = self.cfg.policy
        if pol == "polarized":
            d_cs = dflat[s_lr * N + cur]
            d_ns = dflat[(s_lr * N)[:, None] + nb]
            allowed, deroute = polarized_port_mask(
                d_cs[:, None], d_ct[:, None], d_ns, d_nt,
                hops[:, None], self.cfg.max_hops, vp)
            next_vc = jnp.minimum(hops // 2, V - 1)
        elif pol in ("minimal_adaptive", "ksp"):
            allowed = vp & (d_nt == d_ct[:, None] - 1)
            deroute = jnp.zeros_like(allowed)
            next_vc = jnp.minimum(hops // 2, V - 1)
        elif pol in ("ugal", "valiant"):
            tgt = jnp.where(mid_lr >= 0, mid_lr, t_lr)
            d_cg = dflat[tgt * N + cur]
            d_ng = dflat[(tgt * N)[:, None] + nb]
            allowed = vp & (d_ng == d_cg[:, None] - 1)
            deroute = jnp.zeros_like(allowed)
            next_vc = jnp.minimum(hops, V - 1)
        else:
            raise ValueError(pol)

        # congestion signal: local output queue + downstream input queue for
        # the flight VC.  Credit = room in the local output queue.
        oq_idx = (cur[:, None] * P + jnp.arange(P, dtype=jnp.int32)[None, :]
                  ) * V + next_vc[:, None]                          # [NR,P]
        dq_idx = (nb * P + self.nbr_port[cur]) * V + next_vc[:, None]
        occ = st["oq_len"][oq_idx] + st["qlen"][dq_idx]
        credit = st["oq_len"][oq_idx] < OQ
        score = (occ.astype(jnp.float32)
                 + self.cfg.deroute_penalty * deroute
                 + jax.random.uniform(k_tie, (NR, P)))
        if pol == "ksp":
            score = jax.random.uniform(k_tie, (NR, P))
        score = jnp.where(allowed & credit, score, BIG)
        port = jnp.argmin(score, axis=1).astype(jnp.int32)
        can_move = route & (jnp.min(score, axis=1) < BIG)

        # ---- output arbitration: one grant per (switch, out-port, round) ----
        out_key = cur * P + port                                    # [NR]
        # unique int32 priorities: 8 random high bits | requester index
        rnd = jax.random.randint(k_arb, (NR,), 0, 1 << 8, dtype=jnp.int32)
        prio = (rnd << 23) | jnp.arange(NR, dtype=jnp.int32)
        prio = jnp.where(can_move, prio, -1)
        seg = jnp.full((N * P,), -1, jnp.int32).at[out_key].max(prio)
        win = can_move & (seg[out_key] == prio)

        # ---- moves: input queue -> output queue ----
        tgt_q = oq_idx[jnp.arange(NR), port]
        tgt_pos = tgt_q * OQ + (st["oq_head"][tgt_q] + st["oq_len"][tgt_q]) % OQ
        oq_buf = st["oq_buf"].reshape(-1)
        oq_buf = oq_buf.at[jnp.where(win, tgt_pos, oq_buf.shape[0])].set(
            pkt0, mode="drop")
        oq_len = st["oq_len"].at[jnp.where(win, tgt_q, self.NQ)].add(1, mode="drop")

        # pops: winners + ejectors leave their input queues
        leave = win | eject
        net_leave = leave[: N * P]
        qi = jnp.where(net_leave, q_idx, self.NQ)
        qhead = st["qhead"].at[qi].add(1, mode="drop") % Q
        qlen = st["qlen"].at[qi].add(-1, mode="drop")
        ep_leave = leave[N * P:]
        eq_head = (st["eq_head"] + ep_leave.astype(jnp.int32)) % self.QE
        eq_len = st["eq_len"] - ep_leave.astype(jnp.int32)

        # ejections: free pool, record stats
        p_free = st["p_free"].at[jnp.where(eject, pkt0, self.pool)].set(
            True, mode="drop")
        lat = jnp.clip(st["slot"] - st["p_born"][pkt0] + 1, 0,
                       self.cfg.hist_bins - 1)
        lat_hist = st["lat_hist"].at[jnp.where(eject, lat, 0)].add(
            jnp.where(eject, 1, 0))

        st = dict(st)
        st["oq_buf"] = oq_buf.reshape(self.NQ, OQ)
        st["oq_len"] = oq_len
        st["qhead"], st["qlen"] = qhead, qlen
        st["eq_head"], st["eq_len"] = eq_head, eq_len
        st["p_free"] = p_free
        st["lat_hist"] = lat_hist
        st["ejected"] = st["ejected"] + eject.sum(dtype=jnp.int32)
        st["hop_sum"] = st["hop_sum"] + jnp.where(eject, hops, 0).sum(dtype=jnp.int32)
        return st

    def _link_phase(self, st, key):
        """Move one packet per link: output-queue head -> downstream input
        queue (credit-checked), incrementing hop counts and assigning the
        packet to the downstream switch."""
        N, P, V, Q = self.N, self.P, self.V, self.Q
        OQ = self.cfg.out_queue
        # pick one non-empty output VC per (switch, port) with downstream room
        oq_len3 = st["oq_len"].reshape(N, P, V)
        np_idx = jnp.arange(N * P, dtype=jnp.int32)
        sw = np_idx // P
        pt = np_idx % P
        nb = self.nbrs0[sw, pt]                                     # [N*P]
        nbp = self.nbr_port[sw, pt]
        link_ok = self.valid_port[sw, pt]
        # downstream input queue per VC
        dq = (nb[:, None] * P + nbp[:, None]) * V + jnp.arange(V, dtype=jnp.int32)
        room = st["qlen"][dq] < Q                                   # [N*P,V]
        nonempty = oq_len3.reshape(N * P, V) > 0
        cand = nonempty & room & link_ok[:, None]
        prio = jnp.where(cand, jax.random.uniform(key, (N * P, V)), -1.0)
        vcs = jnp.argmax(prio, axis=1).astype(jnp.int32)
        send = jnp.take_along_axis(cand, vcs[:, None], 1)[:, 0]

        src_q = np_idx * V + vcs
        pkt = st["oq_buf"].reshape(-1)[src_q * OQ + st["oq_head"][src_q]]
        pkt0 = jnp.maximum(pkt, 0)
        tgt_q = dq[np_idx, vcs]
        tgt_pos = tgt_q * Q + (st["qhead"][tgt_q] + st["qlen"][tgt_q]) % Q

        qbuf = st["qbuf"].reshape(-1)
        qbuf = qbuf.at[jnp.where(send, tgt_pos, qbuf.shape[0])].set(pkt0, mode="drop")
        qlen = st["qlen"].at[jnp.where(send, tgt_q, self.NQ)].add(1, mode="drop")
        sq = jnp.where(send, src_q, self.NQ)
        oq_head = st["oq_head"].at[sq].add(1, mode="drop") % OQ
        oq_len = st["oq_len"].at[sq].add(-1, mode="drop")
        p_hops = st["p_hops"].at[jnp.where(send, pkt0, self.pool)].add(1, mode="drop")
        # clear UGAL/Valiant intermediate when the packet reaches it
        mid_lr = st["p_mid"][pkt0]
        reached_mid = send & (mid_lr >= 0) & (nb == self.leaf_ids[jnp.maximum(mid_lr, 0)])
        p_mid = st["p_mid"].at[jnp.where(reached_mid, pkt0, self.pool)].set(
            -1, mode="drop")

        st = dict(st)
        st["qbuf"] = qbuf.reshape(self.NQ, Q)
        st["qlen"] = qlen
        st["oq_head"], st["oq_len"] = oq_head, oq_len
        st["p_hops"], st["p_mid"] = p_hops, p_mid
        return st

    def _step(self, st, traffic: Traffic):
        key, k_inj, k_link, *k_xb = jax.random.split(
            st["key"], 3 + self.cfg.speedup)
        st = dict(st)
        st["key"] = key
        st = self._inject(st, k_inj, traffic)
        for r in range(self.cfg.speedup):
            st = self._crossbar_round(st, k_xb[r], ep_active=True)
        st = self._link_phase(st, k_link)
        st["slot"] = st["slot"] + 1
        return st

    # ------------------------------------------------------------------ #
    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def run_chunk(self, st, traffic: Traffic, n_slots: int):
        def body(carry, _):
            return self._step(carry, traffic), None
        st, _ = jax.lax.scan(body, st, None, length=n_slots)
        return st

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def run_chunk_batch(self, st, traffic: Traffic, n_slots: int):
        """``run_chunk`` vmapped over a leading ``[R]`` replica axis."""
        def one(s):
            def body(carry, _):
                return self._step(carry, traffic), None
            return jax.lax.scan(body, s, None, length=n_slots)[0]
        return jax.vmap(one)(st)

    @functools.partial(jax.jit, static_argnums=(0, 2, 4, 5))
    def _completion_loop(self, st, traffic: Traffic, expected,
                         chunk: int, max_slots: int):
        """Device-side completion detection: a ``lax.while_loop`` over
        ``chunk``-slot scans that stops once every replica has ejected
        ``expected`` packets (or ``max_slots`` elapsed).  ``done`` records
        the *exact* slot at which each replica's ejection counter crossed
        ``expected`` (-1 while still running) — completion resolution is one
        slot, not one chunk, and there are no per-chunk host syncs.

        Works on scalar state (0-d ``ejected``) and batched state alike:
        the step is vmapped when a replica axis is present.
        """
        batched = st["ejected"].ndim == 1
        step = lambda s: self._step(s, traffic)
        if batched:
            step = jax.vmap(step)
        expected = jnp.asarray(expected, jnp.int32)

        def slot_body(carry, _):
            s, done = carry
            s = step(s)
            newly = (s["ejected"] >= expected) & (done < 0)
            done = jnp.where(newly, s["slot"], done)
            return (s, done), None

        def chunk_body(carry):
            return jax.lax.scan(slot_body, carry, None, length=chunk)[0]

        def cond(carry):
            s, done = carry
            running = ~jnp.all(done >= 0)
            return running & (jnp.max(s["slot"]) < max_slots)

        done0 = jnp.full_like(st["ejected"], -1)
        return jax.lax.while_loop(cond, chunk_body, (st, done0))

    # ------------------------------------------------------------------ #
    # high-level drivers
    # ------------------------------------------------------------------ #
    def make_state(self, traffic: Traffic, seed: int = 0) -> dict:
        if self._closed:
            raise RuntimeError("Simulator is closed")
        rng = np.random.default_rng(seed)
        seed_arrays = {}
        if traffic.pattern == "rep":
            seed_arrays["perm"] = rng.permutation(self.S).astype(np.int32)
        if traffic.pattern == "rsp":
            seed_arrays["sigma"] = rng.permutation(self.n1).astype(np.int32)
        if traffic.pattern == "phase":
            seed_arrays["partner"] = np.zeros(self.S, np.int32)  # set by caller
        st = self.init_state(traffic, seed_arrays)
        if seed:  # thread the run seed into the sim PRNG (seed=0: legacy key)
            st["key"] = jax.random.PRNGKey(self.cfg.seed + (seed << 16))
        return st

    def make_batch_state(self, traffic: Traffic, seeds) -> dict:
        """Stack R independently-seeded states on a leading replica axis.

        Each replica's slice is exactly the state ``make_state(traffic, s)``
        would produce — seed-dependent traffic permutations (``rep``/``rsp``)
        and the PRNG stream both vary per replica — so a vmapped run is
        replica-for-replica identical to R scalar runs.
        """
        states = [self.make_state(traffic, seed=int(s)) for s in seeds]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)

    def run_throughput(self, traffic: Traffic, warm: int = 200,
                       measure: int = 400, seed: int = 0) -> dict:
        st = self.make_state(traffic, seed)
        st = self.run_chunk(st, traffic, warm)
        e0, h0, ps0 = (int(st["ejected"]), int(st["hop_sum"]),
                       int(st["pool_stall"]))
        st = self.run_chunk(st, traffic, measure)
        e1, h1, ps1 = (int(st["ejected"]), int(st["hop_sum"]),
                       int(st["pool_stall"]))
        return {
            "throughput": (e1 - e0) / (self.S * measure),
            # steady-state window only: the cumulative h1/e1 ratio used to
            # fold warmup transients into the reported hop count
            "avg_hops": (h1 - h0) / max(e1 - e0, 1),
            "ejected": e1,
            "pool_stall": ps1 - ps0,
            "state": st,
        }

    def run_throughput_batch(self, traffic: Traffic, seeds,
                             warm: int = 200, measure: int = 400) -> dict:
        """Batched ``run_throughput``: one compiled executable, R replicas.

        Returns per-replica ``[R]`` arrays for every metric.
        """
        st = self.make_batch_state(traffic, seeds)
        st = self.run_chunk_batch(st, traffic, warm)
        e0 = np.asarray(st["ejected"])
        h0 = np.asarray(st["hop_sum"])
        ps0 = np.asarray(st["pool_stall"])
        st = self.run_chunk_batch(st, traffic, measure)
        e1 = np.asarray(st["ejected"])
        h1 = np.asarray(st["hop_sum"])
        ps1 = np.asarray(st["pool_stall"])
        return {
            "throughput": (e1 - e0) / (self.S * measure),
            "avg_hops": (h1 - h0) / np.maximum(e1 - e0, 1),
            "ejected": e1,
            "pool_stall": ps1 - ps0,
            "state": st,
        }

    def run_latency(self, traffic: Traffic, warm: int = 200,
                    measure: int = 600, seed: int = 0) -> dict:
        st = self.make_state(traffic, seed)
        st = self.run_chunk(st, traffic, warm)
        h0 = np.asarray(st["lat_hist"])
        st = self.run_chunk(st, traffic, measure)
        h1 = np.asarray(st["lat_hist"])
        hist = h1 - h0
        return {"hist": hist, **percentiles(hist, (0.5, 0.99, 0.9999))}

    def run_latency_batch(self, traffic: Traffic, seeds,
                          warm: int = 200, measure: int = 600) -> dict:
        """Batched ``run_latency``: per-replica histograms and percentile
        lists (``{"p0.5": [R floats], ...}``; NaN where a replica ejected
        nothing in the window)."""
        st = self.make_batch_state(traffic, seeds)
        st = self.run_chunk_batch(st, traffic, warm)
        h0 = np.asarray(st["lat_hist"])
        st = self.run_chunk_batch(st, traffic, measure)
        h1 = np.asarray(st["lat_hist"])
        hist = h1 - h0                                           # [R, bins]
        per = [percentiles(row, (0.5, 0.99, 0.9999)) for row in hist]
        out = {"hist": hist}
        for k in ("p0.5", "p0.99", "p0.9999"):
            out[k] = np.asarray([p[k] for p in per])
        return out

    def run_completion(self, traffic: Traffic, expected: int,
                       chunk: int = 128, max_slots: int = 100_000,
                       seed: int = 0, state: Optional[dict] = None) -> dict:
        """Run until all ``expected`` packets are delivered (collectives).

        The chunk loop runs entirely on device (``lax.while_loop``); the
        reported ``slots`` is the exact slot the ejection counter crossed
        ``expected``, not the enclosing chunk boundary.  Accepts scalar or
        batched (``make_batch_state``) state; with a replica axis, ``slots``
        / ``completed`` / ``pool_stall`` come back as per-replica arrays and
        the loop stops once *all* replicas have completed.
        """
        st = state if state is not None else self.make_state(traffic, seed)
        st = {k: jnp.asarray(v) for k, v in st.items()}
        st, done = self._completion_loop(st, traffic, expected, chunk,
                                         max_slots)
        done = np.asarray(done)
        final = np.asarray(st["slot"])
        slots = np.where(done >= 0, done, final)
        completed = done >= 0
        if done.ndim == 0:
            return {"slots": int(slots), "completed": bool(completed),
                    "pool_stall": int(st["pool_stall"]), "state": st}
        return {"slots": slots, "completed": completed,
                "pool_stall": np.asarray(st["pool_stall"]), "state": st}

    def run_completion_batch(self, traffic: Traffic, expected: int, seeds,
                             chunk: int = 128,
                             max_slots: int = 100_000) -> dict:
        """Batched ``run_completion`` over fresh per-seed replica states."""
        return self.run_completion(
            traffic, expected, chunk=chunk, max_slots=max_slots,
            state=self.make_batch_state(traffic, seeds))


def percentiles(hist: np.ndarray, qs) -> dict:
    """Latency percentiles from a histogram whose bin index *is* the latency
    in slots (packets are recorded at ``clip(slot - born + 1, ...)``)."""
    total = hist.sum()
    out = {}
    if total == 0:
        return {f"p{q}": float("nan") for q in qs}
    cum = np.cumsum(hist)
    for q in qs:
        out[f"p{q}"] = int(np.searchsorted(cum, q * total))
    return out
