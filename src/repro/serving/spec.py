"""Frozen, JSON-round-trippable serving-sweep specification.

A :class:`ServingSpec` names one fabric, one arrival process, and a
ladder of offered loads; :func:`repro.serving.sweep.serve_sweep` expands
it into ``serving``-metric :class:`repro.api.Experiment` grid points and
returns the load-latency SLO curve (p50 / p99 / p999 / p9999 vs offered
load) plus the saturation knee.  ``python -m repro.api serve-sweep
spec.json`` executes one from a file.

Optionally the spec carries an LM request (``model`` / ``phase``), in
which case the sweep also runs the bridged collective once per fabric
(:mod:`repro.serving.bridge`) and attaches its completion record — the
"what does one request cost in isolation" companion to the open-loop
curve.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Optional, Tuple

from ..api.specs import NetworkSpec, RouteSpec
from ..workloads.patterns import check_arrival

__all__ = ["ServingSpec"]

DEFAULT_LOADS = (0.2, 0.4, 0.6, 0.7, 0.8, 0.9, 0.95)


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """One open-loop serving sweep: fabric x arrival process x load ladder.

    * ``network`` / ``route`` — the fabric, exactly as in ``Experiment``.
    * ``process`` — arrival family (``poisson`` / ``pareto`` / ``diurnal``)
      with its knobs (``pareto_alpha`` / ``pareto_cap`` / ``diurnal_amp`` /
      ``diurnal_period`` / ``arr_depth``).
    * ``loads`` — offered loads swept (packets/slot/endpoint); every load
      must pass :func:`repro.workloads.patterns.check_arrival`.
    * ``sat_ratio`` — the knee rule: the first load whose delivered
      throughput drops below ``sat_ratio * offered`` marks saturation.
    * ``model`` / ``phase`` / ``ranks`` / ``tokens`` / ``batch`` — optional
      LM request attached via :mod:`repro.serving.bridge` (``model=""``
      disables the bridge leg).
    """

    network: NetworkSpec
    route: RouteSpec = RouteSpec()
    process: str = "poisson"
    loads: Tuple[float, ...] = DEFAULT_LOADS
    # arrival-process knobs (mirror WorkloadSpec)
    pareto_alpha: float = 1.5
    pareto_cap: int = 64
    diurnal_amp: float = 0.5
    diurnal_period: int = 512
    arr_depth: int = 8
    # measurement
    warm: int = 200
    measure: int = 600
    seed: int = 0
    replicas: int = 1
    max_slots: int = 60_000
    sat_ratio: float = 0.95
    # optional LM-request leg
    model: str = ""
    phase: str = "decode"
    ranks: int = 0
    tokens: int = 256
    batch: int = 1
    name: str = ""

    def __post_init__(self):
        loads = tuple(float(x) for x in self.loads)
        if not loads:
            raise ValueError("loads must name at least one offered load")
        object.__setattr__(self, "loads", loads)
        for load in loads:
            check_arrival(self.process, load, pareto_alpha=self.pareto_alpha,
                          pareto_cap=self.pareto_cap,
                          diurnal_amp=self.diurnal_amp,
                          diurnal_period=self.diurnal_period,
                          arr_depth=self.arr_depth)
        if not 0.0 < self.sat_ratio <= 1.0:
            raise ValueError(f"sat_ratio must be in (0, 1], got "
                             f"{self.sat_ratio}")
        if self.model:
            from .bridge import SERVING_PHASES
            if self.phase not in SERVING_PHASES:
                raise ValueError(f"unknown serving phase {self.phase!r}; "
                                 f"expected one of {SERVING_PHASES}")

    def label(self) -> str:
        if self.name:
            return self.name
        params = ",".join(f"{k}={v}" for k, v in self.network.params)
        return f"{self.network.family}({params})/{self.process}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["network"] = self.network.to_dict()
        d["route"] = self.route.to_dict()
        d["loads"] = list(self.loads)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "ServingSpec":
        d = dict(d)
        d["network"] = NetworkSpec.from_dict(d["network"])
        if "route" in d:
            d["route"] = RouteSpec.from_dict(d["route"])
        if "loads" in d:
            d["loads"] = tuple(d["loads"])
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "ServingSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **kw) -> "ServingSpec":
        return dataclasses.replace(self, **kw)
