"""Load-latency SLO sweeps over open-loop serving traffic.

:func:`serve_sweep` expands a :class:`repro.serving.spec.ServingSpec`
into one ``serving``-metric experiment per offered load, runs them on a
shared compiled simulator (one fabric -> one trace), and folds the
results into an SLO record::

    {"name": ..., "spec": {...},
     "points": [{"load", "offered", "delivered", "p50", "p99", "p999",
                 "p9999", "dropped", "pool_stall"}, ...],
     "saturation": {"load", "offered", "delivered", "ratio"} | None,
     "request": {...} | None}

The saturation knee is the first swept load whose delivered throughput
falls below ``sat_ratio * offered`` — the point where the open loop
stops keeping up and latency curves go vertical.  When the spec names an
LM request, ``request`` holds the bridged collective's completion record
(slots to finish one request's traffic on an idle fabric).
"""
from __future__ import annotations

from typing import Optional

from ..api.runner import SimulatorCache, run_all
from ..api.specs import Experiment, WorkloadSpec
from .spec import ServingSpec

__all__ = ["serve_sweep", "serve_sweep_many"]


def _experiments(spec: ServingSpec) -> list:
    wl_kw = dict(pareto_alpha=spec.pareto_alpha, pareto_cap=spec.pareto_cap,
                 diurnal_amp=spec.diurnal_amp,
                 diurnal_period=spec.diurnal_period, arr_depth=spec.arr_depth)
    return [
        Experiment(network=spec.network, route=spec.route,
                   workload=WorkloadSpec(spec.process, load=load, **wl_kw),
                   name=f"{spec.label()}@{load:g}", seed=spec.seed,
                   replicas=spec.replicas, warm=spec.warm,
                   measure=spec.measure, max_slots=spec.max_slots)
        for load in spec.loads
    ]


def _point(load: float, res) -> dict:
    return {"load": load, "offered": res.offered,
            "delivered": res.throughput, "dropped": res.dropped,
            "pool_stall": res.pool_stall, **(res.latency or {})}


def _saturation(points, sat_ratio: float) -> Optional[dict]:
    for p in points:
        if p["offered"] and p["delivered"] < sat_ratio * p["offered"]:
            return {"load": p["load"], "offered": p["offered"],
                    "delivered": p["delivered"],
                    "ratio": p["delivered"] / p["offered"]}
    return None


def _request_record(spec: ServingSpec,
                    cache: Optional[SimulatorCache]) -> Optional[dict]:
    if not spec.model:
        return None
    from ..api.registry import build_network
    from .bridge import request_phase_shape, request_to_spec
    from ..configs import get_config

    S = int(build_network(spec.network).n_endpoints)
    cfg = get_config(spec.model)
    wl = request_to_spec(cfg, spec.phase, S, ranks=spec.ranks,
                         tokens=spec.tokens, batch=spec.batch)
    shape = request_phase_shape(cfg, spec.phase, ranks=wl.ranks,
                                tokens=spec.tokens, batch=spec.batch)
    exp = Experiment(network=spec.network, route=spec.route, workload=wl,
                     name=f"{spec.label()}/request", seed=spec.seed,
                     warm=0, measure=0, max_slots=spec.max_slots)
    res = run_all([exp], cache=cache)[0]
    return {"model": cfg.name, "phase": spec.phase, "shape": shape,
            "pattern": wl.pattern, "slots": res.slots,
            "completed": res.completed, "avg_hops": res.avg_hops}


def serve_sweep(spec: ServingSpec, *,
                cache: Optional[SimulatorCache] = None) -> dict:
    """Run one serving sweep and return its SLO record (see module doc)."""
    own = cache is None
    if own:
        cache = SimulatorCache()
    try:
        results = run_all(_experiments(spec), cache=cache)
        points = [_point(load, res)
                  for load, res in zip(spec.loads, results)]
        record = {
            "name": spec.label(),
            "spec": spec.to_dict(),
            "points": points,
            "saturation": _saturation(points, spec.sat_ratio),
            "request": _request_record(spec, cache),
        }
    finally:
        if own:
            cache.close()
    return record


def serve_sweep_many(specs, *,
                     cache: Optional[SimulatorCache] = None) -> list:
    """Sweep several specs (e.g. MRLS vs Fat-Tree at matched endpoint
    count) sharing one simulator cache; returns one record per spec."""
    own = cache is None
    if own:
        cache = SimulatorCache()
    try:
        return [serve_sweep(s, cache=cache) for s in specs]
    finally:
        if own:
            cache.close()
