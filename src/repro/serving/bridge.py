"""LM-request -> workload-program bridge.

Compiles an LM inference request (an architecture from ``repro.configs``
plus a serving phase) into the :class:`repro.workloads.WorkloadProgram`
its fabric traffic reduces to:

* ``prefill`` — the tensor-parallel all-gather of the prompt's sharded
  activations: a ring over ``ranks`` ranks, ``ranks - 1`` phases, each
  shifting one shard of ``ceil(tokens / ranks) * d_model`` activation
  bytes to the next neighbour.
* ``decode``  — per-token point-to-point: each rank ships one token's
  ``d_model`` activation vector to its stage peer
  (``(r + ranks // 2) mod ranks``), one phase.
* ``moe``     — expert-parallel All2All from :mod:`repro.models.moe`
  shapes: every rank exchanges its capacity-bounded routed-token slice
  (``tokens_local * top_k / ranks * capacity_factor``) with every other
  rank via the shifted exchange, ``ranks - 1`` phases.

Bytes lower to packets through :data:`PACKET_BYTES` (one 16-flit packet,
the engine's slot serialization unit).  The three structural builders are
registered with :func:`repro.workloads.register_program_builder` under
``lm_prefill`` / ``lm_decode`` / ``lm_moe`` at import, so ``WorkloadSpec``
gains serving vocabulary for free (``pattern="lm_moe", ranks=..,
vec_packets=..``) and the runner executes them device-resident like any
collective.  :func:`request_to_program` / :func:`request_to_spec` derive
``ranks`` / ``vec_packets`` from the real model shapes.

Structural builders are numpy-only; ``repro.configs`` (the heavy model
stack) is imported lazily, only when a request names an architecture.
"""
from __future__ import annotations

import math

import numpy as np

from ..workloads.ir import WorkloadProgram
from ..workloads.programs import register_program_builder

__all__ = [
    "PACKET_BYTES",
    "SERVING_PHASES",
    "lm_prefill_program",
    "lm_decode_program",
    "lm_moe_program",
    "request_to_program",
    "request_to_spec",
]

# one slot serializes one 16-flit packet; 16 B flits -> 256 B per packet
PACKET_BYTES = 256
# bf16 activations (2 bytes/element), the serving dtype of the seed stack
ACT_BYTES = 2

SERVING_PHASES = ("prefill", "decode", "moe")


def _check_ranks(name: str, S: int, ranks: int) -> None:
    if ranks < 2:
        raise ValueError(f"{name} needs ranks >= 2, got {ranks}")
    if ranks > S:
        raise ValueError(f"{name}: ranks {ranks} > endpoints {S}")


def _fill_program(name: str, S: int, ranks: int,
                  rank_partner: np.ndarray, packets: int) -> WorkloadProgram:
    """Lower rank-level phases onto S endpoints: ranks map identity onto
    the first ``ranks`` endpoints, the rest are self-partnered with the
    same per-phase size (local fast-path delivery) — the same layout the
    allreduce builders use, so completion semantics match."""
    n_phases = rank_partner.shape[0]
    partner = np.tile(np.arange(S, dtype=np.int64), (n_phases, 1))
    partner[:, :ranks] = rank_partner
    return WorkloadProgram(
        name=name, partner=partner,
        packets=np.full((n_phases, S), packets, np.int64))


def lm_prefill_program(S: int, ranks: int, packets: int) -> WorkloadProgram:
    """Ring all-gather: phase ``p`` sends rank ``r``'s current shard to
    ``(r + 1) mod ranks``; ``ranks - 1`` phases of ``packets`` each."""
    _check_ranks("lm_prefill", S, ranks)
    r = np.arange(ranks, dtype=np.int64)
    rank_partner = np.tile((r + 1) % ranks, (ranks - 1, 1))
    return _fill_program(f"lm_prefill[{ranks}x{packets}]", S, ranks,
                         rank_partner, packets)


def lm_decode_program(S: int, ranks: int, packets: int) -> WorkloadProgram:
    """Decode point-to-point: one phase, rank ``r`` ships its token
    activations to stage peer ``(r + ranks // 2) mod ranks`` (the
    cross-fabric pipeline hop)."""
    _check_ranks("lm_decode", S, ranks)
    r = np.arange(ranks, dtype=np.int64)
    rank_partner = ((r + ranks // 2) % ranks)[None, :]
    return _fill_program(f"lm_decode[{ranks}x{packets}]", S, ranks,
                         rank_partner, packets)


def lm_moe_program(S: int, ranks: int, packets: int) -> WorkloadProgram:
    """Expert-parallel All2All: shifted exchange, phase ``p`` pairs rank
    ``r`` with ``(r + p + 1) mod ranks``; ``packets`` = one rank-pair
    routed-token slice."""
    _check_ranks("lm_moe", S, ranks)
    r = np.arange(ranks, dtype=np.int64)
    rank_partner = np.stack([(r + p + 1) % ranks for p in range(ranks - 1)])
    return _fill_program(f"lm_moe[{ranks}x{packets}]", S, ranks,
                         rank_partner, packets)


_STRUCTURAL = {"prefill": lm_prefill_program, "decode": lm_decode_program,
               "moe": lm_moe_program}


def _default_ranks(S: int) -> int:
    """Largest power of two <= min(S, 8): a typical tensor-parallel degree
    that always fits the fabric."""
    return 1 << (min(S, 8).bit_length() - 1)


def _make_builder(phase: str):
    structural = _STRUCTURAL[phase]

    def build(S: int, *, ranks: int = 0, vec_packets: int = 16,
              **_kw) -> WorkloadProgram:
        return structural(S, ranks or _default_ranks(S), vec_packets)
    return build


for _phase in SERVING_PHASES:
    # WorkloadSpec vocabulary: pattern="lm_prefill" | "lm_decode" | "lm_moe"
    # (idempotent under re-import: the module object is cached, so this
    # body runs once per process)
    register_program_builder(f"lm_{_phase}", _make_builder(_phase))


def _packets(nbytes: float) -> int:
    return max(1, math.ceil(nbytes / PACKET_BYTES))


def request_phase_shape(cfg, phase: str, *, ranks: int,
                        tokens: int = 256, batch: int = 1) -> dict:
    """Per-phase traffic shape of one request on ``cfg``: the per-endpoint
    message size in packets plus the derivation (bytes, phases).

    * ``prefill``: one prompt shard — ``ceil(tokens / ranks) * d_model``
      activations per phase of the ring all-gather.
    * ``decode``: one token — ``d_model`` activations, times ``batch``
      decoding requests sharing the step.
    * ``moe``: one rank pair's routed tokens —
      ``tokens_local * top_k / ranks`` capacity-scaled, times ``d_model``.
    """
    if phase not in SERVING_PHASES:
        raise ValueError(f"unknown serving phase {phase!r}; expected one "
                         f"of {SERVING_PHASES}")
    if tokens < 1 or batch < 1:
        raise ValueError(f"tokens and batch must be >= 1, got "
                         f"tokens={tokens} batch={batch}")
    d = cfg.d_model
    if phase == "prefill":
        shard = math.ceil(tokens / ranks)
        nbytes = shard * d * ACT_BYTES * batch
        n_phases = ranks - 1
    elif phase == "decode":
        nbytes = d * ACT_BYTES * batch
        n_phases = 1
    else:  # moe
        m = cfg.moe
        if m is None:
            raise ValueError(
                f"arch {cfg.name!r} has no MoE block: the moe phase needs "
                "an expert-parallel architecture")
        t_loc = max(1, math.ceil(tokens * batch / ranks))
        per_pair = max(1.0, t_loc * m.top_k / ranks * m.capacity_factor)
        nbytes = per_pair * d * ACT_BYTES
        n_phases = ranks - 1
    return {"phase": phase, "ranks": ranks, "d_model": d,
            "bytes_per_phase": int(math.ceil(nbytes)),
            "packets": _packets(nbytes), "n_phases": n_phases}


def _resolve_cfg(model):
    if isinstance(model, str):
        from ..configs import get_config   # heavy import, deferred
        return get_config(model)
    return model


def request_to_program(model, phase: str, S: int, *, ranks: int = 0,
                       tokens: int = 256, batch: int = 1) -> WorkloadProgram:
    """Compile one LM inference request into a workload program.

    ``model`` is an arch id (resolved via ``repro.configs``, lazily) or a
    ``ModelConfig``; ``phase`` is ``prefill`` / ``decode`` / ``moe``;
    ``S`` the fabric's endpoint count.  ``ranks=0`` picks the default
    tensor-parallel degree."""
    cfg = _resolve_cfg(model)
    n = ranks or _default_ranks(S)
    shape = request_phase_shape(cfg, phase, ranks=n, tokens=tokens,
                                batch=batch)
    return _STRUCTURAL[phase](S, n, shape["packets"])


def request_to_spec(model, phase: str, S: int, *, ranks: int = 0,
                    tokens: int = 256, batch: int = 1):
    """The :class:`repro.api.WorkloadSpec` equivalent of
    :func:`request_to_program` — declarative, JSON-serializable, and
    executed device-resident by the runner through the registered
    ``lm_*`` builders."""
    from ..api.specs import WorkloadSpec   # avoid a cycle at import time
    cfg = _resolve_cfg(model)
    n = ranks or _default_ranks(S)
    shape = request_phase_shape(cfg, phase, ranks=n, tokens=tokens,
                                batch=batch)
    return WorkloadSpec(pattern=f"lm_{phase}", ranks=n,
                        vec_packets=shape["packets"])
