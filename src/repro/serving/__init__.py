"""Open-loop serving traffic: arrival processes, the LM request-to-traffic
bridge, and load-latency SLO sweeps.

Three layers over the simulator stack:

* the engine's ``Traffic("arrival")`` source (Poisson / bounded-Pareto /
  diurnal, :mod:`repro.simulator.engine`) injects request batches
  open-loop and measures birth-to-ejection latency;
* :mod:`repro.serving.bridge` compiles LM requests (prefill all-gather,
  decode point-to-point, MoE All2All) into workload programs — importing
  this package registers the ``lm_prefill`` / ``lm_decode`` / ``lm_moe``
  spec patterns;
* :mod:`repro.serving.sweep` turns a :class:`ServingSpec` into the
  p50/p99/p999 vs offered-load SLO curve with its saturation knee
  (``python -m repro.api serve-sweep spec.json``).
"""
from .bridge import (PACKET_BYTES, SERVING_PHASES, lm_decode_program,
                     lm_moe_program, lm_prefill_program, request_phase_shape,
                     request_to_program, request_to_spec)
from .spec import ServingSpec
from .sweep import serve_sweep, serve_sweep_many

__all__ = [
    "PACKET_BYTES",
    "SERVING_PHASES",
    "ServingSpec",
    "lm_prefill_program",
    "lm_decode_program",
    "lm_moe_program",
    "request_phase_shape",
    "request_to_program",
    "request_to_spec",
    "serve_sweep",
    "serve_sweep_many",
]
