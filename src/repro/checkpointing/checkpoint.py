"""Sharded, atomic, elastic checkpointing.

* Atomic: written to ``<dir>/tmp.<step>`` then renamed to ``<dir>/step_N`` —
  a crash mid-save never corrupts the latest checkpoint.
* Elastic: ``restore`` re-places arrays onto the *current* mesh's shardings
  (the new mesh may be smaller/larger than the one that saved — node-failure
  recovery and elastic scaling reuse the same path).
* Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread, overlapping I/O with the next train steps.
* Bounded retention: ``keep`` newest checkpoints survive.

Storage: one ``.npz`` per checkpoint with flattened path keys (portable,
no pickle).  At real production scale this would be a per-host shard file;
the layout keeps that switch local to ``_write``/``_read``.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy .npz cannot represent ml_dtypes (bfloat16, fp8): store such arrays
# as raw uint views and record the dtype in meta for lossless restore.
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_npz(a: np.ndarray):
    for name, (dt, view) in _VIEW_DTYPES.items():
        if a.dtype == dt:
            return a.view(view), name
    return a, None


def _from_npz(a: np.ndarray, name):
    if name:
        return a.view(_VIEW_DTYPES[name][0])
    return a


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        # a SIGKILL mid-save leaves a tmp.<step> behind; it never shadows
        # a finished checkpoint (only the rename publishes), but stale
        # partial writes would accumulate across supervised retries
        for name in os.listdir(directory):
            if name.startswith("tmp."):
                import shutil
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # ------------------------------------------------------------------ #
    def _write(self, step: int, host_tree: dict, meta: dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_tree)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def _snapshot(self, tree, meta):
        host, dtypes = {}, {}
        for k, v in _flatten(tree).items():
            arr, dname = _to_npz(np.asarray(v))
            host[k] = arr
            if dname:
                dtypes[k] = dname
        return host, {"dtypes": dtypes, **meta}

    def save(self, step: int, tree, meta: Optional[dict] = None):
        host, m = self._snapshot(tree, {"step": step, **(meta or {})})
        self._write(step, host, m)

    def save_async(self, step: int, tree, meta: Optional[dict] = None):
        self.wait()
        host, m = self._snapshot(tree, {"step": step, **(meta or {})})
        self._thread = threading.Thread(
            target=self._write, args=(step, host, m), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> tuple[Any, dict]:
        """Load into the structure of ``template``; re-place onto
        ``shardings`` (same tree) if given — elastic across meshes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta_peek = json.load(f)
        dtypes = meta_peek.get("dtypes", {})
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: _from_npz(data[k], dtypes.get(k)) for k in data.files}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return tree, meta
