"""Attention: GQA (RoPE, qk-norm, sliding window) and MLA (DeepSeek).

The training/prefill core is a chunked online-softmax (flash-style) attention
written in pure jnp so it lowers everywhere (the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU fast path and is validated
against the same reference).  Chunking bounds the live score tensor to
``[B, q_block, H, kv_block]`` — required for the 32K prefill cells.

Decode (one new token against a cached context) uses a single fused pass; for
MLA the *absorbed* form is used so the latent cache is attended directly.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ParamSpec, apply_rope, fdot, rmsnorm, rope_freqs

NEG = -1e30


# ---------------------------------------------------------------------- #
# chunked online-softmax core
# ---------------------------------------------------------------------- #
def attention_core(q, k, v, *, causal: bool, window: Optional[int] = None,
                   q_block: int = 512, kv_block: int = 1024,
                   q_offset: int = 0):
    """q: [B,Sq,H,D]; k,v: [B,Skv,Hkv,D] with H % Hkv == 0.

    Returns [B,Sq,H,D].  Scans over q blocks; within each q block scans over
    kv blocks with running (max, sum, acc) — O(q_block*kv_block) live scores.
    ``window`` (sliding-window attention) statically restricts the kv range
    per q block to ``window + q_block`` positions.
    """
    B, Sq, H, D = q.shape
    Skv_real, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    q_block = min(q_block, Sq)
    pq = (-Sq) % q_block
    if pq:                        # pad queries to a block multiple
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    Sq_pad = Sq + pq
    nq = Sq_pad // q_block

    use_window = window is not None and Skv_real > window + 2 * q_block
    if use_window:
        kv_span = min(window + q_block, Skv_real)
        nkv = 1
        kv_block = kv_span
        Skv = Skv_real
    else:
        kv_block = min(kv_block, Skv_real)
        pk = (-Skv_real) % kv_block
        if pk:                    # pad keys/values; masked by kpos < Skv_real
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        Skv = Skv_real + pk
        nkv = Skv // kv_block

    qr = q.reshape(B, nq, q_block, H, D).transpose(1, 0, 2, 3, 4)
    kv_limit = Skv_real

    def q_step(_, qb_and_idx):
        with jax.named_scope("flash_tile"):
            return _q_step_inner(qb_and_idx)

    def _q_step_inner(qb_and_idx):
        qb, qi = qb_and_idx                        # [B,qb,H,D], scalar idx
        q0 = qi * q_block + q_offset               # global start of q block

        if use_window:
            start = jnp.clip(q0 + q_block - kv_span, 0, Skv - kv_span)
            kb = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            starts = start[None]
            kbs, vbs = kb[None], vb[None]
        else:
            starts = jnp.arange(nkv, dtype=jnp.int32) * kv_block
            kbs = k.reshape(B, nkv, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
            vbs = v.reshape(B, nkv, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

        def kv_step(carry, kv):
            m, l, acc = carry
            # interior of the flash-attention tile: VMEM-resident when the
            # Pallas kernel (repro.kernels.flash_attention) replaces this
            # reference; the analyzer's fused mode keys off this scope name.
            kb_, vb_, k0 = kv                      # [B,kb,Hkv,D], start
            kb_r = jnp.repeat(kb_, g, axis=2)      # [B,kb,H,D]
            vb_r = jnp.repeat(vb_, g, axis=2)
            s = fdot("bqhd,bkhd->bhqk", qb, kb_r) * scale
            qpos = q0 + jnp.arange(q_block)[:, None]
            kpos = k0 + jnp.arange(kb_.shape[1])[None, :]
            mask = kpos < kv_limit            # kv padding
            if causal:
                mask = mask & (kpos <= qpos)
            if window is not None:
                mask = mask & (kpos > qpos - (window + 1))
            s = jnp.where(mask[None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + fdot(
                "bhqk,bkhd->bhqd", p.astype(vb_r.dtype), vb_r)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kbs, vbs, starts))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,qb,H,D]

    # checkpoint each q block: backward recomputes the kv scan per block
    # (flash-style) instead of saving f32 softmax tiles for every
    # (q_block, kv_block) pair — otherwise the saved p-stacks are
    # O(Sq*Skv) f32 and dominate HBM (verified in the dry-run HLO).
    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (qr, jnp.arange(nq, dtype=jnp.int32)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq_pad, H, Dv)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """q: [B,1,H,D]; caches: [B,S,Hkv,D] (ring-buffered if window).

    Masks cache entries beyond ``pos``; with a window cache the whole ring is
    valid once pos >= window.  Softmax in f32.
    """
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    kr = jnp.repeat(k_cache, g, axis=2)
    vr = jnp.repeat(v_cache, g, axis=2)
    s = fdot("bqhd,bkhd->bhk", q, kr) * scale
    idx = jnp.arange(S)
    valid = idx <= pos if window is None else (idx <= pos) | (pos >= S)
    s = jnp.where(valid[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = fdot("bhk,bkhd->bhd", p.astype(vr.dtype), vr)
    return out[:, None].astype(q.dtype).reshape(B, 1, H, Dv)


# ---------------------------------------------------------------------- #
# GQA block
# ---------------------------------------------------------------------- #
def gqa_specs(cfg) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # TP over heads where divisible; else over head_dim (e.g. hymba's 25H);
    # attn_replicated turns attention TP off entirely (small-attention archs
    # where score psums would dominate the collective term).
    if cfg.attn_replicated:
        h_ax = d_ax = None
    else:
        h_ax, d_ax = ("tp", None) if cfg.heads_shardable else (None, "tp")
    out = {
        "wq": ParamSpec((d, H, hd), ("fsdp", h_ax, d_ax)),
        "wk": ParamSpec((d, Hkv, hd), ("fsdp", h_ax, d_ax)),
        "wv": ParamSpec((d, Hkv, hd), ("fsdp", h_ax, d_ax)),
        "wo": ParamSpec((H, hd, d), (h_ax, d_ax, "fsdp"),
                        scale=0.02 / math.sqrt(2 * cfg.total_layers)),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec((hd,), (None,), "float32", "ones")
        out["k_norm"] = ParamSpec((hd,), (None,), "float32", "ones")
    return out


def gqa_qkv(p, x, cfg, positions):
    """Project + rope; returns q [B,S,H,D], k, v [B,S,Hkv,D]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.bfloat16)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.bfloat16)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
        cos, sin = cos[:, :, None], sin[:, :, None]    # [B,S,1,hd/2]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                      preferred_element_type=jnp.bfloat16)


# ---------------------------------------------------------------------- #
# MLA block (DeepSeek-V3)
# ---------------------------------------------------------------------- #
def mla_specs(cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    return {
        "wq_a": ParamSpec((d, m.q_lora), ("fsdp", None)),
        "q_norm": ParamSpec((m.q_lora,), (None,), "float32", "ones"),
        "wq_b": ParamSpec((m.q_lora, H, m.nope_dim + m.rope_dim),
                          (None, "tp", None)),
        "wkv_a": ParamSpec((d, m.kv_lora + m.rope_dim), ("fsdp", None)),
        "kv_norm": ParamSpec((m.kv_lora,), (None,), "float32", "ones"),
        "wk_b": ParamSpec((m.kv_lora, H, m.nope_dim), (None, "tp", None)),
        "wv_b": ParamSpec((m.kv_lora, H, m.v_dim), (None, "tp", None)),
        "wo": ParamSpec((H, m.v_dim, d), ("tp", None, "fsdp"),
                        scale=0.02 / math.sqrt(2 * cfg.total_layers)),
    }


def mla_latent(p, x, cfg, positions):
    """Shared path: compressed kv latent + rope key (single shared head)."""
    m = cfg.mla
    ckv = jnp.einsum("bsd,dc->bsc", x, p["wkv_a"],
                     preferred_element_type=jnp.bfloat16)
    c_kv, k_rope = ckv[..., : m.kv_lora], ckv[..., m.kv_lora:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(m.rope_dim, cfg.rope_theta, positions)
    k_rope = apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_queries(p, x, cfg, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dq->bsq", x, p["wq_a"],
                    preferred_element_type=jnp.bfloat16)
    q = jnp.einsum("bsq,qhk->bshk", rmsnorm(cq, p["q_norm"], cfg.norm_eps),
                   p["wq_b"], preferred_element_type=jnp.bfloat16)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    cos, sin = rope_freqs(m.rope_dim, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos[:, :, None], sin[:, :, None])
    return q_nope, q_rope


def mla_attention_train(p, x, cfg, positions, q_block=512, kv_block=1024):
    """Expanded form: materialize per-head K/V from the latent (train/prefill)."""
    m = cfg.mla
    c_kv, k_rope = mla_latent(p, x, cfg, positions)
    q_nope, q_rope = mla_queries(p, x, cfg, positions)
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, p["wk_b"],
                        preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsc,chk->bshk", c_kv, p["wv_b"],
                   preferred_element_type=jnp.bfloat16)
    H = cfg.n_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None],
                                (*k_rope.shape[:2], H, m.rope_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b.astype(k_nope.dtype)], -1)
    o = attention_core(q, k, v, causal=True, q_block=q_block,
                       kv_block=kv_block)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                      preferred_element_type=jnp.bfloat16), (c_kv, k_rope)


def mla_attention_decode(p, x, cfg, c_kv_cache, k_rope_cache, pos):
    """Absorbed form: attend the latent cache directly (decode)."""
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    c_kv_new, k_rope_new = mla_latent(p, x, cfg, positions)
    c_kv_cache = jax.lax.dynamic_update_slice_in_dim(c_kv_cache, c_kv_new, pos, 1)
    k_rope_cache = jax.lax.dynamic_update_slice_in_dim(k_rope_cache, k_rope_new, pos, 1)
    q_nope, q_rope = mla_queries(p, x, cfg, positions)
    # absorb W_k into the query
    q_c = fdot("bshk,chk->bshc", q_nope, p["wk_b"])
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)
    s = (fdot("bshc,btc->bhst", q_c.astype(jnp.bfloat16), c_kv_cache)
         + fdot("bshk,btk->bhst", q_rope, k_rope_cache)) * scale
    idx = jnp.arange(c_kv_cache.shape[1])
    s = jnp.where((idx <= pos)[None, None, None], s, NEG)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = fdot("bhst,btc->bshc", pattn.astype(jnp.bfloat16), c_kv_cache)
    o = jnp.einsum("bshc,chk->bshk", ctx.astype(jnp.bfloat16), p["wv_b"],
                   preferred_element_type=jnp.bfloat16)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=jnp.bfloat16)
    return out, c_kv_cache, k_rope_cache
