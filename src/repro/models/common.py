"""Shared model machinery: parameter specs, init, norms, RoPE, activations.

Parameters are described by :class:`ParamSpec` trees (shape, dtype, logical
sharding axes, init recipe).  The same tree drives:
  * real initialization (smoke tests, the train example),
  * abstract ``ShapeDtypeStruct`` construction with attached shardings
    (the multi-pod dry-run — no allocation),
  * optimizer-state and checkpoint layout.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import Sharder

DType = jnp.dtype

# bf16 x bf16 -> f32 dots: the TPU target wants MXU bf16 inputs with f32
# accumulation (preferred_element_type).  XLA:CPU's DotThunk rejects that
# combination at runtime for some contraction patterns, so CPU execution
# (smoke tests, examples) upcasts instead.  The dry-run sets
# REPRO_STRICT_BF16=1 to keep the TPU-intent HLO (it never executes).
import os as _os
_STRICT = _os.environ.get("REPRO_STRICT_BF16", "0") == "1"


def fdot(subscripts, a, b):
    """einsum with f32 accumulation (TPU-intent bf16 MXU dot)."""
    if _STRICT or jax.default_backend() != "cpu":
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical sharding names per dim (see Sharder)
    dtype: str = "bfloat16"
    init: str = "normal"        # normal | zeros | ones | mamba_a | dt_bias
    scale: float = 0.02

    def struct(self, sh: Optional[Sharder] = None) -> jax.ShapeDtypeStruct:
        if sh is None:
            return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))
        return jax.ShapeDtypeStruct(
            self.shape, jnp.dtype(self.dtype),
            sharding=sh.sharding(self.axes, self.shape))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "mamba_a":      # A_log = log(1..N) broadcast over channels
        n = spec.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, spec.shape).astype(dt)
    if spec.init == "dt_bias":      # softplus^-1 of uniform(1e-3, 1e-1)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dt)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dt)


def init_params(specs, key, sh: Optional[Sharder] = None):
    """Initialize a ParamSpec tree; deterministic per-leaf keys by path."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    out = []
    for i, leaf in enumerate(leaves):
        v = _init_one(leaf, jax.random.fold_in(key, i))
        if sh is not None:
            v = jax.device_put(v, sh.sharding(leaf.axes, leaf.shape))
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, sh: Optional[Sharder] = None):
    return jax.tree.map(lambda s: s.struct(sh), specs, is_leaf=is_spec)


def param_shardings(specs, sh: Sharder):
    return jax.tree.map(lambda s: sh.sharding(s.axes, s.shape), specs,
                        is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------- #
# numerics
# ---------------------------------------------------------------------- #
def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: [...]; returns cos/sin of shape [..., head_dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., head_dim]; cos/sin broadcastable to [..., head_dim/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def activation(name: str):
    if name == "swiglu" or name == "geglu":
        raise ValueError("gated activations are handled in the MLP itself")
    return {
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "silu": jax.nn.silu,
        "sq_relu": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


GATED_ACTS = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu}


def mlp_specs(d_model: int, d_ff: int, act: str, scale_out: float) -> dict:
    if act in GATED_ACTS:
        return {
            "wi": ParamSpec((d_model, 2, d_ff), ("fsdp", None, "tp")),
            "wo": ParamSpec((d_ff, d_model), ("tp", "fsdp"), scale=scale_out),
        }
    return {
        "wi": ParamSpec((d_model, d_ff), ("fsdp", "tp")),
        "wo": ParamSpec((d_ff, d_model), ("tp", "fsdp"), scale=scale_out),
    }


def mlp_apply(p: dict, x, act: str):
    if act in GATED_ACTS:
        gu = jnp.einsum("bsd,dgf->bsgf", x, p["wi"],
                        preferred_element_type=jnp.bfloat16)
        h = GATED_ACTS[act](gu[:, :, 0].astype(jnp.float32)).astype(x.dtype) \
            * gu[:, :, 1]
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"],
                       preferred_element_type=jnp.bfloat16)
        h = activation(act)(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"],
                      preferred_element_type=jnp.bfloat16)


def pad_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple
