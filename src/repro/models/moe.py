"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Dispatch strategy (see DESIGN.md): activations enter the MoE block already
replicated over the model axis (they are the psum output of the TP attention
block), so expert dispatch needs *no* communication — each model-rank gathers
the tokens routed to its local experts (capacity-bounded top-C selection),
runs the expert FFNs as one batched einsum, and scatter-adds gate-weighted
results.  The only collective is the combine ``psum`` over the model axis,
which coincides with the TP all-reduce the block needs anyway.

The cross-pod/EP traffic this generates is exactly the All2All-class pattern
whose fabric cost the paper optimizes (MRLS +50% vs FT at 100K endpoints) —
see ``repro.fabric`` for the planner that consumes the dry-run byte counts.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamSpec, GATED_ACTS
from .. import _jax_compat  # noqa: F401 — polyfills jax.shard_map


__all__ = ["MoECfg", "moe_specs", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_scale_bias: bool = False    # DeepSeek aux-loss-free bias


def moe_specs(cfg) -> dict:
    m, d = cfg.moe, cfg.d_model
    scale_out = 0.02 / math.sqrt(2 * cfg.total_layers)
    out = {
        "router": ParamSpec((d, m.n_experts), (None, None), "float32"),
        "wi": ParamSpec((m.n_experts, d, 2, m.d_expert),
                        ("tp", "fsdp", None, None)),
        "wo": ParamSpec((m.n_experts, m.d_expert, d),
                        ("tp", None, "fsdp"), scale=scale_out),
    }
    if m.router_scale_bias:
        out["router_bias"] = ParamSpec((m.n_experts,), (None,), "float32", "zeros")
    if m.n_shared:
        out["shared_wi"] = ParamSpec((d, 2, m.n_shared * m.d_expert),
                                     ("fsdp", None, "tp"))
        out["shared_wo"] = ParamSpec((m.n_shared * m.d_expert, d),
                                     ("tp", "fsdp"), scale=scale_out)
    return out


def _local_expert_ffn(wi, wo, xs):
    """xs: [E_loc, C, d] -> [E_loc, C, d]; gated (SwiGLU) experts."""
    gu = jnp.einsum("ecd,edgf->ecgf", xs, wi,
                    preferred_element_type=jnp.bfloat16)
    h = jax.nn.silu(gu[:, :, 0].astype(jnp.float32)).astype(xs.dtype) * gu[:, :, 1]
    return jnp.einsum("ecf,efd->ecd", h, wo,
                      preferred_element_type=jnp.bfloat16)


def moe_apply(p: dict, x, cfg, sh):
    """x: [B,S,d] (replicated over the model axis).  Returns [B,S,d]."""
    m: MoECfg = cfg.moe
    B, S, d = x.shape
    mesh = sh.mesh
    tp_ax = sh.rules.tp
    dp_axes = tuple(sh.rules.dp)
    n_tp = mesh.shape[tp_ax] if tp_ax else 1
    assert m.n_experts % n_tp == 0
    e_loc = m.n_experts // n_tp

    # per-device token count and capacity (static)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    t_loc = (B * S) // n_dp
    cap = max(4, int(t_loc * m.top_k * m.capacity_factor / m.n_experts))

    def local(x_loc, router_w, router_b, wi_loc, wo_loc):
        T = x_loc.shape[0] * x_loc.shape[1]
        xt = x_loc.reshape(T, d)
        logits = (xt.astype(jnp.float32) @ router_w).astype(jnp.float32)
        if router_b is not None:                  # aux-loss-free load balance
            sel_scores = jax.nn.sigmoid(logits) + router_b
        else:
            sel_scores = logits
        top_vals, top_idx = jax.lax.top_k(sel_scores, m.top_k)     # [T,k]
        gates = jax.nn.softmax(
            jnp.take_along_axis(logits, top_idx, 1), axis=-1)      # [T,k]

        tp_rank = jax.lax.axis_index(tp_ax) if tp_ax else 0
        e0 = tp_rank * e_loc
        # match[e, T*k] for my experts; pick first `cap` per expert
        flat_e = top_idx.reshape(-1)                               # [T*k]
        flat_g = gates.reshape(-1)
        eids = e0 + jnp.arange(e_loc, dtype=jnp.int32)
        match = flat_e[None, :] == eids[:, None]                   # [E_loc,T*k]
        prio = jnp.where(match, -jnp.arange(T * m.top_k, dtype=jnp.int32),
                         jnp.int32(-(1 << 30)))
        sel_p, sel_i = jax.lax.top_k(prio, cap)                    # [E_loc,cap]
        sel_ok = sel_p > -(1 << 30)
        tok = jnp.where(sel_ok, sel_i // m.top_k, 0)
        gate = jnp.where(sel_ok, flat_g[sel_i], 0.0)

        xs = xt[tok.reshape(-1)].reshape(e_loc, cap, d)
        ys = _local_expert_ffn(wi_loc, wo_loc, xs)
        ys = ys * gate[..., None].astype(ys.dtype)
        out = jnp.zeros((T, d), ys.dtype).at[tok.reshape(-1)].add(
            ys.reshape(-1, d), mode="drop")
        if tp_ax:
            out = jax.lax.psum(out, tp_ax)
        return out.reshape(x_loc.shape)

    router_b = p.get("router_bias")
    in_specs = (P(dp_axes, None, None), P(None, None),
                (P(None) if router_b is not None else None),
                P(tp_ax, None, None, None), P(tp_ax, None, None))
    out = jax.shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(dp_axes, None, None),
        check_vma=False,
    )(x, p["router"].astype(jnp.float32), router_b, p["wi"], p["wo"])

    if m.n_shared:
        gu = jnp.einsum("bsd,dgf->bsgf", x, p["shared_wi"],
                        preferred_element_type=jnp.bfloat16)
        h = jax.nn.silu(gu[:, :, 0].astype(jnp.float32)).astype(x.dtype) * gu[:, :, 1]
        out = out + jnp.einsum("bsf,fd->bsd", h, p["shared_wo"],
                               preferred_element_type=jnp.bfloat16)
    return out
