"""Unified model assembly for all assigned architectures.

A model is a *block program*: an ordered list of homogeneous groups, each
``lax.scan``-ned over stacked layer parameters (keeps the HLO small enough to
compile 80 dry-run cells) — heterogeneous stacks (DeepSeek dense->MoE,
Hymba's 3 full-attention layers, Llama-vision's cross-attn interleave) are
split into scanned groups / unrolled singletons.

Three entry points per model:
  * ``train_logits / loss``      — causal LM training (or enc-dec).
  * ``prefill``                  — build the decode cache from a prompt.
  * ``decode_step``              — one token against the cache (serve_step).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .common import (ParamSpec, abstract_params, init_params, mlp_apply,
                     mlp_specs, pad_vocab, rmsnorm)
from . import attention as attn
from .moe import MoECfg, moe_apply, moe_specs
from .ssm import ssm_decode, ssm_prefill, ssm_specs
from ..parallel.sharding import Sharder


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tp_heads: bool = True           # TP over heads (False -> over head_dim)
    # MoE
    moe: Optional[MoECfg] = None
    dense_layers: int = 0           # leading dense layers (DeepSeek: 3)
    dense_d_ff: int = 0
    # MLA
    mla: Optional[MLACfg] = None
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    hybrid: bool = False            # parallel attn + ssm (Hymba)
    full_attn_layers: tuple = ()    # hybrid: these layer idxs use full attn
    sliding_window: Optional[int] = None
    # cross-attention context (vision tokens / audio frames)
    cross_every: int = 0            # vlm: 1 cross layer per `cross_every`
    n_ctx_tokens: int = 0
    ctx_seq_for: dict = dataclasses.field(default_factory=dict)
    # encoder-decoder
    enc_dec: bool = False
    enc_layers: int = 0
    # execution knobs
    remat: str = "full"             # full | dots | none
    seq_parallel: bool = False      # Megatron-style SP on the residual stream
    attn_replicated: bool = False   # no TP in attention (tiny-head archs)
    q_block: int = 512
    kv_block: int = 1024
    ssm_chunk: int = 256

    @property
    def total_layers(self) -> int:
        return self.n_layers + self.enc_layers

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def heads_shardable(self) -> bool:
        return self.tp_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid-with-window)."""
        return self.ssm_state > 0

    def param_count(self) -> int:
        from .common import count_params
        return count_params(build_specs(self))


# ---------------------------------------------------------------------- #
# block program
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Group:
    kind: str          # dense|moe|mla_dense|mla_moe|mamba|hybrid|hybrid_full
    n: int             # |vision_super|enc|dec
    name: str


def plan(cfg: ModelConfig) -> list[Group]:
    if cfg.enc_dec:
        return [Group("enc", cfg.enc_layers, "enc"),
                Group("dec", cfg.n_layers, "dec")]
    if cfg.family == "vlm":
        assert cfg.n_layers % (cfg.cross_every) == 0
        return [Group("vision_super", cfg.n_layers // cfg.cross_every, "vs")]
    if cfg.family == "ssm":
        return [Group("mamba", cfg.n_layers, "m")]
    if cfg.hybrid:
        groups, prev, gi = [], 0, 0
        fal = sorted(cfg.full_attn_layers)
        for li in fal:
            if li > prev:
                groups.append(Group("hybrid", li - prev, f"h{gi}")); gi += 1
            groups.append(Group("hybrid_full", 1, f"hf{gi}")); gi += 1
            prev = li + 1
        if prev < cfg.n_layers:
            groups.append(Group("hybrid", cfg.n_layers - prev, f"h{gi}"))
        return groups
    if cfg.moe is not None:
        gs = []
        if cfg.dense_layers:
            gs.append(Group("mla_dense" if cfg.mla else "dense",
                            cfg.dense_layers, "d"))
        gs.append(Group("mla_moe" if cfg.mla else "moe",
                        cfg.n_layers - cfg.dense_layers, "e"))
        return gs
    return [Group("dense", cfg.n_layers, "d")]


# ---------------------------------------------------------------------- #
# parameter specs
# ---------------------------------------------------------------------- #
def _norm(cfg):
    return ParamSpec((cfg.d_model,), (None,), "float32", "ones")


def _dense_ffn_specs(cfg, kind):
    d_ff = cfg.dense_d_ff if kind in ("mla_dense",) and cfg.dense_d_ff \
        else cfg.d_ff
    scale = 0.02 / math.sqrt(2 * cfg.total_layers)
    return mlp_specs(cfg.d_model, d_ff, cfg.act, scale)


def block_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "mamba":
        return {"ln1": _norm(cfg), "ssm": ssm_specs(cfg)}
    if kind in ("hybrid", "hybrid_full"):
        return {
            "ln1": _norm(cfg),
            "attn": attn.gqa_specs(cfg),
            "ssm": ssm_specs(cfg),
            "po_norm_a": _norm(cfg), "po_norm_s": _norm(cfg),
            "ln2": _norm(cfg), "mlp": _dense_ffn_specs(cfg, kind),
        }
    if kind == "vision_super":
        self_block = {"ln1": _norm(cfg), "attn": attn.gqa_specs(cfg),
                      "ln2": _norm(cfg), "mlp": _dense_ffn_specs(cfg, kind)}
        stacked = jax.tree.map(
            lambda s: ParamSpec((cfg.cross_every - 1, *s.shape),
                                (None, *s.axes), s.dtype, s.init, s.scale),
            self_block, is_leaf=lambda x: isinstance(x, ParamSpec))
        gate = ParamSpec((), (), "float32", "zeros")
        return {"self": stacked,
                "cross": {"ln1": _norm(cfg), "attn": attn.gqa_specs(cfg),
                          "gate_attn": gate,
                          "ln2": _norm(cfg), "mlp": _dense_ffn_specs(cfg, kind),
                          "gate_mlp": gate}}
    if kind == "dec":
        return {"ln1": _norm(cfg), "attn": attn.gqa_specs(cfg),
                "lnx": _norm(cfg), "xattn": attn.gqa_specs(cfg),
                "ln2": _norm(cfg), "mlp": _dense_ffn_specs(cfg, kind)}
    out = {"ln1": _norm(cfg)}
    out["attn"] = attn.mla_specs(cfg) if kind.startswith("mla") else \
        attn.gqa_specs(cfg)
    out["ln2"] = _norm(cfg)
    if kind.endswith("moe"):
        out["moe"] = moe_specs(cfg)
    else:
        out["mlp"] = _dense_ffn_specs(cfg, kind)
    return out


def _stack(specs, n):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.axes), s.dtype, s.init,
                            s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def build_specs(cfg: ModelConfig) -> dict:
    V, d = cfg.vocab_padded, cfg.d_model
    out = {
        "embed": ParamSpec((V, d), (None, "tp"), scale=1.0 / math.sqrt(d)),
        "final_norm": _norm(cfg),
        "unembed": ParamSpec((d, V), ("fsdp", "tp")),
        "groups": {},
    }
    for g in plan(cfg):
        specs = block_specs(cfg, g.kind)
        out["groups"][g.name] = _stack(specs, g.n) if g.n > 1 else \
            _stack(specs, 1)
    if cfg.enc_dec:
        out["enc_final_norm"] = _norm(cfg)
    return out


# ---------------------------------------------------------------------- #
# block application
# ---------------------------------------------------------------------- #
def _cross_kv(p_attn, ctx, cfg):
    k = jnp.einsum("bsd,dhk->bshk", ctx, p_attn["wk"],
                   preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsd,dhk->bshk", ctx, p_attn["wv"],
                   preferred_element_type=jnp.bfloat16)
    if cfg.qk_norm:
        k = rmsnorm(k, p_attn["k_norm"], cfg.norm_eps)
    return k, v


def _cross_q(p_attn, h, cfg):
    q = jnp.einsum("bsd,dhk->bshk", h, p_attn["wq"],
                   preferred_element_type=jnp.bfloat16)
    if cfg.qk_norm:
        q = rmsnorm(q, p_attn["q_norm"], cfg.norm_eps)
    return q


def block_apply(kind, p, x, cfg, sh, positions, ctx=None):
    """Full-sequence (train / prefill) block.  Returns (x, cache_entry)."""
    cache = {}
    if kind == "mamba":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, (conv_s, ssm_s) = ssm_prefill(p["ssm"], h, cfg, cfg.ssm_chunk)
        cache = {"conv": conv_s, "ssm": ssm_s}
        return x + y, cache

    if kind in ("hybrid", "hybrid_full"):
        window = None if kind == "hybrid_full" else cfg.sliding_window
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
        o = attn.attention_core(q, k, v, causal=True, window=window,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
        a_out = attn.gqa_out(p["attn"], o)
        s_out, (conv_s, ssm_s) = ssm_prefill(p["ssm"], h, cfg, cfg.ssm_chunk)
        mixed = 0.5 * (rmsnorm(a_out, p["po_norm_a"], cfg.norm_eps)
                       + rmsnorm(s_out, p["po_norm_s"], cfg.norm_eps))
        x = x + mixed
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
        W = window or k.shape[1]
        cache = {"k": k[:, -W:], "v": v[:, -W:], "conv": conv_s, "ssm": ssm_s}
        return x, cache

    if kind.startswith("mla"):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a_out, (c_kv, k_rope) = attn.mla_attention_train(
            p["attn"], h, cfg, positions, cfg.q_block, cfg.kv_block)
        x = x + a_out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind.endswith("moe"):
            x = x + moe_apply(p["moe"], h2, cfg, sh)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.act)
        return x, {"ckv": c_kv, "kr": k_rope}

    if kind == "vision_super":
        caches = []
        for i in range(cfg.cross_every - 1):
            pi = jax.tree.map(lambda a: a[i], p["self"])
            h = rmsnorm(x, pi["ln1"], cfg.norm_eps)
            q, k, v = attn.gqa_qkv(pi["attn"], h, cfg, positions)
            o = attn.attention_core(q, k, v, causal=True,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block)
            x = x + attn.gqa_out(pi["attn"], o)
            h2 = rmsnorm(x, pi["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pi["mlp"], h2, cfg.act)
            caches.append({"k": k, "v": v})
        pc = p["cross"]
        h = rmsnorm(x, pc["ln1"], cfg.norm_eps)
        ck, cv = _cross_kv(pc["attn"], ctx, cfg)
        q = _cross_q(pc["attn"], h, cfg)
        o = attn.attention_core(q, ck, cv, causal=False,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + jnp.tanh(pc["gate_attn"]).astype(x.dtype) * attn.gqa_out(pc["attn"], o)
        h2 = rmsnorm(x, pc["ln2"], cfg.norm_eps)
        x = x + jnp.tanh(pc["gate_mlp"]).astype(x.dtype) * mlp_apply(pc["mlp"], h2, cfg.act)
        cache = {"k": jnp.stack([c["k"] for c in caches], 0),
                 "v": jnp.stack([c["v"] for c in caches], 0),
                 "ck": ck, "cv": cv}
        return x, cache

    if kind == "dec":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
        o = attn.attention_core(q, k, v, causal=True,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + attn.gqa_out(p["attn"], o)
        hx = rmsnorm(x, p["lnx"], cfg.norm_eps)
        ck, cv = _cross_kv(p["xattn"], ctx, cfg)
        qx = _cross_q(p["xattn"], hx, cfg)
        ox = attn.attention_core(qx, ck, cv, causal=False,
                                 q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + attn.gqa_out(p["xattn"], ox)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
        return x, {"k": k, "v": v, "ck": ck, "cv": cv}

    # dense / moe / enc
    causal = kind != "enc"
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
    o = attn.attention_core(q, k, v, causal=causal, window=cfg.sliding_window,
                            q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + attn.gqa_out(p["attn"], o)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        x = x + moe_apply(p["moe"], h2, cfg, sh)
    else:
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
    cache = {} if kind == "enc" else {"k": k, "v": v}
    return x, cache


# ---------------------------------------------------------------------- #
# decode-step block application
# ---------------------------------------------------------------------- #
def _write_kv(cache_k, cache_v, k, v, pos, window):
    W = cache_k.shape[1]
    wpos = pos % W if window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, wpos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, wpos, 1)
    return cache_k, cache_v


def block_decode(kind, p, x, cfg, sh, cache, pos):
    """x: [B,1,d].  Returns (x, cache')."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)

    if kind == "mamba":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, (conv_s, ssm_s) = ssm_decode(p["ssm"], h, cfg,
                                        cache["conv"], cache["ssm"])
        return x + y, {"conv": conv_s, "ssm": ssm_s}

    if kind in ("hybrid", "hybrid_full"):
        window = None if kind == "hybrid_full" else cfg.sliding_window
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
        ck, cv = _write_kv(cache["k"], cache["v"], k, v, pos,
                           window is not None)
        o = attn.decode_attention(q, ck, cv, pos, window=window)
        a_out = attn.gqa_out(p["attn"], o)
        s_out, (conv_s, ssm_s) = ssm_decode(p["ssm"], h, cfg,
                                            cache["conv"], cache["ssm"])
        mixed = 0.5 * (rmsnorm(a_out, p["po_norm_a"], cfg.norm_eps)
                       + rmsnorm(s_out, p["po_norm_s"], cfg.norm_eps))
        x = x + mixed
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
        return x, {"k": ck, "v": cv, "conv": conv_s, "ssm": ssm_s}

    if kind.startswith("mla"):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a_out, ckv, kr = attn.mla_attention_decode(
            p["attn"], h, cfg, cache["ckv"], cache["kr"], pos)
        x = x + a_out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind.endswith("moe"):
            x = x + moe_apply(p["moe"], h2, cfg, sh)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.act)
        return x, {"ckv": ckv, "kr": kr}

    if kind == "vision_super":
        ks, vs = [], []
        for i in range(cfg.cross_every - 1):
            pi = jax.tree.map(lambda a: a[i], p["self"])
            h = rmsnorm(x, pi["ln1"], cfg.norm_eps)
            q, k, v = attn.gqa_qkv(pi["attn"], h, cfg, positions)
            ck_, cv_ = _write_kv(cache["k"][i], cache["v"][i], k, v, pos, False)
            o = attn.decode_attention(q, ck_, cv_, pos)
            x = x + attn.gqa_out(pi["attn"], o)
            h2 = rmsnorm(x, pi["ln2"], cfg.norm_eps)
            x = x + mlp_apply(pi["mlp"], h2, cfg.act)
            ks.append(ck_); vs.append(cv_)
        pc = p["cross"]
        h = rmsnorm(x, pc["ln1"], cfg.norm_eps)
        q = _cross_q(pc["attn"], h, cfg)
        o = attn.decode_attention(q, cache["ck"], cache["cv"],
                                  cache["ck"].shape[1] - 1)
        x = x + jnp.tanh(pc["gate_attn"]).astype(x.dtype) * attn.gqa_out(pc["attn"], o)
        h2 = rmsnorm(x, pc["ln2"], cfg.norm_eps)
        x = x + jnp.tanh(pc["gate_mlp"]).astype(x.dtype) * mlp_apply(pc["mlp"], h2, cfg.act)
        return x, {"k": jnp.stack(ks, 0), "v": jnp.stack(vs, 0),
                   "ck": cache["ck"], "cv": cache["cv"]}

    if kind == "dec":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
        ck_, cv_ = _write_kv(cache["k"], cache["v"], k, v, pos, False)
        o = attn.decode_attention(q, ck_, cv_, pos)
        x = x + attn.gqa_out(p["attn"], o)
        hx = rmsnorm(x, p["lnx"], cfg.norm_eps)
        qx = _cross_q(p["xattn"], hx, cfg)
        ox = attn.decode_attention(qx, cache["ck"], cache["cv"],
                                   cache["ck"].shape[1] - 1)
        x = x + attn.gqa_out(p["xattn"], ox)
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
        return x, {"k": ck_, "v": cv_, "ck": cache["ck"], "cv": cache["cv"]}

    # dense / moe
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn.gqa_qkv(p["attn"], h, cfg, positions)
    ck, cv = _write_kv(cache["k"], cache["v"], k, v, pos,
                       cfg.sliding_window is not None)
    o = attn.decode_attention(q, ck, cv, pos, window=cfg.sliding_window)
    x = x + attn.gqa_out(p["attn"], o)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        x = x + moe_apply(p["moe"], h2, cfg, sh)
    else:
        x = x + mlp_apply(p["mlp"], h2, cfg.act)
    return x, {"k": ck, "v": cv}


# ---------------------------------------------------------------------- #
# model-level passes
# ---------------------------------------------------------------------- #
def _maybe_remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        # save projection/MLP dot outputs; the attention tile interior keeps
        # its own inner checkpoint (flash-style recompute) regardless.
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots)
    return f


def _encode(params, cfg, sh, ctx_embeds):
    """Encoder stack (enc-dec models): ctx_embeds [B,S_src,d] -> memory."""
    x = ctx_embeds
    g = plan(cfg)[0]
    gp = params["groups"][g.name]
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    def body(carry, pl):
        y, _ = block_apply("enc", pl, carry, cfg, sh, positions, None)
        return sh.constrain_safe(y, "dp", "sp", None), None
    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, gp)
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def embed_tokens(params, tokens, cfg, sh):
    x = jnp.take(params["embed"], tokens, axis=0)
    return sh.constrain_safe(x, "dp", "sp", None)


def logits_from(params, x, cfg):
    return jnp.einsum("bsd,dv->bsv", rmsnorm(x, params["final_norm"],
                                             cfg.norm_eps),
                      params["unembed"], preferred_element_type=jnp.bfloat16)


def forward_train(params, batch, cfg: ModelConfig, sh: Sharder):
    """batch: {"tokens": [B,S] int32, optional "ctx": [B,Sc,d]}.
    Returns logits [B,S,V]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg, sh)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = batch.get("ctx")
    if cfg.enc_dec:
        ctx = _encode(params, cfg, sh, ctx)
        groups = plan(cfg)[1:]
    else:
        groups = plan(cfg)
    for g in groups:
        gp = params["groups"][g.name]
        def body(carry, pl):
            y, _ = block_apply(g.kind, pl, carry, cfg, sh, positions, ctx)
            return sh.constrain_safe(y, "dp", "sp", None), None
        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, gp)
    return logits_from(params, x, cfg)


def loss_fn(params, batch, cfg, sh):
    logits = forward_train(params, batch, cfg, sh)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], -1)[..., 0]
    mask = labels >= 0
    nll = jnp.where(mask, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def prefill(params, batch, cfg: ModelConfig, sh: Sharder):
    """Prompt pass: returns (last-token logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg, sh)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = batch.get("ctx")
    caches = {}
    if cfg.enc_dec:
        ctx = _encode(params, cfg, sh, ctx)
        groups = plan(cfg)[1:]
    else:
        groups = plan(cfg)
    for g in groups:
        gp = params["groups"][g.name]
        def body(carry, pl):
            y, cache = block_apply(g.kind, pl, carry, cfg, sh, positions, ctx)
            return sh.constrain_safe(y, "dp", "sp", None), cache
        body = _maybe_remat(body, cfg)
        x, cs = jax.lax.scan(body, x, gp)
        caches[g.name] = cs
    return logits_from(params, x[:, -1:], cfg), caches


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, sh: Sharder):
    """tokens: [B,1]; pos: scalar int32.  Returns (logits, cache')."""
    x = embed_tokens(params, tokens, cfg, sh)
    groups = plan(cfg)[1:] if cfg.enc_dec else plan(cfg)
    new_caches = {}
    for g in groups:
        gp = params["groups"][g.name]
        def body(carry, xs):
            pl, cl = xs
            y, c2 = block_decode(g.kind, pl, carry, cfg, sh, cl, pos)
            return y, c2
        x, cs = jax.lax.scan(body, x, (gp, cache[g.name]))
        new_caches[g.name] = cs
    return logits_from(params, x, cfg), new_caches


# ---------------------------------------------------------------------- #
# abstract inputs & cache specs (dry-run)
# ---------------------------------------------------------------------- #
def cache_struct(cfg: ModelConfig, batch: int, seq: int, sh: Sharder):
    """ShapeDtypeStructs of the decode cache at context length ``seq``."""
    bf16 = jnp.bfloat16
    f32 = jnp.float32
    di = cfg.ssm_expand * cfg.d_model
    out = {}

    def sds(shape, axes, dtype=bf16):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=sh.sharding(axes, shape))

    groups = plan(cfg)[1:] if cfg.enc_dec else plan(cfg)
    for g in groups:
        L = g.n
        c = {}
        if g.kind == "mamba":
            c = {"conv": sds((L, batch, di, cfg.ssm_conv - 1), (None, "dp", "tp", None)),
                 "ssm": sds((L, batch, di, cfg.ssm_state), (None, "dp", "tp", None), f32)}
        elif g.kind in ("hybrid", "hybrid_full"):
            W = cfg.sliding_window if g.kind == "hybrid" else seq
            c = {"k": sds((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None)),
                 "v": sds((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None)),
                 "conv": sds((L, batch, di, cfg.ssm_conv - 1), (None, "dp", "tp", None)),
                 "ssm": sds((L, batch, di, cfg.ssm_state), (None, "dp", "tp", None), f32)}
        elif g.kind.startswith("mla"):
            m = cfg.mla
            c = {"ckv": sds((L, batch, seq, m.kv_lora), (None, "dp", "tp", None)),
                 "kr": sds((L, batch, seq, m.rope_dim), (None, "dp", "tp", None))}
        elif g.kind == "vision_super":
            ns = cfg.cross_every - 1
            c = {"k": sds((L, ns, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                          (None, None, "dp", "tp", None, None)),
                 "v": sds((L, ns, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                          (None, None, "dp", "tp", None, None)),
                 "ck": sds((L, batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim),
                           (None, "dp", None, None, None)),
                 "cv": sds((L, batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim),
                           (None, "dp", None, None, None))}
        elif g.kind == "dec":
            c = {"k": sds((L, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None)),
                 "v": sds((L, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None)),
                 "ck": sds((L, batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim),
                           (None, "dp", None, None, None)),
                 "cv": sds((L, batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim),
                           (None, "dp", None, None, None))}
        else:
            W = cfg.sliding_window or seq
            c = {"k": sds((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None)),
                 "v": sds((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                          (None, "dp", "tp", None, None))}
        out[g.name] = c
    return out
