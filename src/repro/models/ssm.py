"""Mamba-1 selective SSM block (falcon-mamba, hymba's parallel SSM path).

Prefill runs a chunked parallel scan: an outer ``lax.scan`` over time-chunks
carrying the SSM state, with a ``lax.associative_scan`` inside each chunk —
the TPU-friendly decomposition (the Pallas kernel in
``repro.kernels.selective_scan`` implements the same chunk step).  Decode is
the O(1) single-step recurrence; its state is the whole "KV cache", which is
what makes the ``long_500k`` cells tractable for SSM/hybrid archs.

Channel dimension (``d_inner``) is embarrassingly parallel -> sharded over
the ``model`` (TP) axis; state dim N is tiny (16).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ParamSpec, fdot

__all__ = ["ssm_specs", "ssm_prefill", "ssm_decode"]


def ssm_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    scale_out = 0.02 / math.sqrt(2 * cfg.total_layers)
    return {
        "in_proj": ParamSpec((d, 2, di), ("fsdp", None, "tp")),
        "conv_w": ParamSpec((cfg.ssm_conv, di), (None, "tp")),
        "conv_b": ParamSpec((di,), ("tp",), init="zeros"),
        "x_proj": ParamSpec((di, dt_rank + 2 * N), ("tp", None)),
        "dt_w": ParamSpec((dt_rank, di), (None, "tp"),
                          scale=dt_rank ** -0.5),
        "dt_b": ParamSpec((di,), ("tp",), "float32", "dt_bias"),
        "A_log": ParamSpec((di, N), ("tp", None), "float32", "mamba_a"),
        "D": ParamSpec((di,), ("tp",), "float32", "ones"),
        "out_proj": ParamSpec((di, d), ("tp", "fsdp"), scale=scale_out),
    }


def _ssm_inputs(p, x, cfg):
    """Shared projections: returns (u, z, dt, Bc, Cc) with
    u,z: [B,S,di]; dt: [B,S,di] (f32); Bc,Cc: [B,S,N] (f32)."""
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,dgi->bsgi", x, p["in_proj"],
                    preferred_element_type=jnp.bfloat16)
    u, z = xz[:, :, 0], xz[:, :, 1]
    return u, z


def _post_conv(p, u_conv, cfg):
    N = cfg.ssm_state
    dt_rank = p["dt_w"].shape[0]
    u_act = jax.nn.silu(u_conv.astype(jnp.float32)).astype(u_conv.dtype)
    proj = fdot("bsi,ir->bsr", u_act, p["x_proj"])
    dt_in, Bc, Cc = (proj[..., :dt_rank], proj[..., dt_rank:dt_rank + N],
                     proj[..., dt_rank + N:])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["dt_w"].astype(jnp.float32))
        + p["dt_b"])
    return u_act, dt, Bc, Cc


def ssm_prefill(p, x, cfg, chunk: int = 256):
    """x: [B,S,d] -> (y [B,S,d], (conv_state, ssm_state))."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    K = cfg.ssm_conv
    u, z = _ssm_inputs(p, x, cfg)

    # causal depthwise conv over time
    u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    u_conv = sum(u_pad[:, i: i + S] * p["conv_w"][i][None, None]
                 for i in range(K)) + p["conv_b"][None, None]
    u_act, dt, Bc, Cc = _post_conv(p, u_conv, cfg)

    A = -jnp.exp(p["A_log"])                                   # [di,N]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:       # dt=0 padding is the identity step: da=1, db=0
        u_act = jnp.pad(u_act, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def chunk_step(h, xs):
        with jax.named_scope("ssm_chunk"):
            return _chunk_inner(h, xs)

    def _chunk_inner(h, xs):
        ua, dt_c, B_c, C_c = xs                                # [B,chunk,...]
        da = jnp.exp(dt_c[..., None] * A[None, None])          # [B,c,di,N]
        db = (dt_c * ua.astype(jnp.float32))[..., None] * B_c[:, :, None]
        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        a_sc, b_sc = jax.lax.associative_scan(comb, (da, db), axis=1)
        hs = a_sc * h[:, None] + b_sc                          # [B,c,di,N]
        y = jnp.einsum("bcin,bcn->bci", hs, C_c)
        return hs[:, -1], y

    ur = u_act.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    dtr = dt.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)
    Br = Bc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cr = Cc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (ur, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, di)[:, :S]
    y = y + u_act[:, :S].astype(jnp.float32) * p["D"][None, None]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.bfloat16)
    conv_state = u[:, -(K - 1):].transpose(0, 2, 1) if K > 1 else \
        jnp.zeros((B, di, 0), u.dtype)
    return out, (conv_state, h_last)


def ssm_decode(p, x, cfg, conv_state, h):
    """x: [B,1,d]; conv_state: [B,di,K-1]; h: [B,di,N].  O(1) step."""
    B = x.shape[0]
    di = cfg.ssm_expand * cfg.d_model
    K = cfg.ssm_conv
    u, z = _ssm_inputs(p, x, cfg)                              # [B,1,di]
    u1 = u[:, 0]
    window = jnp.concatenate([conv_state, u1[:, :, None]], axis=2)  # [B,di,K]
    u_conv = (window * p["conv_w"].T[None]).sum(-1) + p["conv_b"]
    u_act, dt, Bc, Cc = _post_conv(p, u_conv[:, None], cfg)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[:, 0, :, None] * A[None])                  # [B,di,N]
    db = (dt[:, 0] * u_act[:, 0].astype(jnp.float32))[..., None] * Bc[:, 0, None]
    h_new = da * h + db
    y = jnp.einsum("bin,bn->bi", h_new, Cc[:, 0])
    y = y + u_act[:, 0].astype(jnp.float32) * p["D"][None]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None].astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.bfloat16)
    return out, (window[:, :, 1:], h_new)
