"""Design-space search over (family, radix, f, policy, vcs).

The paper's argument is a *design* argument — random multi-layer
leaf-spine fabrics beat structured ones per unit link cost — so this
package turns the repro into a searcher: a frozen :class:`SearchSpec`
names the axes and protocol, :func:`search` samples/prunes/screens/
promotes candidates through the normal batched ``run()`` path, and the
Pareto layer emits the throughput-vs-cost frontier artifact
(``artifacts/PARETO_search.json``).  Importing the package registers
the ``python -m repro.api search`` subcommand.
"""
from .loop import search, search_many
from .pareto import dominated_flags, frontier_ids
from .space import (Candidate, DesignError, candidate_experiment,
                    design_network, designer_families, register_designer)
from .spec import OBJECTIVES, STRATEGIES, SearchSpec
from . import cli as _cli  # noqa: F401  (subcommand registration)

__all__ = [
    "SearchSpec", "OBJECTIVES", "STRATEGIES",
    "Candidate", "DesignError", "register_designer", "designer_families",
    "design_network", "candidate_experiment",
    "search", "search_many",
    "dominated_flags", "frontier_ids",
]
