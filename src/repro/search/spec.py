"""Frozen, JSON-round-trippable design-space search specification.

A :class:`SearchSpec` names the *design space* the paper argues over —
(topology family, radix, thickness ``f``, routing policy, VC count) at a
fixed endpoint count — plus the search protocol: objective, strategy
(``random`` | ``evolutionary``), candidate budget, successive-halving
screen/promotion windows, and the memory budget the estimator prunes
against *before* anything compiles.  It follows the same frozen-spec
discipline as :mod:`repro.api.specs`: hashable, losslessly
``to_dict()``/``from_dict()`` round-trippable, validated at
construction.  ``python -m repro.api search spec.json`` executes one
from a file.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Optional, Tuple

from ..api.specs import RouteSpec, WorkloadSpec
from ..core.routing import POLICIES

__all__ = ["SearchSpec", "OBJECTIVES", "STRATEGIES"]

OBJECTIVES = ("throughput_per_link", "throughput")
STRATEGIES = ("random", "evolutionary")


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """One design-space search at a fixed endpoint count.

    Axes (the cartesian space candidates are drawn from):

    * ``families`` — topology families with a registered designer
      (:mod:`repro.search.space`; ``mrls``/``jellyfish``/``fat_tree``
      out of the box).
    * ``radix`` — switch radix R per candidate.
    * ``f`` — thickness (network-port : endpoint-port ratio ``u/d``;
      the paper's passes knob).  Families without the knob (fat_tree)
      accept and record it without using it.
    * ``policies`` / ``vcs`` — routing-policy and virtual-channel axes
      applied on top of ``route``.

    Protocol:

    * ``objective`` — ``throughput_per_link`` (delivered throughput /
      links-per-endpoint, the paper's throughput-per-cost lens) or raw
      ``throughput``.
    * ``strategy`` — ``random`` draws ``budget`` distinct candidates;
      ``evolutionary`` seeds half the budget randomly and fills the rest
      by mutating one axis of screened elites.
    * ``budget`` — total candidates drawn (pruned ones count: they were
      drawn, the estimator refused them).
    * ``screen_warm``/``screen_measure`` — the cheap screening window
      every admitted candidate gets; ``warm``/``measure`` — the full
      window survivors are promoted to.
    * ``survivors`` — promotion fraction for successive halving (the
      top ``ceil(survivors * screened)`` candidates re-run full; the
      screen-stage Pareto frontier is always promoted on top of the
      quota so the cost axis stays covered).
    * ``max_slots`` — completion-run ceiling per candidate (all2all
      workloads); candidates that blow it read as zero throughput
      instead of stalling the search for the full default budget.
    * ``mem_budget_mib`` — per-candidate resident peak budget
      (``estimate_memory(...)["peak_bytes"]``); candidates over it are
      pruned without compiling.  ``None`` skips the explicit budget and
      leaves only host-RAM admission (:mod:`repro.api.admission`).
    """

    endpoints: int
    families: Tuple[str, ...] = ("mrls", "jellyfish", "fat_tree")
    radix: Tuple[int, ...] = (16, 24, 32)
    f: Tuple[float, ...] = (1.0, 2.0)
    policies: Tuple[str, ...] = ("polarized",)
    vcs: Tuple[int, ...] = (4,)
    route: RouteSpec = RouteSpec()
    workload: WorkloadSpec = WorkloadSpec("uniform", load=1.0)
    objective: str = "throughput_per_link"
    strategy: str = "random"
    budget: int = 16
    survivors: float = 0.5
    screen_warm: int = 30
    screen_measure: int = 60
    warm: int = 100
    measure: int = 200
    max_slots: int = 60_000
    seed: int = 0
    replicas: int = 1
    mem_budget_mib: Optional[float] = None
    name: str = ""

    def __post_init__(self):
        for field, cast in (("families", str), ("policies", str),
                            ("radix", int), ("vcs", int), ("f", float)):
            vals = getattr(self, field)
            if isinstance(vals, (str, int, float)):
                vals = (vals,)
            vals = tuple(cast(v) for v in vals)
            if not vals:
                raise ValueError(f"SearchSpec.{field} must name at least "
                                 "one value")
            object.__setattr__(self, field, vals)
        if not isinstance(self.route, RouteSpec):
            object.__setattr__(self, "route",
                               RouteSpec.from_dict(self.route))
        if not isinstance(self.workload, WorkloadSpec):
            object.__setattr__(self, "workload",
                               WorkloadSpec.from_dict(self.workload))
        if self.endpoints < 4:
            raise ValueError(f"endpoints must be >= 4, got {self.endpoints}")
        if self.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"known: {OBJECTIVES}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"known: {STRATEGIES}")
        unknown = [p for p in self.policies if p not in POLICIES]
        if unknown:
            raise ValueError(f"unknown routing policies {unknown}; "
                             f"known: {POLICIES}")
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")
        if not 0.0 < self.survivors <= 1.0:
            raise ValueError(f"survivors must lie in (0, 1], got "
                             f"{self.survivors}")
        for field in ("screen_warm", "screen_measure", "warm", "measure",
                      "max_slots"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be >= 1, got "
                                 f"{getattr(self, field)}")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.mem_budget_mib is not None and self.mem_budget_mib <= 0:
            raise ValueError(f"mem_budget_mib must be > 0, got "
                             f"{self.mem_budget_mib}")

    # ------------------------------------------------------------------ #
    def label(self) -> str:
        return self.name or f"search.{self.endpoints}.{self.objective}"

    def mem_budget_bytes(self) -> Optional[int]:
        if self.mem_budget_mib is None:
            return None
        return int(self.mem_budget_mib * (1 << 20))

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for field in ("families", "radix", "f", "policies", "vcs"):
            d[field] = list(d[field])
        d["route"] = self.route.to_dict()
        d["workload"] = self.workload.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "SearchSpec":
        d = dict(d)
        if "route" in d:
            d["route"] = RouteSpec.from_dict(d["route"])
        if "workload" in d:
            d["workload"] = WorkloadSpec.from_dict(d["workload"])
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "SearchSpec":
        return cls.from_dict(json.loads(s))
