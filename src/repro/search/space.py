"""Candidate encoding and per-family fabric designers.

A :class:`Candidate` is one point of the search space — ``(family,
radix, f, policy, vcs)``.  A *designer* maps the point plus the spec's
fixed endpoint count to concrete builder kwargs for that family
(:data:`repro.core.TOPOLOGY_BUILDERS` vocabulary), mirroring the
paper's sizing rules:

* ``mrls`` — :func:`repro.core.analytics.mrls_design`: ``d = R/(1+f)``
  endpoint ports, ``u = R - d`` uplinks, leaf count rounded up until
  ``u*n1 % R == 0``.
* ``jellyfish`` — same port split on a flat random regular graph:
  ``r = R - d`` network ports per switch, switch count rounded up to an
  even-stub population.
* ``fat_tree`` — smallest height whose full tree reaches the target
  (``f`` accepted but unused — the folded Clos has no thickness knob).

Designers live in a registry (:func:`register_designer`) so downstream
families — anything added via :func:`repro.api.register_topology` — can
join the search space without touching the loop.  Invalid points (odd
fat-tree radix, degenerate port splits, ...) raise :class:`DesignError`;
the loop records them as infeasible instead of crashing the search.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Tuple

from ..api.specs import Experiment, NetworkSpec
from ..core import analytics
from .spec import SearchSpec

__all__ = ["Candidate", "DesignError", "register_designer",
           "designer_families", "design_network", "candidate_experiment",
           "axis_values", "space_size"]


class DesignError(ValueError):
    """The (family, radix, f) point has no valid instance at this
    endpoint count — the candidate is infeasible by construction."""


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One search-space point.  Hashable — the loop dedups on it."""

    family: str
    radix: int
    f: float
    policy: str
    vcs: int

    def label(self) -> str:
        return (f"{self.family}.r{self.radix}.f{self.f:g}"
                f".{self.policy}.v{self.vcs}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "Candidate":
        return cls(family=d["family"], radix=int(d["radix"]),
                   f=float(d["f"]), policy=d["policy"], vcs=int(d["vcs"]))


def _split_ports(radix: int, f: float) -> Tuple[int, int]:
    """Split ``radix`` into (network_ports, endpoint_ports) at thickness
    ``f`` = network/endpoint — the paper's ``u/d``."""
    d = max(1, round(radix / (1.0 + f)))
    u = radix - d
    if u < 1:
        raise DesignError(f"radix {radix} at f={f:g} leaves no network "
                          "ports")
    return u, d


def _design_mrls(endpoints: int, radix: int, f: float, seed: int) -> dict:
    n1, n2, u, d = analytics.mrls_design(endpoints, radix, f)
    if n2 < 2:
        raise DesignError(f"mrls at S={endpoints}, R={radix}, f={f:g} "
                          f"needs >= 2 spines, designed {n2}")
    return {"n_leaves": n1, "u": u, "d": d, "seed": seed}


def _design_jellyfish(endpoints: int, radix: int, f: float,
                      seed: int) -> dict:
    r, d = _split_ports(radix, f)
    if r < 2:
        raise DesignError(f"jellyfish at R={radix}, f={f:g} leaves r={r} "
                          "network ports (needs >= 2)")
    n = max(r + 1, math.ceil(endpoints / d))
    if (n * r) % 2:
        n += 1                                  # even stub population
    return {"n_switches": n, "r": r, "d": d, "seed": seed}


def _design_fat_tree(endpoints: int, radix: int, f: float,
                     seed: int) -> dict:
    if radix % 2 or radix < 4:
        raise DesignError(f"fat_tree needs an even radix >= 4, got {radix}")
    k = radix // 2
    h = 1
    while 2 * k ** (h + 1) < endpoints:
        h += 1
        if h > 8:
            raise DesignError(f"fat_tree radix {radix} cannot reach "
                              f"S={endpoints} within 8 levels")
    return {"radix": radix, "h": h}


_DESIGNERS: dict = {
    "mrls": _design_mrls,
    "jellyfish": _design_jellyfish,
    "fat_tree": _design_fat_tree,
}


def register_designer(family: str,
                      designer: Callable[[int, int, float, int], dict],
                      *, overwrite: bool = False) -> None:
    """Register ``designer(endpoints, radix, f, seed) -> builder kwargs``
    so ``family`` candidates can be instantiated by the search loop.
    Same idempotence contract as :func:`repro.api.register_topology`."""
    if family in _DESIGNERS and not overwrite:
        if _DESIGNERS[family] is designer:
            return
        raise ValueError(f"designer for family {family!r} already "
                         "registered with a different function (pass "
                         "overwrite=True to replace it)")
    _DESIGNERS[family] = designer


def designer_families() -> tuple:
    return tuple(sorted(_DESIGNERS))


def design_network(cand: Candidate, endpoints: int,
                   seed: int = 0) -> NetworkSpec:
    """Instantiate ``cand`` at ``endpoints`` as a :class:`NetworkSpec`.

    Raises :class:`DesignError` for infeasible points and ``KeyError``
    for families without a designer.
    """
    try:
        designer = _DESIGNERS[cand.family]
    except KeyError:
        raise KeyError(
            f"no designer for topology family {cand.family!r}; known: "
            f"{designer_families()} (register_designer adds more)") from None
    return NetworkSpec(cand.family, designer(endpoints, cand.radix,
                                             cand.f, seed))


def candidate_experiment(spec: SearchSpec, cand: Candidate,
                         network: NetworkSpec, *,
                         stage: str) -> Experiment:
    """The runnable :class:`Experiment` for one candidate at one
    successive-halving stage (``"screen"`` or ``"full"``)."""
    warm, measure = ((spec.screen_warm, spec.screen_measure)
                     if stage == "screen" else (spec.warm, spec.measure))
    route = dataclasses.replace(spec.route, policy=cand.policy,
                                vcs=cand.vcs)
    return Experiment(
        network=network, route=route, workload=spec.workload,
        name=f"{spec.label()}.{cand.label()}.{stage}",
        seed=spec.seed, replicas=spec.replicas,
        warm=warm, measure=measure, max_slots=spec.max_slots)


def axis_values(spec: SearchSpec) -> dict:
    """The per-axis value tuples, in sampling order."""
    return {"family": spec.families, "radix": spec.radix, "f": spec.f,
            "policy": spec.policies, "vcs": spec.vcs}


def space_size(spec: SearchSpec) -> int:
    size = 1
    for vals in axis_values(spec).values():
        size *= len(vals)
    return size
