"""The design-space search loop: sample -> prune -> screen -> promote.

:func:`search` drives one :class:`SearchSpec` end to end:

1. **Sample** candidates from the (family, radix, f, policy, vcs) space
   — ``random`` draws distinct points from one seeded generator;
   ``evolutionary`` seeds half the budget randomly, then fills the rest
   by mutating one axis of screened elites (ArchGym-shaped agent loop,
   deterministic under the spec seed).
2. **Prune before compiling** — every candidate is priced by
   :func:`repro.api.estimate_memory` (exact resident bytes) and
   :func:`repro.api.check_admission` (compile-RAM-multiplier peak-RSS
   prediction); points over the spec's ``mem_budget_mib`` or the host
   budget are recorded as ``pruned`` and never touch the simulator.
   Design-infeasible points (no valid instance at this endpoint count)
   are recorded as ``invalid``.
3. **Screen** — every admitted candidate runs the spec workload through
   the normal :func:`repro.api.run` path (shared
   :class:`~repro.api.SimulatorCache`) with the cheap
   ``screen_warm``/``screen_measure`` window.
4. **Promote (successive halving)** — the top ``ceil(survivors * n)``
   screened candidates by objective re-run with the full
   ``warm``/``measure`` window *on the same cached simulator* (same
   fabric + route key — zero recompiles), and only they enter the
   Pareto layer.

The returned record is the committed artifact format (see docs/API.md
"Design-space search").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..api.admission import AdmissionError, check_admission
from ..api.memory import estimate_memory
from ..api.registry import build_network
from ..api.runner import Result, SimulatorCache, run
from ..core.analytics import exact_metrics
from ..workloads.patterns import check_pattern
from .pareto import dominated_flags, frontier_ids
from .space import (Candidate, DesignError, axis_values,
                    candidate_experiment, design_network, space_size)
from .spec import SearchSpec

__all__ = ["search", "search_many"]


# ---------------------------------------------------------------------- #
# sampling
# ---------------------------------------------------------------------- #
def _draw(rng: np.random.Generator, axes: dict) -> Candidate:
    return Candidate(**{name: vals[rng.integers(0, len(vals))]
                        for name, vals in axes.items()})


def _mutate(rng: np.random.Generator, axes: dict,
            parent: Candidate) -> Candidate:
    """Change exactly one axis of ``parent`` to a different value (axes
    with a single value can't mutate and are skipped)."""
    movable = [n for n, vals in axes.items() if len(vals) > 1]
    if not movable:
        return parent
    name = movable[rng.integers(0, len(movable))]
    vals = [v for v in axes[name] if v != getattr(parent, name)]
    value = vals[rng.integers(0, len(vals))]
    return Candidate(**{**parent.to_dict(), name: value})


def _distinct(rng: np.random.Generator, axes: dict, seen: set,
              proposer, tries: int = 64) -> Optional[Candidate]:
    """Draw until unseen; fall back to a fresh random point, then give
    up (space exhausted)."""
    for _ in range(tries):
        cand = proposer()
        if cand not in seen:
            return cand
    for _ in range(tries):
        cand = _draw(rng, axes)
        if cand not in seen:
            return cand
    return None


# ---------------------------------------------------------------------- #
# pricing (the no-compile gate)
# ---------------------------------------------------------------------- #
def _price(spec: SearchSpec, cand: Candidate, cid: int) -> dict:
    """Design + estimate + admission for one candidate — no compilation.

    Returns the candidate's record with ``status`` one of ``invalid``
    (no instance exists), ``pruned`` (estimator/admission refused it),
    or ``admitted`` (carries the screen-stage experiment under
    ``"_exp"`` for the evaluation stages).
    """
    rec = {"id": cid, **cand.to_dict(), "label": cand.label()}
    try:
        network = design_network(cand, spec.endpoints, seed=spec.seed)
        topo = build_network(network)
    except (DesignError, ValueError) as e:
        # DesignError: no instance at this point; plain ValueError: the
        # builder itself refused the designed instance (e.g. a random
        # construction too dense to repair) — both are infeasible points,
        # not search crashes
        rec.update(status="invalid", reason=str(e))
        return rec
    m = exact_metrics(topo)
    rec.update(params=network.to_dict()["params"],
               n_endpoints=m.S, n_switches=m.N, n_links=m.M,
               cost_links=m.cost_links, theta=m.theta, diameter=m.D)

    exp = candidate_experiment(spec, cand, network, stage="screen")
    est = estimate_memory(exp)
    rec.update(est_total_bytes=est["total_bytes"],
               est_peak_bytes=est["peak_bytes"])

    budget = spec.mem_budget_bytes()
    if budget is not None and est["peak_bytes"] > budget:
        rec.update(status="pruned",
                   reason=(f"estimated resident peak {est['peak_bytes']} B "
                           f"exceeds the spec's mem_budget "
                           f"({budget} B)"))
        return rec
    try:
        decision = check_admission(exp)
        rec["predicted_rss_bytes"] = decision.predicted_bytes
    except AdmissionError as e:
        rec.update(status="pruned", reason=f"admission refused: {e}")
        return rec

    rec.update(status="admitted", _exp=exp, _masks=decision.masks)
    return rec


# ---------------------------------------------------------------------- #
# objective
# ---------------------------------------------------------------------- #
def _throughput_of(res: Result) -> float:
    if res.metric == "completion":
        # all2all proxy: rounds packets per endpoint over the completion
        # window -> packets/slot/endpoint, comparable to the windowed
        # throughput metric (0 when the run hit max_slots incomplete)
        if not res.completed or not res.slots:
            return 0.0
        return res.experiment.workload.rounds / float(res.slots)
    return float(res.throughput or 0.0)


def _objective(spec: SearchSpec, rec: dict, throughput: float) -> float:
    if spec.objective == "throughput":
        return throughput
    return throughput / rec["cost_links"] if rec["cost_links"] else 0.0


def _evaluate(spec: SearchSpec, rec: dict, cache: SimulatorCache,
              stage: str) -> None:
    """Run one stage for an admitted candidate and fold the metrics into
    its record (``rec["screen"]`` / ``rec["full"]``)."""
    exp = rec["_exp"]
    if stage == "full":
        exp = candidate_experiment(
            spec, Candidate.from_dict(rec),
            exp.network, stage="full")
        if (exp.resolved_metric() == "completion"
                and dataclasses.replace(exp, name=rec["_exp"].name)
                == rec["_exp"]):
            # completion runs ignore warm/measure, so promotion would
            # replay the identical run — reuse the screen reading
            rec["full"] = dict(rec["screen"])
            return
    res = run(exp, cache=cache)
    throughput = _throughput_of(res)
    rec[stage] = {
        "throughput": throughput,
        "objective": _objective(spec, rec, throughput),
    }
    if res.avg_hops is not None:
        rec[stage]["avg_hops"] = float(res.avg_hops)
    if res.metric == "completion":
        rec[stage]["slots"] = res.slots
        rec[stage]["completed"] = res.completed


def _promote(spec: SearchSpec, screened: list) -> tuple:
    """Pick the screened candidates that re-run with the full window.

    Scalar top-``survivors`` halving alone would discard exactly the
    points the Pareto layer exists for: a cheap family can lose every
    objective comparison yet still be non-dominated on (throughput,
    cost).  So the screen-stage frontier (zero-throughput points
    excluded — a failed run earns no promotion) is always promoted, and
    the ``ceil(survivors * n)`` quota is then filled by objective rank.
    """
    ranked = sorted(screened, key=lambda r: r["screen"]["objective"],
                    reverse=True)
    n_promote = math.ceil(spec.survivors * len(ranked))
    pts = [{"throughput": r["screen"]["throughput"],
            "cost_links": r["cost_links"]} for r in ranked]
    promoted = [r for r, dom in zip(ranked, dominated_flags(pts))
                if not dom and r["screen"]["throughput"] > 0]
    chosen = {id(r) for r in promoted}
    for r in ranked:
        if len(promoted) >= n_promote:
            break
        if id(r) not in chosen:
            promoted.append(r)
            chosen.add(id(r))
    # keep run order deterministic: objective rank, frontier or not
    promoted.sort(key=lambda r: ranked.index(r))
    demoted = [r for r in ranked if id(r) not in chosen]
    return promoted, demoted


# ---------------------------------------------------------------------- #
# the loop
# ---------------------------------------------------------------------- #
def search(spec: SearchSpec, *,
           cache: Optional[SimulatorCache] = None) -> dict:
    """Run one design-space search; returns the frontier record."""
    kind = check_pattern(spec.workload.pattern)
    if kind == "collective" and spec.workload.pattern != "all2all":
        raise ValueError(
            "search ranks candidates by delivered throughput; collective "
            "workloads other than all2all have no per-slot throughput "
            f"reading (got {spec.workload.pattern!r})")

    rng = np.random.default_rng(spec.seed)
    axes = axis_values(spec)
    budget = min(spec.budget, space_size(spec))
    seen: set = set()
    records: list = []

    owns = cache is None
    if owns:
        cache = SimulatorCache()

    def admit_and_screen(cand: Candidate) -> dict:
        seen.add(cand)
        rec = _price(spec, cand, len(records))
        if rec["status"] == "admitted":
            _evaluate(spec, rec, cache, "screen")
            rec["status"] = "screened"
        records.append(rec)
        return rec

    try:
        if spec.strategy == "random":
            while len(records) < budget:
                cand = _distinct(rng, axes, seen, lambda: _draw(rng, axes))
                if cand is None:
                    break
                admit_and_screen(cand)
        else:  # evolutionary
            n_seed = max(2, math.ceil(budget / 2))
            while len(records) < min(n_seed, budget):
                cand = _distinct(rng, axes, seen, lambda: _draw(rng, axes))
                if cand is None:
                    break
                admit_and_screen(cand)
            while len(records) < budget:
                pool = sorted(
                    (r for r in records if r["status"] == "screened"),
                    key=lambda r: r["screen"]["objective"], reverse=True)
                elites = pool[:max(1, len(pool) // 2)]
                if elites:
                    parent = Candidate.from_dict(
                        elites[rng.integers(0, len(elites))])
                    cand = _distinct(rng, axes, seen,
                                     lambda: _mutate(rng, axes, parent))
                else:
                    cand = _distinct(rng, axes, seen,
                                     lambda: _draw(rng, axes))
                if cand is None:
                    break
                admit_and_screen(cand)

        # ---- successive-halving promotion ---------------------------- #
        screened = [r for r in records if r["status"] == "screened"]
        promoted, demoted = _promote(spec, screened)
        # screened-out fabrics are done — drop their simulators before
        # the full-window runs so at most |promoted| stay live
        for rec in demoted:
            exp = rec["_exp"]
            cache.release(exp.network, exp.route, rec["_masks"])
        for rec in promoted:
            _evaluate(spec, rec, cache, "full")
            rec["status"] = "full"
            exp = rec["_exp"]
            cache.release(exp.network, exp.route, rec["_masks"])
    finally:
        if owns:
            cache.close()

    for rec in records:
        rec.pop("_exp", None)
        rec.pop("_masks", None)
        if rec["status"] == "full":
            rec["throughput"] = rec["full"]["throughput"]
            rec["objective"] = rec["full"]["objective"]

    evaluated = [r for r in records if r["status"] == "full"]
    # a wedged network (zero delivered throughput over the full window)
    # earns no frontier spot, mirroring the promotion rule — it is
    # dominated outright, however cheap its links are
    alive = [r for r in evaluated if r["throughput"] > 0]
    for rec in evaluated:
        rec["dominated"] = True
    for rec, dom in zip(alive, dominated_flags(alive)):
        rec["dominated"] = dom
    frontier = frontier_ids(alive, [r["id"] for r in alive])

    counts = {s: sum(1 for r in records if r["status"] == s)
              for s in ("invalid", "pruned", "screened", "full")}
    return {
        "name": spec.label(),
        "spec": spec.to_dict(),
        "objective": spec.objective,
        "strategy": spec.strategy,
        "space_size": space_size(spec),
        "n_candidates": len(records),
        "counts": counts,
        "candidates": records,
        "frontier": frontier,
    }


def search_many(specs) -> list:
    """Run several searches; returns one record per spec."""
    return [search(s) for s in specs]
