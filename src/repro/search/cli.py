"""``python -m repro.api search`` — the design-space search driver.

Registered through the same declarative subcommand registry as the
built-in drivers (:mod:`repro.api.cli`); importing :mod:`repro.search`
is what makes the subcommand exist.  Spec files hold one search object
or ``{"searches": [...]}``; ``--pareto-out`` writes the frontier
artifact (default ``artifacts/PARETO_search.json``).
"""
from __future__ import annotations

import dataclasses
import json
import os

from ..api import cli as _cli
from .loop import search
from .spec import SearchSpec

__all__ = ["main_search", "write_pareto"]

PARETO_OUT = os.path.join("artifacts", "PARETO_search.json")


def write_pareto(records, path: str) -> None:
    """Write search record(s) as the committed frontier artifact."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    doc = records[0] if len(records) == 1 else {"searches": records}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def _summary(rec: dict) -> None:
    c = rec["counts"]
    print(f"{rec['name']}  strategy={rec['strategy']}  "
          f"objective={rec['objective']}  candidates={rec['n_candidates']}"
          f"/{rec['space_size']}  invalid={c['invalid']}  "
          f"pruned={c['pruned']}  screened={c['screened']}  "
          f"full={c['full']}")
    by_id = {r["id"]: r for r in rec["candidates"]}
    for cid in rec["frontier"]:
        r = by_id[cid]
        print(f"  * {r['label']:<40s} thr={r['throughput']:.3f}  "
              f"C_l={r['cost_links']:.3f}  obj={r['objective']:.3f}")


def main_search(args) -> int:
    specs = [SearchSpec.from_dict(d)
             for d in _cli.load_spec(args.spec, key="search",
                                     plural="searches")]
    if args.replicas is not None:
        specs = [dataclasses.replace(s, replicas=args.replicas)
                 for s in specs]
    if args.seed is not None:
        specs = [dataclasses.replace(s, seed=args.seed) for s in specs]
    records = [search(s) for s in specs]
    for rec in records:
        _summary(rec)
    if args.pareto_out:
        write_pareto(records, args.pareto_out)
        print(f"wrote Pareto artifact to {args.pareto_out}")
    _cli.emit_records(records, args.out, "search record")
    return 0


def _search_flags(p) -> None:
    p.add_argument("--pareto-out", default=PARETO_OUT, metavar="PATH",
                   help="Pareto frontier artifact path (empty string "
                        f"disables; default {PARETO_OUT})")


_cli.register_subcommand(_cli.Subcommand(
    name="search",
    help="design-space search: optimize (family, radix, f, policy, vcs) "
         "at fixed endpoints for throughput per link cost",
    fn=main_search,
    spec_help="path to the JSON search spec "
              "(one object or {'searches': [...]})",
    out="write full search records as JSON",
    replicas=True, seed=True,
    configure=_search_flags,
))
