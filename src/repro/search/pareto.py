"""Pareto layer: dominance over (throughput up, link cost down).

The search's committed artifact is a *frontier*, not a single winner —
the paper's design argument is exactly a throughput-vs-cost trade
(Θ vs C_l, Eqs. 1-2), so every fully-evaluated candidate carries a
``dominated`` flag and the record names the non-dominated subset.

Candidate ``a`` dominates ``b`` when ``a.throughput >= b.throughput``
and ``a.cost_links <= b.cost_links`` with at least one strict — the
standard weak-dominance rule on (maximize throughput, minimize cost).
"""
from __future__ import annotations

from typing import Optional, Sequence

__all__ = ["dominated_flags", "frontier_ids"]


def _dominates(a: dict, b: dict) -> bool:
    ge = a["throughput"] >= b["throughput"]
    le = a["cost_links"] <= b["cost_links"]
    strict = (a["throughput"] > b["throughput"]
              or a["cost_links"] < b["cost_links"])
    return ge and le and strict


def dominated_flags(points: Sequence[dict]) -> list:
    """``points`` carry ``throughput`` and ``cost_links``; returns one
    bool per point (O(n^2) — search budgets are tens, not millions)."""
    return [any(_dominates(a, b) for a in points if a is not b)
            for b in points]


def frontier_ids(points: Sequence[dict],
                 ids: Optional[Sequence] = None) -> list:
    """Ids (default: indices) of the non-dominated points, sorted by
    ascending link cost so the frontier reads as a curve."""
    if ids is None:
        ids = list(range(len(points)))
    keep = [(p["cost_links"], p["throughput"], i)
            for p, i, dom in zip(points, ids, dominated_flags(points))
            if not dom]
    return [i for _, _, i in sorted(keep, key=lambda t: (t[0], -t[1]))]
