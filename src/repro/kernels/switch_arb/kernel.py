"""Pallas TPU kernel: fused per-switch crossbar arbitration.

Hardware adaptation (docs/DESIGN.md): the simulator's arbitration stage is
a batch of tiny independent problems — one per switch — with no cross-switch
data flow.  The kernel tiles ``block_n`` switches per grid step and keeps a
whole switch's requester block ``[R, P]`` resident in VMEM, fusing

* routing-score evaluation (``occ + penalty * deroute + tie``, masked),
* per-requester port selection (VPU argmin over ports), and
* segmented output arbitration (per-port max-priority reduction over the
  requester axis)

into one pass, so the ``[NR, P]`` score/priority intermediates never hit
HBM.  The score axis is padded to the 128-lane boundary and the requester
axis to the 8-sublane boundary (f32 tile = (8, 128)); padded lanes carry
``mask = 0`` -> score ``BIG`` and padded rows carry ``route = 0``, so they
can never win a grant and the unpadded results are bitwise those of
``ref.switch_arbitrate_ref``.

``vc_prearb`` (stage 1 of the sub-round) is likewise tiled per switch.  It
cannot fuse into the arbitration kernel: between the two stages the engine
gathers the selected head packets and their attributes from state arrays
(data-dependent addresses spanning the whole pool), which is exactly the
irregular access Pallas blocks are not shaped for — see DESIGN.md.  Its
``[P, V]`` trailing block is left unpadded (V is 4; a production TPU port
would flatten to a 128-lane ``[P * V]`` layout).

All randomness is drawn by the caller (``jax.random`` on the host stream)
and passed in as tensors, which is what makes kernel, oracle, and inline
XLA engine bitwise interchangeable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# python float, not a jnp scalar: kernel bodies must not capture traced
# constants, and weak-typed 1e9 promotes to the same f32 the engine uses
BIG = 1e9


def _pad_to(x, mults, fill):
    """Pad trailing dims of ``x`` up to multiples of ``mults`` (leading dims
    untouched when the corresponding mult is 1)."""
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if not any(hi for _, hi in pads):
        return x
    return jnp.pad(x, pads, constant_values=fill)


# ---------------------------------------------------------------------- #
# stage 1: VC pre-arbitration
# ---------------------------------------------------------------------- #
def _prearb_kernel(qlen_ref, rand_ref, sel_ref, has_ref):
    prio = jnp.where(qlen_ref[...] > 0, rand_ref[...], -1.0)
    sel_ref[...] = jnp.argmax(prio, axis=-1).astype(jnp.int32)
    has_ref[...] = (jnp.max(prio, axis=-1) >= 0.0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def vc_prearb(qlen, rand, block_n: int = 8, interpret: bool = False):
    """Per-switch-tiled VC pre-arbitration.  [N, P, V] -> ([N, P], [N, P])."""
    n, p, v = qlen.shape
    qlen = _pad_to(qlen, (block_n, 1, 1), 0)
    rand = _pad_to(rand, (block_n, 1, 1), 0.0)
    np_ = qlen.shape[0]
    grid = (np_ // block_n,)
    sel, has = pl.pallas_call(
        _prearb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, p, v), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((np_, p), jnp.int32),
            jax.ShapeDtypeStruct((np_, p), jnp.int32),
        ),
        interpret=interpret,
    )(qlen, rand)
    return sel[:n], has[:n]


# ---------------------------------------------------------------------- #
# stages 2+3: fused score evaluation + segmented output arbitration
# ---------------------------------------------------------------------- #
def _arb_kernel(occ_ref, der_ref, mask_ref, tie_ref, route_ref, rnd_ref,
                lo_ref, port_ref, win_ref, seg_ref, *, penalty: float):
    score = (occ_ref[...].astype(jnp.float32)
             + penalty * der_ref[...].astype(jnp.float32) + tie_ref[...])
    score = jnp.where(mask_ref[...] > 0, score, BIG)
    port = jnp.argmin(score, axis=-1).astype(jnp.int32)
    can = (route_ref[...] > 0) & (jnp.min(score, axis=-1) < BIG)
    prio = jnp.where(can, (rnd_ref[...] << 23) | lo_ref[...], -1)
    p_ids = jax.lax.broadcasted_iota(jnp.int32, score.shape, 2)
    onehot = (port[:, :, None] == p_ids) & can[:, :, None]      # [BN,R,P]
    seg = jnp.max(jnp.where(onehot, prio[:, :, None], -1), axis=1)
    seg_at = jnp.sum(jnp.where(onehot, seg[:, None, :], 0), axis=-1)
    port_ref[...] = port
    win_ref[...] = (can & (seg_at == prio)).astype(jnp.int32)
    seg_ref[...] = seg


@functools.partial(jax.jit, static_argnames=("penalty", "block_n",
                                             "interpret"))
def switch_arbitrate(occ, deroute, mask, tie, route, rnd, lo, *,
                     penalty: float, block_n: int = 8,
                     interpret: bool = False):
    """Fused arbitration over the dense per-switch layout.

    Shapes/dtypes as in :func:`repro.kernels.switch_arb.ref
    .switch_arbitrate_ref`; returns ``(port, win)`` int32 [N, R] plus the
    per-output-port winning priority ``seg`` int32 [N, P].
    """
    n, r, p = occ.shape
    m3, m2 = (block_n, 8, 128), (block_n, 8)
    occ = _pad_to(occ, m3, 0)
    deroute = _pad_to(deroute, m3, 0)
    mask = _pad_to(mask, m3, 0)
    tie = _pad_to(tie, m3, 0.0)
    route = _pad_to(route, m2, 0)
    rnd = _pad_to(rnd, m2, 0)
    lo = _pad_to(lo, m2, 0)
    np_, rp, pp = occ.shape
    grid = (np_ // block_n,)
    spec3 = pl.BlockSpec((block_n, rp, pp), lambda i: (i, 0, 0))
    spec2 = pl.BlockSpec((block_n, rp), lambda i: (i, 0))
    spec_seg = pl.BlockSpec((block_n, pp), lambda i: (i, 0))
    port, win, seg = pl.pallas_call(
        functools.partial(_arb_kernel, penalty=penalty),
        grid=grid,
        in_specs=[spec3, spec3, spec3, spec3, spec2, spec2, spec2],
        out_specs=(spec2, spec2, spec_seg),
        out_shape=(
            jax.ShapeDtypeStruct((np_, rp), jnp.int32),
            jax.ShapeDtypeStruct((np_, rp), jnp.int32),
            jax.ShapeDtypeStruct((np_, pp), jnp.int32),
        ),
        interpret=interpret,
    )(occ, deroute, mask, tie, route, rnd, lo)
    return port[:n, :r], win[:n, :r], seg[:n, :p]
