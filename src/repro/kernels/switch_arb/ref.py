"""Pure-jnp oracle for the fused switch-arbitration kernel.

One crossbar sub-round of the cycle-level simulator
(:mod:`repro.simulator.engine`) decomposes into

1. **VC pre-arbitration** — per (switch, input port), pick one candidate VC
   among the non-empty input queues by random priority;
2. **routing-score evaluation** — per requester, score every output port
   (occupancy + deroute penalty + random tiebreak, masked to the
   allowed & credited ports) and pick the argmin;
3. **segmented output arbitration** — per (switch, output port), grant the
   single requester with the highest random priority.

Stages 2+3 operate on a dense per-switch requester layout
``[N, R, ...]`` where row ``r`` of switch ``n`` is network input port ``r``
(``r < P``) or NIC slot ``r - P`` (leaf switches only); the engine scatters
its flat requester table into this layout (see ``ops.switch_arbitrate_flat``)
so a Pallas kernel can tile over switches with every requester of a switch
resident in one block.

All randomness is drawn by the caller and passed in — the oracle, the
Pallas kernel, and the engine's inline XLA path therefore produce
*bitwise identical* grants for the same PRNG stream.

Integer-mask convention: ``deroute``/``mask``/``route`` arrive as int32
0/1 (Pallas block I/O is friendlier to int32 than bool) and ``win`` is
returned as int32 0/1.
"""
from __future__ import annotations

import jax.numpy as jnp

BIG = jnp.float32(1e9)


def vc_prearb_ref(qlen, rand):
    """VC pre-arbitration: random-priority pick among non-empty VCs.

    ``qlen``: int32 [N, P, V] input-queue lengths; ``rand``: float32
    [N, P, V] uniform [0, 1) priorities.  Returns ``(vc_sel, has_pkt)``:
    int32 [N, P] selected VC and int32 [N, P] 0/1 whether any VC had a
    packet (the selected VC is non-empty iff so).
    """
    prio = jnp.where(qlen > 0, rand, -1.0)
    vc_sel = jnp.argmax(prio, axis=-1).astype(jnp.int32)
    has_pkt = (jnp.max(prio, axis=-1) >= 0.0).astype(jnp.int32)
    return vc_sel, has_pkt


def switch_arbitrate_ref(occ, deroute, mask, tie, route, rnd, lo, *,
                         penalty: float):
    """Fused routing-score evaluation + segmented output arbitration.

    Inputs (dense per-switch layout, ``R`` requester rows per switch):
      occ     int32   [N, R, P]  congestion (output queue + downstream queue)
      deroute int32   [N, R, P]  0/1 — port is a Polarized deroute
      mask    int32   [N, R, P]  0/1 — port allowed by routing AND credited
      tie     float32 [N, R, P]  uniform [0, 1) score tiebreak
      route   int32   [N, R]     0/1 — requester holds a routable packet
      rnd     int32   [N, R]     8-bit random arbitration priority
      lo      int32   [N, R]     unique low bits (flat requester index)

    Returns ``(port, win, seg)``: int32 [N, R] chosen output port, int32
    [N, R] 0/1 grant mask (at most one winner per (switch, port)), and
    int32 [N, P] winning priority word per output port (-1 = no grant; the
    low 23 bits are the winner's unique ``lo`` — the engine inverts grants
    through it without a scatter).
    """
    score = (occ.astype(jnp.float32)
             + penalty * deroute.astype(jnp.float32) + tie)
    score = jnp.where(mask > 0, score, BIG)
    port = jnp.argmin(score, axis=-1).astype(jnp.int32)
    can = (route > 0) & (jnp.min(score, axis=-1) < BIG)
    # unique int32 priorities: 8 random high bits | unique requester index
    prio = jnp.where(can, (rnd << 23) | lo, -1)
    p_ids = jnp.arange(occ.shape[-1], dtype=jnp.int32)
    onehot = (port[..., None] == p_ids) & can[..., None]        # [N,R,P]
    seg = jnp.max(jnp.where(onehot, prio[..., None], -1), axis=1)  # [N,P]
    seg_at = jnp.sum(jnp.where(onehot, seg[:, None, :], 0), axis=-1)
    win = (can & (seg_at == prio)).astype(jnp.int32)
    return port, win, seg
