"""Fused per-switch crossbar arbitration kernel (simulator hot path)."""
from .ops import switch_arbitrate_op, switch_arbitrate_flat, vc_prearb_op
from .ref import switch_arbitrate_ref, vc_prearb_ref

__all__ = [
    "switch_arbitrate_op",
    "switch_arbitrate_flat",
    "switch_arbitrate_ref",
    "vc_prearb_op",
    "vc_prearb_ref",
]
