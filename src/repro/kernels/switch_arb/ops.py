"""Public ops: fused switch arbitration with kernel/oracle dispatch.

``*_op`` entry points auto-fall back to interpret mode on CPU (kernel body
executed in Python by the Pallas interpreter), matching the other kernel
packages.  ``use_ref=True`` routes to the pure-jnp oracle instead — both
paths are bitwise identical, so the engine's ``backend="pallas"`` output
never depends on which one ran.

``switch_arbitrate_flat`` adapts the engine's flat requester table
(``[NR] = [N*P network inputs] ++ [S endpoint NICs]``) to the dense
per-switch layout the kernel tiles over: ``row_of`` (static, topology-only)
scatters flat rows to ``switch * r_max + row`` positions, and results
gather back through the same map.  Dense rows not backed by a requester
keep ``route = 0`` and can never win a grant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import switch_arbitrate, vc_prearb
from .ref import switch_arbitrate_ref, vc_prearb_ref


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def vc_prearb_op(qlen, rand, *, block_n: int = 8,
                 interpret: bool | None = None, use_ref: bool = False):
    """VC pre-arbitration.  Returns ``(vc_sel int32 [N,P], has_pkt bool)``."""
    if use_ref:
        sel, has = vc_prearb_ref(qlen, rand)
    else:
        if interpret is None:
            interpret = _auto_interpret()
        sel, has = vc_prearb(qlen, rand, block_n=block_n,
                             interpret=interpret)
    return sel, has.astype(bool)


def switch_arbitrate_op(occ, deroute, mask, tie, route, rnd, lo, *,
                        penalty: float, block_n: int = 8,
                        interpret: bool | None = None,
                        use_ref: bool = False):
    """Fused arbitration on the dense [N, R, P] layout (bool-friendly).

    ``deroute``/``mask``/``route`` may be bool or int; ``win`` returns
    bool.  Also returns ``seg`` int32 [N, P] — the winning priority word
    per output port (-1 = no grant).
    """
    i32 = jnp.int32
    args = (occ.astype(i32), deroute.astype(i32), mask.astype(i32), tie,
            route.astype(i32), rnd.astype(i32), lo.astype(i32))
    if use_ref:
        port, win, seg = switch_arbitrate_ref(*args, penalty=penalty)
    else:
        if interpret is None:
            interpret = _auto_interpret()
        port, win, seg = switch_arbitrate(*args, penalty=penalty,
                                          block_n=block_n,
                                          interpret=interpret)
    return port, win.astype(bool), seg


def switch_arbitrate_flat(occ, deroute, mask, tie, route, rnd, lo, *,
                          penalty: float, row_of, n_switches: int,
                          r_max: int, **kw):
    """Flat-requester adapter: ``[NR, ...]`` in, ``(port, win)`` back as
    flat ``[NR]`` vectors plus ``seg`` flattened to ``[N * P]`` (matching
    the engine's ``switch * P + port`` output-key layout).

    ``row_of`` is the static flat-row -> dense-row map (int32 [NR],
    injective, values < n_switches * r_max).
    """
    n_rows = n_switches * r_max

    def den(x, fill):
        out = jnp.full((n_rows,) + x.shape[1:], fill, x.dtype)
        return out.at[row_of].set(x).reshape((n_switches, r_max)
                                             + x.shape[1:])

    port, win, seg = switch_arbitrate_op(
        den(occ, 0), den(deroute, 0), den(mask, 0), den(tie, 0.0),
        den(route, 0), den(rnd, 0), den(lo, 0), penalty=penalty, **kw)
    return (port.reshape(-1)[row_of], win.reshape(-1)[row_of],
            seg.reshape(-1))
