"""Public op: selective scan with CPU-interpret fallback."""
from __future__ import annotations

import jax

from .kernel import selective_scan


def selective_scan_op(u, dt, A, Bc, Cc, h0, **kw):
    kw.setdefault("interpret", jax.default_backend() == "cpu")
    return selective_scan(u, dt, A, Bc, Cc, h0, **kw)
