"""Pure-jnp oracle: Mamba-1 selective-scan chunk step (sequential)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, A, Bc, Cc, h0):
    """Sequential recurrence.

    u, dt: [T, Di]; A: [Di, N]; Bc, Cc: [T, N]; h0: [Di, N].
    Returns (y [T, Di] f32, h_T [Di, N] f32).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) outer B_t
    y_t = h_t . C_t
    """
    u = u.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        da = jnp.exp(dt_t[:, None] * A)
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = (h * c_t[None, :]).sum(-1)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), (u, dt, Bc, Cc))
    return ys, h
