"""Pallas TPU kernel: Mamba-1 selective-scan chunk step.

Grid ``(B, Di // bd)`` — channels are embarrassingly parallel (the TP axis of
``repro.models.ssm``).  Each program holds its ``[bd, N]`` state slab in VMEM
and walks the chunk sequentially with ``fori_loop`` (N = 16, so a step is a
pure VPU broadcast-multiply-add; the HBM traffic is just u/dt/B/C streams —
this is the memory-roofline-optimal layout for the recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                 y_ref, h_ref, *, T: int):
    A = a_ref[0]                                     # [bd, N]
    h = h0_ref[0].astype(jnp.float32)                # [bd, N]

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)      # [bd]
        u_t = u_ref[0, t].astype(jnp.float32)        # [bd]
        b_t = b_ref[0, t].astype(jnp.float32)        # [N]
        c_t = c_ref[0, t].astype(jnp.float32)        # [N]
        da = jnp.exp(dt_t[:, None] * A)
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h * c_t[None, :]).sum(-1)
        return h

    h = jax.lax.fori_loop(0, T, step, h)
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def selective_scan(u, dt, A, Bc, Cc, h0, bd: int = 256,
                   interpret: bool = False):
    """Batched chunk scan.

    u, dt: [B, T, Di]; A: [Di, N]; Bc, Cc: [B, T, N]; h0: [B, Di, N].
    Returns (y [B, T, Di] f32, h_T [B, Di, N] f32).
    """
    B, T, Di = u.shape
    N = A.shape[1]
    bd = min(bd, Di)
    assert Di % bd == 0
    nd = Di // bd
    grid = (B, nd)

    y, h = pl.pallas_call(
        functools.partial(_scan_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),   # u
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),   # dt
            pl.BlockSpec((1, bd, N), lambda b, d: (0, d, 0)),   # A (shared)
            pl.BlockSpec((1, T, N), lambda b, d: (b, 0, 0)),    # B
            pl.BlockSpec((1, T, N), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((1, bd, N), lambda b, d: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, bd, N), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Di), jnp.float32),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        interpret=interpret,
    )(u, dt, A[None], Bc, Cc, h0)
    return y, h
