"""Pallas TPU kernel: tropical (min-plus) matmul.

Hardware adaptation (DESIGN.md): min-plus has no MXU form — it is a VPU
reduction.  The kernel tiles C into ``bm x bn`` VMEM blocks, iterates the K
dimension as the minor-most (sequential) grid axis, and inside each step
reduces a ``bk``-deep slab with an unrolled VPU ``minimum`` loop over
broadcast row+col sums.  Block sizes are multiples of (8, 128) to keep VREG
lanes full; the running min lives in the output block across K steps
(revisiting pattern, legal because the minor grid axis is sequential on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 1e9


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int, k_chunk: int):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[...]                      # [bm, bk]
    b = b_ref[...]                      # [bk, bn]
    acc = o_ref[...]
    # VPU reduction: process k_chunk rows of b at a time
    for k0 in range(0, bk, k_chunk):
        blk = jnp.min(a[:, k0:k0 + k_chunk, None]
                      + b[None, k0:k0 + k_chunk, :], axis=1)
        acc = jnp.minimum(acc, blk)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "k_chunk",
                                             "interpret"))
def minplus(a, b, bm: int = 128, bn: int = 128, bk: int = 128,
            k_chunk: int = 8, interpret: bool = False):
    """Tropical matmul C = A (min,+) B with BlockSpec VMEM tiling."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    a = jnp.pad(a, ((0, pm), (0, pk)), constant_values=INF)
    b = jnp.pad(b, ((0, pk), (0, pn)), constant_values=INF)
    M, K = a.shape
    _, N = b.shape
    grid = (M // bm, N // bn, K // bk)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
