"""Public ops: device-resident all-pairs distances via min-plus powering."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import minplus
from .ref import adjacency_matrix, minplus_ref, INF


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def minplus_op(a, b, **kw):
    kw.setdefault("interpret", _auto_interpret())
    return minplus(a, b, **kw)


def all_pairs_distances(nbrs, n_iters: int | None = None, **kw):
    """Hop distances between all switch pairs by repeated squaring.

    ``nbrs``: padded neighbor array [N, P] (as in ``core.Topology``).
    ``n_iters``: number of squarings (default: enough for diameter <= 2^n).
    Returns float32 [N, N] (INF = unreachable).
    """
    adj = adjacency_matrix(nbrs)
    it = n_iters if n_iters is not None else 5      # diameter <= 32
    d = adj
    for _ in range(it):
        d = minplus_op(d, d, **kw)
    return d
