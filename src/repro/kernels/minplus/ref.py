"""Pure-jnp oracle for tropical (min-plus) matrix multiplication.

``C[i, j] = min_k (A[i, k] + B[k, j])`` — the inner product of the
(min, +) semiring.  Powering the (hop-weighted) adjacency matrix under this
product yields all-pairs shortest-path distances: the TPU-native form of the
paper's distance-table computation (Section 4.3 needs d(x, leaf) tables for
Polarized routing; the CPU path is frontier BFS in ``repro.core.routing``).
"""
from __future__ import annotations

import jax.numpy as jnp

INF = 1e9


def minplus_ref(a, b):
    """a: [M, K]; b: [K, N] -> [M, N] under (min, +)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def adjacency_matrix(nbrs, inf: float = INF):
    """Padded neighbor array [N, P] -> dense weighted adjacency [N, N]."""
    import numpy as np
    n = nbrs.shape[0]
    m = np.full((n, n), inf, np.float32)
    np.fill_diagonal(m, 0.0)
    for i in range(n):
        for j in nbrs[i]:
            if j >= 0:
                m[i, j] = 1.0
    return jnp.asarray(m)


def all_pairs_ref(adj, max_pow: int = 16):
    """Repeated min-plus squaring to the shortest-path fixpoint."""
    d = adj
    for _ in range(max_pow):
        nd = minplus_ref(d, d)
        if bool(jnp.all(nd == d)):
            break
        d = nd
    return d
