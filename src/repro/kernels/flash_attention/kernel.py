"""Pallas TPU kernel: flash attention (fwd) with GQA and causal block skip.

Grid ``(B, H, nq, nk)`` with the KV axis minor-most (sequential on TPU).
Running (m, l) statistics live in SMEM-adjacent VMEM scratch; the f32
accumulator is VMEM scratch written back as bf16 at the last KV step.
Causal masking skips fully-masked KV tiles with ``pl.when`` — the tile never
leaves HBM on a real TPU since the index map still addresses it, but no
compute or accumulation happens (the XLA-level baseline cannot skip at all;
see EXPERIMENTS.md §Perf).  The GQA index map points ``g`` consecutive query
heads at the same KV head, so KV tiles are fetched once per KV head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  nk: int, seq_q: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: KV tile strictly above the diagonal does nothing
    q0 = qi * bq + (seq_kv - seq_q)
    k0 = ki * bk
    live = (not causal) or (k0 <= q0 + bq - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                                   # [bq, D]
        k = k_ref[0, 0]                                   # [bk, D]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: bool = False):
    """q: [B,Sq,H,D]; k,v: [B,Skv,Hkv,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(D)

    qt = q.transpose(0, 2, 1, 3)        # [B,H,Sq,D]
    kt = k.transpose(0, 2, 1, 3)        # [B,Hkv,Skv,D]
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk, seq_q=Sq, seq_kv=Skv),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running sum l
            pltpu.VMEM((bq, D), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
