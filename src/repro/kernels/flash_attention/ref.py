"""Pure-jnp oracle: causal/bidirectional (G)QA attention."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, causal: bool = True, window=None):
    """q: [B,Sq,H,D]; k,v: [B,Skv,Hkv,D]; returns [B,Sq,H,D] (f32 math)."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    qi = jnp.arange(Sq)[:, None] + (Skv - Sq)
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - (window + 1)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
