"""Public op: flash attention with CPU-interpret fallback."""
from __future__ import annotations

import jax

from .kernel import flash_attention
from .ref import attention_ref


def flash_attention_op(q, k, v, causal: bool = True, **kw):
    kw.setdefault("interpret", jax.default_backend() == "cpu")
    return flash_attention(q, k, v, causal=causal, **kw)
