"""Deterministic synthetic LM data pipeline with host sharding + prefetch.

Real-cluster layout: each host generates only its shard of the global batch
(``host_id / n_hosts``) and assembles a globally-sharded array; here a
single process plays all hosts.  The stream is a counter-based hash
(splitmix64) -> reproducible anywhere, no filesystem dependency, and
restart-safe: the cursor is part of the checkpoint, so a restored job
replays exactly the batches it would have seen (see
``runtime.fault_tolerance``).

A background thread prefetches ``prefetch`` batches ahead of the consumer.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from ..parallel.sharding import Sharder


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2
    markov_order: bool = True   # learnable structure (not pure noise)


class SyntheticLM:
    """Counter-based token stream; ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig, sh: Optional[Sharder] = None):
        self.cfg = cfg
        self.sh = sh

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        idx = (np.uint64(step) * np.uint64(c.global_batch * (c.seq + 1))
               + np.arange(c.global_batch * (c.seq + 1), dtype=np.uint64)
               + np.uint64(c.seed) * np.uint64(0x2545F4914F6CDD1D))
        h = _splitmix64(idx).reshape(c.global_batch, c.seq + 1)
        toks = (h % np.uint64(c.vocab)).astype(np.int32)
        if c.markov_order:
            # overwrite odd positions with a deterministic function of the
            # previous token -> the LM has something to learn.
            prev = toks[:, :-1]
            succ = ((prev.astype(np.int64) * 31 + 7) % self.cfg.vocab
                    ).astype(np.int32)
            toks[:, 1::2] = succ[:, ::2][:, : toks[:, 1::2].shape[1]]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.sh is not None:
            shd = self.sh.sharding(("dp", None), batch["tokens"].shape)
            batch = {k: jax.device_put(v, shd) for k, v in batch.items()}
        return batch

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        """Prefetching iterator starting at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
