"""Workload-program IR: a declarative multi-phase traffic program.

A :class:`WorkloadProgram` is the intermediate representation every
collective lowers to before execution: ``n_phases`` rows of per-endpoint
``partner`` / ``packets`` arrays.  Phase ``p`` means "endpoint ``e`` sends
``packets[p, e]`` packets to ``partner[p, e]``" — self-partnered endpoints
(``partner[p, e] == e``) model ranks idle in that phase; their packets are
delivered by the same-leaf local fast path and still count toward the
phase's ejection target (the completion semantics the engine measures).

The IR is deliberately execution-agnostic: *when* phase ``p+1`` may start
relative to phase ``p`` is a property of the compiled schedule
(:func:`repro.workloads.compile.compile_program`), not of the program.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WorkloadProgram"]


@dataclasses.dataclass(frozen=True)
class WorkloadProgram:
    """``n_phases`` rows of per-endpoint destinations and message sizes.

    * ``partner``  — int32 ``[n_phases, S]``, destination endpoint ids.
    * ``packets``  — int32 ``[n_phases, S]``, per-endpoint message sizes
      (``0`` = endpoint silent in that phase).
    """

    name: str
    partner: np.ndarray
    packets: np.ndarray

    def __post_init__(self):
        partner = np.ascontiguousarray(np.asarray(self.partner, np.int32))
        packets = np.ascontiguousarray(np.asarray(self.packets, np.int32))
        object.__setattr__(self, "partner", partner)
        object.__setattr__(self, "packets", packets)
        self.validate()

    # ------------------------------------------------------------------ #
    @property
    def n_phases(self) -> int:
        return self.partner.shape[0]

    @property
    def n_endpoints(self) -> int:
        return self.partner.shape[1]

    def expected(self) -> np.ndarray:
        """Per-phase ejection target: every packet of the phase delivered
        (network *and* local fast-path deliveries both count)."""
        return self.packets.sum(axis=1, dtype=np.int64)

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        if self.partner.ndim != 2:
            raise ValueError(f"partner must be [n_phases, S], got shape "
                             f"{self.partner.shape}")
        if self.packets.shape != self.partner.shape:
            raise ValueError(
                f"packets shape {self.packets.shape} != partner shape "
                f"{self.partner.shape}")
        n_phases, S = self.partner.shape
        if n_phases < 1:
            raise ValueError("program needs at least one phase")
        if (self.partner < 0).any() or (self.partner >= S).any():
            raise ValueError("partner ids must lie in [0, S)")
        if (self.packets < 0).any():
            raise ValueError("packets must be >= 0")
        exp = self.expected()
        if (exp < 1).any():
            empty = int(np.argmin(exp))
            raise ValueError(
                f"phase {empty} sends no packets; an empty phase would "
                "complete instantly and desynchronize the phase scheduler")
