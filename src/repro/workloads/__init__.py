"""Declarative workload programs: a collective/traffic IR, its compiler,
and the shared pattern registry.

Three layers (see docs/DESIGN.md "Workload programs"):

* **IR** — :class:`WorkloadProgram`: ``[n_phases, S]`` per-endpoint
  ``partner`` / ``packets`` arrays, execution-agnostic.
* **Compiler** — :func:`compile_program` lowers a program plus a
  dependency schedule (``barrier`` or ``window=W``) to the device arrays
  the engine's on-device phase scheduler consumes
  (:class:`CompiledProgram`).
* **Library** — :mod:`repro.workloads.programs` builds the standard
  collectives (shifted-exchange all2all, Rabenseifner / ring /
  recursive-doubling allreduce); :mod:`repro.workloads.patterns` is the
  single pattern-name registry shared by ``WorkloadSpec`` and the engine.

This package never imports the engine: programs are compiled to plain
device arrays and handed to ``Simulator.run_program``.
"""
from .compile import CompiledProgram, compile_program
from .ir import WorkloadProgram
from .patterns import (BERNOULLI_PATTERNS, COLLECTIVE_PATTERNS, SCHEDULES,
                       check_pattern, check_schedule, pattern_kinds,
                       register_pattern)
from .programs import (PROGRAM_BUILDERS, all2all_program,
                       build_collective_program, rabenseifner_program,
                       rd_allreduce_program, register_program_builder,
                       ring_allreduce_program)

__all__ = [
    "WorkloadProgram", "CompiledProgram", "compile_program",
    "BERNOULLI_PATTERNS", "COLLECTIVE_PATTERNS", "SCHEDULES",
    "check_pattern", "check_schedule", "pattern_kinds", "register_pattern",
    "PROGRAM_BUILDERS", "register_program_builder",
    "build_collective_program",
    "all2all_program", "rabenseifner_program", "ring_allreduce_program",
    "rd_allreduce_program",
]
