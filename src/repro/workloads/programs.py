"""Library of :class:`WorkloadProgram` builders.

Collective schedules lower rank-level phase lists
(:mod:`repro.core.collectives`) onto endpoint-level programs: ranks map
identity onto the first ``ranks`` endpoints, every remaining endpoint is
self-partnered (local fast-path delivery) with the same per-phase message
size — exactly the layout the legacy host loop patched into
``st["partner"]``, so the barrier schedule reproduces it bitwise.

``PROGRAM_BUILDERS`` is the registry the declarative layer dispatches
through; :func:`register_program_builder` adds a new collective in one
call (builder + pattern name), making it reachable from ``WorkloadSpec``
(``pattern=<name>``) and the runner without touching any other list.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from ..core.collectives import (recursive_doubling_phases,
                                rabenseifner_phases, ring_allreduce_phases)
from .ir import WorkloadProgram
from .patterns import pattern_kinds, register_pattern

__all__ = [
    "all2all_program",
    "rabenseifner_program",
    "ring_allreduce_program",
    "rd_allreduce_program",
    "PROGRAM_BUILDERS",
    "register_program_builder",
    "build_collective_program",
]


def all2all_program(S: int, rounds: int) -> WorkloadProgram:
    """Shifted-exchange All2All: phase ``r`` pairs ``e`` with
    ``(e + r + 1) mod S``, one packet per phase.  Compiled with
    ``schedule="window"`` this is the pipelined All2All (endpoints run up
    to ``window`` rounds ahead of the globally-completed round)."""
    if rounds < 1:
        raise ValueError(f"all2all needs rounds >= 1, got {rounds}")
    if S < 2:
        raise ValueError("all2all needs at least 2 endpoints")
    e = np.arange(S, dtype=np.int64)
    partner = np.stack([(e + r + 1) % S for r in range(rounds)], axis=0)
    return WorkloadProgram(name=f"all2all[{rounds}r]", partner=partner,
                           packets=np.ones((rounds, S), np.int32))


def _rank_phases_to_program(name: str, phases: list, S: int,
                            ranks: int) -> WorkloadProgram:
    if ranks > S:
        raise ValueError(f"{name}: ranks {ranks} > endpoints {S}")
    partner = np.tile(np.arange(S, dtype=np.int64), (len(phases), 1))
    packets = np.empty((len(phases), S), np.int64)
    for p, ph in enumerate(phases):
        partner[p, :ranks] = ph["partner"]
        packets[p, :] = ph["packets"]
    return WorkloadProgram(name=name, partner=partner, packets=packets)


def rabenseifner_program(S: int, ranks: int,
                         vec_packets: int) -> WorkloadProgram:
    """Rabenseifner Allreduce (recursive-halving reduce-scatter +
    recursive-doubling all-gather) over ``ranks`` power-of-two ranks."""
    return _rank_phases_to_program(
        f"rabenseifner[{ranks}x{vec_packets}]",
        rabenseifner_phases(ranks, vec_packets), S, ranks)


def ring_allreduce_program(S: int, ranks: int,
                           vec_packets: int) -> WorkloadProgram:
    """Ring Allreduce: ``2 * (ranks - 1)`` next-neighbour chunk shifts."""
    return _rank_phases_to_program(
        f"ring_allreduce[{ranks}x{vec_packets}]",
        ring_allreduce_phases(ranks, vec_packets), S, ranks)


def rd_allreduce_program(S: int, ranks: int,
                         vec_packets: int) -> WorkloadProgram:
    """Recursive-doubling Allreduce: ``log2(ranks)`` full-vector XOR
    exchanges."""
    return _rank_phases_to_program(
        f"rd_allreduce[{ranks}x{vec_packets}]",
        recursive_doubling_phases(ranks, vec_packets), S, ranks)


# ---------------------------------------------------------------------- #
# collective-pattern -> program dispatch (the workloads registry)
# ---------------------------------------------------------------------- #
def _build_all2all(S: int, *, rounds: int = 0, **_kw) -> WorkloadProgram:
    return all2all_program(S, rounds)


def _build_allreduce(builder: Callable) -> Callable:
    def build(S: int, *, ranks: int = 0, vec_packets: int = 16,
              **_kw) -> WorkloadProgram:
        n = ranks or 1 << (S.bit_length() - 1)
        return builder(S, n, vec_packets)
    return build


PROGRAM_BUILDERS: Dict[str, Callable[..., WorkloadProgram]] = {
    "all2all": _build_all2all,
    "allreduce": _build_allreduce(rabenseifner_program),
    "ring_allreduce": _build_allreduce(ring_allreduce_program),
    "rd_allreduce": _build_allreduce(rd_allreduce_program),
}


def register_program_builder(name: str,
                             builder: Callable[..., WorkloadProgram],
                             *, overwrite: bool = False) -> None:
    """Register a custom collective: ``builder(S, **spec_knobs)`` must
    return a :class:`WorkloadProgram` (it receives ``rounds`` / ``ranks``
    / ``vec_packets`` as keyword arguments; accept ``**_kw`` for the
    ones it ignores).  The pattern name becomes valid ``WorkloadSpec``
    vocabulary and the runner executes it device-resident like the
    built-in collectives."""
    if name in PROGRAM_BUILDERS and not overwrite:
        raise ValueError(f"program builder {name!r} already registered")
    existing = pattern_kinds().get(name)
    if existing not in (None, "collective"):
        raise ValueError(f"pattern {name!r} is already registered as "
                         f"{existing!r}")
    register_pattern(name, "collective", overwrite=existing == "collective")
    PROGRAM_BUILDERS[name] = builder


def build_collective_program(pattern: str, S: int,
                             **params) -> WorkloadProgram:
    """Resolve a collective pattern name and build its program for ``S``
    endpoints.  ``params`` are the pattern's knobs (``rounds`` for
    all2all; ``ranks`` / ``vec_packets`` for the allreduce family)."""
    try:
        builder = PROGRAM_BUILDERS[pattern]
    except KeyError:
        raise KeyError(
            f"no program builder for pattern {pattern!r}; known: "
            f"{tuple(sorted(PROGRAM_BUILDERS))}") from None
    return builder(S, **params)
