"""The single registry of workload-pattern names.

Both the declarative layer (:class:`repro.api.specs.WorkloadSpec`) and the
engine (:class:`repro.simulator.engine.Traffic`) validate pattern names
against this module, so a typo'd pattern raises the same error everywhere
instead of silently injecting nothing.

Kinds:

* ``bernoulli``  — open-loop load-driven injection, measured with the
  throughput / latency metrics.  Includes the adversarial families
  (``tornado`` / ``shift`` permutations, ``hotspot`` incast, ``bursty``
  on-off Markov) used to stress non-minimal routing.
* ``collective`` — finite programs measured to completion.  All but the
  legacy free-running ``all2all`` compile to a
  :class:`repro.workloads.WorkloadProgram` and execute device-resident.
* ``engine``     — raw simulator-level patterns (``phase``, ``program``)
  that the spec layer reaches only through a collective pattern.
"""
from __future__ import annotations

from typing import Mapping

__all__ = [
    "BERNOULLI_PATTERNS",
    "COLLECTIVE_PATTERNS",
    "ENGINE_ONLY_PATTERNS",
    "SCHEDULES",
    "pattern_kinds",
    "check_pattern",
    "check_schedule",
]

# open-loop Bernoulli injection (drawn fresh each slot, driven by ``load``)
BERNOULLI_PATTERNS = ("uniform", "rep", "rsp", "bu", "mice_elephant",
                      "tornado", "shift", "hotspot", "bursty")
# finite programs measured to completion
COLLECTIVE_PATTERNS = ("all2all", "allreduce", "ring_allreduce",
                       "rd_allreduce")
# engine-level patterns the spec layer never names directly:
# ``phase``   — one hand-patched partner exchange (legacy host-loop idiom)
# ``program`` — a compiled multi-phase WorkloadProgram (device scheduler)
ENGINE_ONLY_PATTERNS = ("phase", "program")

# collective execution schedules ("" = per-pattern default)
SCHEDULES = ("", "barrier", "window")

# mutable: registered collectives (register_pattern, called by
# repro.workloads.programs.register_program_builder) join the built-ins
_KINDS = (
    {p: "bernoulli" for p in BERNOULLI_PATTERNS}
    | {p: "collective" for p in COLLECTIVE_PATTERNS}
    | {p: "engine" for p in ENGINE_ONLY_PATTERNS}
)


def pattern_kinds() -> Mapping[str, str]:
    """``{pattern name: kind}`` for every registered pattern."""
    return dict(_KINDS)


def register_pattern(name: str, kind: str = "collective",
                     *, overwrite: bool = False) -> None:
    """Register a new pattern name.  Spec-level collectives additionally
    need a program builder (use
    :func:`repro.workloads.programs.register_program_builder`, which calls
    this)."""
    if kind not in ("bernoulli", "collective", "engine"):
        raise ValueError(f"unknown pattern kind {kind!r}")
    if name in _KINDS and not overwrite:
        raise ValueError(f"pattern {name!r} already registered "
                         f"({_KINDS[name]})")
    _KINDS[name] = kind


def _spec_names() -> tuple:
    return tuple(sorted(n for n, k in _KINDS.items() if k != "engine"))


def _engine_names() -> tuple:
    return tuple(sorted(n for n, k in _KINDS.items()
                        if k != "collective" or n == "all2all"))


def check_pattern(name: str, *, engine: bool = False) -> str:
    """Validate ``name`` against the registry and return its kind.

    ``engine=True`` accepts what the raw simulator ``Traffic`` executes
    (Bernoulli families + ``all2all`` + the engine-only patterns —
    registered collectives reach the engine as compiled
    ``Traffic("program")`` runs, never by name);
    ``engine=False`` accepts what a ``WorkloadSpec`` may declare
    (Bernoulli + collectives, including registered ones).
    """
    kind = _KINDS.get(name)
    ok = (kind == "bernoulli"
          or (engine and (kind == "engine" or name == "all2all"))
          or (not engine and kind == "collective"))
    if not ok:
        known = _engine_names() if engine else _spec_names()
        hint = ""
        if not engine and kind == "engine":
            hint = (" (engine-only pattern: reach it via a collective such "
                    "as pattern='allreduce')")
        raise ValueError(f"unknown pattern {name!r}; expected one of "
                         f"{known}{hint}")
    return kind


def check_schedule(schedule: str, window: int) -> None:
    """Validate a collective ``schedule``/``window`` pair."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{SCHEDULES}")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if window != 1 and schedule != "window":
        raise ValueError(
            f"window={window} requires schedule='window' (got "
            f"schedule={schedule!r})")
