"""The single registry of workload-pattern names.

Both the declarative layer (:class:`repro.api.specs.WorkloadSpec`) and the
engine (:class:`repro.simulator.engine.Traffic`) validate pattern names
against this module, so a typo'd pattern raises the same error everywhere
instead of silently injecting nothing.

Kinds:

* ``bernoulli``  — open-loop load-driven injection, measured with the
  throughput / latency metrics.  Includes the adversarial families
  (``tornado`` / ``shift`` permutations, ``hotspot`` incast, ``bursty``
  on-off Markov) used to stress non-minimal routing.
* ``collective`` — finite programs measured to completion.  All but the
  legacy free-running ``all2all`` compile to a
  :class:`repro.workloads.WorkloadProgram` and execute device-resident.
* ``arrival``    — open-loop serving traffic (``poisson`` / ``pareto`` /
  ``diurnal`` arrival processes, driven by an offered ``load``): the
  injection source queues request batches per endpoint instead of
  regenerating Bernoulli draws, so latency includes source queueing and
  delivered throughput can fall below offered load.  Measured with the
  ``serving`` metric.  The engine reaches these as
  ``Traffic("arrival", process=<name>)``, never by family name.
* ``engine``     — raw simulator-level patterns (``phase``, ``program``,
  ``arrival``) that the spec layer reaches only through a collective or
  arrival pattern.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np

__all__ = [
    "BERNOULLI_PATTERNS",
    "COLLECTIVE_PATTERNS",
    "ARRIVAL_PATTERNS",
    "ENGINE_ONLY_PATTERNS",
    "SCHEDULES",
    "pattern_kinds",
    "check_pattern",
    "check_schedule",
    "check_arrival",
    "bounded_pareto_mean",
]

# open-loop Bernoulli injection (drawn fresh each slot, driven by ``load``)
BERNOULLI_PATTERNS = ("uniform", "rep", "rsp", "bu", "mice_elephant",
                      "tornado", "shift", "hotspot", "bursty")
# finite programs measured to completion
COLLECTIVE_PATTERNS = ("all2all", "allreduce", "ring_allreduce",
                       "rd_allreduce")
# open-loop arrival processes (serving traffic; engine pattern "arrival")
ARRIVAL_PATTERNS = ("poisson", "pareto", "diurnal")
# engine-level patterns the spec layer never names directly:
# ``phase``   — one hand-patched partner exchange (legacy host-loop idiom)
# ``program`` — a compiled multi-phase WorkloadProgram (device scheduler)
# ``arrival`` — the open-loop source (process name rides in Traffic.process)
ENGINE_ONLY_PATTERNS = ("phase", "program", "arrival")

# collective execution schedules ("" = per-pattern default)
SCHEDULES = ("", "barrier", "window")

# mutable: registered collectives (register_pattern, called by
# repro.workloads.programs.register_program_builder) join the built-ins
_KINDS = (
    {p: "bernoulli" for p in BERNOULLI_PATTERNS}
    | {p: "collective" for p in COLLECTIVE_PATTERNS}
    | {p: "arrival" for p in ARRIVAL_PATTERNS}
    | {p: "engine" for p in ENGINE_ONLY_PATTERNS}
)


def pattern_kinds() -> Mapping[str, str]:
    """``{pattern name: kind}`` for every registered pattern."""
    return dict(_KINDS)


def register_pattern(name: str, kind: str = "collective",
                     *, overwrite: bool = False) -> None:
    """Register a new pattern name.  Spec-level collectives additionally
    need a program builder (use
    :func:`repro.workloads.programs.register_program_builder`, which calls
    this)."""
    if kind not in ("bernoulli", "collective", "arrival", "engine"):
        raise ValueError(f"unknown pattern kind {kind!r}")
    if name in _KINDS and not overwrite:
        raise ValueError(f"pattern {name!r} already registered "
                         f"({_KINDS[name]})")
    _KINDS[name] = kind


def _spec_names() -> tuple:
    return tuple(sorted(n for n, k in _KINDS.items() if k != "engine"))


def _engine_names() -> tuple:
    return tuple(sorted(n for n, k in _KINDS.items()
                        if k in ("bernoulli", "engine") or n == "all2all"))


def check_pattern(name: str, *, engine: bool = False) -> str:
    """Validate ``name`` against the registry and return its kind.

    ``engine=True`` accepts what the raw simulator ``Traffic`` executes
    (Bernoulli families + ``all2all`` + the engine-only patterns —
    registered collectives reach the engine as compiled
    ``Traffic("program")`` runs, and arrival families as
    ``Traffic("arrival", process=<name>)``, never by family name);
    ``engine=False`` accepts what a ``WorkloadSpec`` may declare
    (Bernoulli + arrival families + collectives, including registered
    ones).
    """
    kind = _KINDS.get(name)
    ok = (kind == "bernoulli"
          or (engine and (kind == "engine" or name == "all2all"))
          or (not engine and kind in ("collective", "arrival")))
    if not ok:
        known = _engine_names() if engine else _spec_names()
        hint = ""
        if not engine and kind == "engine":
            hint = (" (engine-only pattern: reach it via a collective such "
                    "as pattern='allreduce')")
        if engine and kind == "arrival":
            hint = (" (arrival family: the engine runs it as "
                    f"Traffic('arrival', process={name!r}))")
        raise ValueError(f"unknown pattern {name!r}; expected one of "
                         f"{known}{hint}")
    return kind


def bounded_pareto_mean(alpha: float, cap: int) -> float:
    """Mean of ``floor(X)`` for ``X ~`` bounded Pareto(``alpha``) on
    ``[1, cap]`` — the exact discrete batch-size mean the arrival source
    divides the batch-arrival probability by, so the long-run offered
    load calibrates to the configured rate with no sampling bias."""
    if cap <= 1:
        return 1.0
    k = np.arange(1, cap + 1, dtype=np.float64)
    cdf = (1.0 - k ** -alpha) / (1.0 - float(cap) ** -alpha)
    pk = np.diff(np.concatenate([cdf, [1.0]]))     # P(floor(X) = k)
    return float((np.arange(1, cap + 1) * pk).sum())


def check_arrival(process: str, load: float, *, pareto_alpha: float = 1.5,
                  pareto_cap: int = 64, diurnal_amp: float = 0.5,
                  diurnal_period: int = 512, arr_depth: int = 8) -> None:
    """Reject degenerate arrival-process configs loudly (mirrors the
    hotspot/bursty validation): a silent clamp would make the offered
    load miscalibrate instead of erroring.  Shared by
    ``WorkloadSpec`` (spec layer) and the engine's ``make_state``."""
    if process not in ARRIVAL_PATTERNS:
        raise ValueError(f"unknown arrival process {process!r}; expected "
                         f"one of {ARRIVAL_PATTERNS}")
    if load <= 0:
        raise ValueError(f"arrival rate (load) must be > 0, got {load}")
    if arr_depth < 1:
        raise ValueError(f"arr_depth must be >= 1, got {arr_depth}")
    if process == "poisson" and load > 1.0:
        raise ValueError(
            f"poisson load {load} > 1 packet/slot/endpoint: the slotted "
            "source generates at most one arrival per endpoint per slot")
    if process == "pareto":
        if pareto_alpha <= 1.0:
            raise ValueError(
                f"pareto_alpha must be > 1 (alpha <= 1 has no finite "
                f"unbounded mean to calibrate against), got {pareto_alpha}")
        if pareto_cap < 1:
            raise ValueError(f"pareto_cap must be >= 1 packet, got "
                             f"{pareto_cap}")
        p_arr = load / bounded_pareto_mean(pareto_alpha, pareto_cap)
        if p_arr > 1.0:
            raise ValueError(
                f"pareto load {load} needs batch-arrival probability "
                f"{p_arr:.3f} > 1 (mean batch {load / p_arr:.2f} "
                "packets): unreachable — lower load or raise "
                "pareto_cap/alpha")
    if process == "diurnal":
        if diurnal_period < 2:
            raise ValueError(
                f"diurnal_period must be >= 2 slots, got {diurnal_period} "
                "(a shorter period cannot represent one modulation cycle)")
        if not 0.0 <= diurnal_amp <= 1.0:
            raise ValueError(f"diurnal_amp must be in [0, 1], got "
                             f"{diurnal_amp}")
        peak = load * (1.0 + diurnal_amp)
        if peak > 1.0:
            raise ValueError(
                f"diurnal peak rate {peak:.3f} > 1 packet/slot/endpoint: "
                "the slotted source would clip the crest and silently "
                "undershoot the offered load")


def check_schedule(schedule: str, window: int) -> None:
    """Validate a collective ``schedule``/``window`` pair."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{SCHEDULES}")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if window != 1 and schedule != "window":
        raise ValueError(
            f"window={window} requires schedule='window' (got "
            f"schedule={schedule!r})")
