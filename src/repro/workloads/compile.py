"""Compile a :class:`WorkloadProgram` to device-resident schedule arrays.

The compiled form is what the engine's on-device phase scheduler consumes
(see ``Traffic("program")`` in :mod:`repro.simulator.engine`):

* ``partner`` / ``packets``     — int32 ``[n_phases, S]`` device arrays,
  gathered row-wise (barrier) or element-wise (windowed) at inject;
* ``expected``                  — int32 ``[n_phases]`` per-phase ejection
  targets (``sum(packets[p])``), the phase-advance thresholds of the
  barrier schedule;
* ``expected_cum``              — the inclusive prefix sum, the thresholds
  of the windowed schedule (ejections are cumulative across overlapped
  phases, so phase ``p`` counts as complete once *total* deliveries reach
  ``expected_cum[p]``);
* ``schedule`` / ``window``     — the dependency mode.  ``barrier``
  replays the legacy host loop exactly (fresh per-phase state, bitwise
  parity-locked); ``window=W`` lets every endpoint run up to ``W`` phases
  ahead of the globally-completed phase count (pipelined rounds).

Two compilations of the same program always conserve total packets:
``expected_cum[-1]`` is schedule-independent.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .ir import WorkloadProgram
from .patterns import check_schedule

__all__ = ["CompiledProgram", "compile_program"]

_INT32_MAX = (1 << 31) - 1


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """Device-array form of a :class:`WorkloadProgram` plus its schedule."""

    name: str
    partner: jnp.ndarray        # [n_phases, S] int32
    packets: jnp.ndarray        # [n_phases, S] int32
    expected: jnp.ndarray       # [n_phases]    int32
    expected_cum: jnp.ndarray   # [n_phases]    int32
    n_phases: int
    n_endpoints: int
    schedule: str               # "barrier" | "window"
    window: int

    @property
    def total_packets(self) -> int:
        """Schedule-independent total (the conservation invariant)."""
        return int(self.expected_cum[-1])


def compile_program(program: WorkloadProgram, *, schedule: str = "barrier",
                    window: int = 1) -> CompiledProgram:
    """Lower ``program`` to device arrays under a dependency schedule."""
    check_schedule(schedule, window)
    if not schedule:
        schedule = "barrier"
    program.validate()
    expected = program.expected()                       # int64 [n_phases]
    cum = np.cumsum(expected)
    if int(cum[-1]) > _INT32_MAX:
        raise ValueError(
            f"program total of {int(cum[-1])} packets overflows the int32 "
            "ejection counter")
    return CompiledProgram(
        name=program.name,
        partner=jnp.asarray(program.partner, jnp.int32),
        packets=jnp.asarray(program.packets, jnp.int32),
        expected=jnp.asarray(expected, jnp.int32),
        expected_cum=jnp.asarray(cum, jnp.int32),
        n_phases=program.n_phases,
        n_endpoints=program.n_endpoints,
        schedule=schedule,
        window=window if schedule == "window" else 1,
    )
