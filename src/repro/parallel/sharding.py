"""Logical-axis sharding: maps logical axis names to mesh axes.

Logical axes used throughout the model code:

* ``fsdp``   — parameter/optimizer sharding over the data(-and-pod) axes
               (ZeRO-3 style: gathered on use by GSPMD).
* ``tp``     — tensor parallel over the ``model`` axis (heads / ffn / vocab /
               experts / kv-seq, depending on the tensor).
* ``dp``     — activation batch sharding over (pod, data).
* ``sp``     — sequence sharding (sequence parallelism / long-context decode).
* ``None``   — replicated.

The same model code therefore runs on the single-pod ``(data, model)`` mesh,
the multi-pod ``(pod, data, model)`` mesh, and the 1-device test mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Sharder", "ShardingRules"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical name -> mesh axis (or tuple of axes)."""
    fsdp: tuple = ("data",)
    dp: tuple = ("data",)
    tp: str = "model"
    sp: Optional[str] = None        # sequence-parallel axis (perf option)

    @staticmethod
    def for_mesh(mesh: Mesh, sequence_parallel: bool = False) -> "ShardingRules":
        axes = mesh.axis_names
        data_axes = tuple(a for a in ("pod", "data") if a in axes)
        return ShardingRules(
            fsdp=data_axes,
            dp=data_axes,
            tp="model" if "model" in axes else None,
            sp="model" if sequence_parallel and "model" in axes else None,
        )


class Sharder:
    """Resolves logical axis names against a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[ShardingRules] = None):
        self.mesh = mesh
        self.rules = rules or ShardingRules.for_mesh(mesh)

    def _resolve(self, name) -> Optional[object]:
        if name is None:
            return None
        if name == "fsdp":
            r = self.rules.fsdp
            return r if len(r) > 1 else (r[0] if r else None)
        if name == "dp":
            r = self.rules.dp
            return r if len(r) > 1 else (r[0] if r else None)
        if name == "tp":
            return self.rules.tp
        if name == "sp":
            return self.rules.sp
        raise ValueError(f"unknown logical axis {name!r}")

    def pspec(self, names: Sequence[Optional[str]]) -> P:
        return P(*[self._resolve(n) for n in names])

    def sharding(self, names: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        # If shape is given, logical axes whose mesh size does not divide
        # the dim are dropped (e.g. 8 KV heads on a 16-way TP axis ->
        # replicated KV projections, the standard GQA fallback).
        if shape is None:
            return NamedSharding(self.mesh, self.pspec(names))
        resolved = []
        for dim, n in zip(shape, names):
            ax = self._resolve(n)
            if ax is None:
                resolved.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= self.mesh.shape[a]
            resolved.append(ax if dim % size == 0 else None)
        return NamedSharding(self.mesh, P(*resolved))

    def constrain(self, x, *names):
        """with_sharding_constraint by logical names (no-op axes resolve to
        replicated)."""
        return jax.lax.with_sharding_constraint(x, self.sharding(names))

    # divisibility-aware helper: drop shardings that don't divide the dim.
    def constrain_safe(self, x, *names):
        return jax.lax.with_sharding_constraint(
            x, self.sharding(names, x.shape))
