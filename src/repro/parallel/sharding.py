"""Logical-axis sharding: maps logical axis names to mesh axes.

Logical axes used throughout the model code:

* ``fsdp``   — parameter/optimizer sharding over the data(-and-pod) axes
               (ZeRO-3 style: gathered on use by GSPMD).
* ``tp``     — tensor parallel over the ``model`` axis (heads / ffn / vocab /
               experts / kv-seq, depending on the tensor).
* ``dp``     — activation batch sharding over (pod, data).
* ``sp``     — sequence sharding (sequence parallelism / long-context decode).
* ``None``   — replicated.

The same model code therefore runs on the single-pod ``(data, model)`` mesh,
the multi-pod ``(pod, data, model)`` mesh, and the 1-device test mesh.

The cycle-level simulator shares this resolver through its own profile
(:meth:`ShardingRules.for_sim_mesh` / :func:`make_sim_mesh`):

* ``replica`` — the vmapped replica batch of ``make_batch_state``; fully
               independent per entry, so ``Simulator.run_chunk_sharded``
               splits it over devices with ``jax.shard_map`` (zero
               cross-device traffic, bitwise-identical per replica).
* ``switch``  — the queue-major (switch-indexed) state dimension;
               ``Simulator.shard_state`` places those arrays with
               :class:`NamedSharding` and GSPMD partitions the jitted
               step (communication inserted at the link phase).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Sharder", "ShardingRules", "make_sim_mesh"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical name -> mesh axis (or tuple of axes)."""
    fsdp: tuple = ("data",)
    dp: tuple = ("data",)
    tp: Optional[str] = "model"
    sp: Optional[str] = None        # sequence-parallel axis (perf option)
    replica: Optional[str] = None   # simulator replica-batch axis
    switch: Optional[str] = None    # simulator queue-major (switch) axis

    @staticmethod
    def for_mesh(mesh: Mesh, sequence_parallel: bool = False) -> "ShardingRules":
        axes = mesh.axis_names
        data_axes = tuple(a for a in ("pod", "data") if a in axes)
        return ShardingRules(
            fsdp=data_axes,
            dp=data_axes,
            tp="model" if "model" in axes else None,
            sp="model" if sequence_parallel and "model" in axes else None,
        )

    @staticmethod
    def for_sim_mesh(mesh: Mesh) -> "ShardingRules":
        """The simulator profile: only the ``replica``/``switch`` axes
        resolve (model axes are absent from a simulator mesh, so the
        model-side names resolve to replicated instead of erroring)."""
        axes = mesh.axis_names
        return ShardingRules(
            fsdp=(), dp=(), tp=None, sp=None,
            replica="replica" if "replica" in axes else None,
            switch="switch" if "switch" in axes else None,
        )


def make_sim_mesh(n_devices: Optional[int] = None,
                  axis: str = "replica") -> Mesh:
    """A 1-D simulator mesh over ``axis`` (``"replica"`` | ``"switch"``)
    spanning ``n_devices`` local devices (default: all of them)."""
    devices = jax.devices()
    if n_devices is not None:
        if n_devices > len(devices):
            raise ValueError(f"asked for {n_devices} devices, have "
                             f"{len(devices)}")
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (axis,))


class Sharder:
    """Resolves logical axis names against a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[ShardingRules] = None):
        self.mesh = mesh
        self.rules = rules or ShardingRules.for_mesh(mesh)

    @classmethod
    def for_simulator(cls, mesh: Optional[Mesh] = None,
                      n_devices: Optional[int] = None,
                      axis: str = "replica") -> "Sharder":
        """The simulator profile: a :func:`make_sim_mesh` mesh (or a
        caller-built one) with :meth:`ShardingRules.for_sim_mesh` rules."""
        mesh = mesh if mesh is not None else make_sim_mesh(n_devices, axis)
        return cls(mesh, ShardingRules.for_sim_mesh(mesh))

    def _resolve(self, name) -> Optional[object]:
        if name is None:
            return None
        if name == "fsdp":
            r = self.rules.fsdp
            return r if len(r) > 1 else (r[0] if r else None)
        if name == "dp":
            r = self.rules.dp
            return r if len(r) > 1 else (r[0] if r else None)
        if name == "tp":
            return self.rules.tp
        if name == "sp":
            return self.rules.sp
        if name == "replica":
            return self.rules.replica
        if name == "switch":
            return self.rules.switch
        raise ValueError(f"unknown logical axis {name!r}")

    def pspec(self, names: Sequence[Optional[str]]) -> P:
        return P(*[self._resolve(n) for n in names])

    def sharding(self, names: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        # If shape is given, logical axes whose mesh size does not divide
        # the dim are dropped (e.g. 8 KV heads on a 16-way TP axis ->
        # replicated KV projections, the standard GQA fallback).
        if shape is None:
            return NamedSharding(self.mesh, self.pspec(names))
        resolved = []
        for dim, n in zip(shape, names):
            ax = self._resolve(n)
            if ax is None:
                resolved.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= self.mesh.shape[a]
            resolved.append(ax if dim % size == 0 else None)
        return NamedSharding(self.mesh, P(*resolved))

    def constrain(self, x, *names):
        """with_sharding_constraint by logical names (no-op axes resolve to
        replicated)."""
        return jax.lax.with_sharding_constraint(x, self.sharding(names))

    # divisibility-aware helper: drop shardings that don't divide the dim.
    def constrain_safe(self, x, *names):
        return jax.lax.with_sharding_constraint(
            x, self.sharding(names, x.shape))
