"""Quickstart: the paper's core objects in ~60 lines.

1. Build an MRLS, check Table-2-style metrics (Θ, costs, diameter).
2. Route a packet with Polarized routing (Theorem 4.2 bound).
3. Simulate uniform traffic and an All2All collective — declaratively,
   through ``repro.api`` (spec in, structured result out).
4. Spin a tiny LM from the framework and take one training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import exact_metrics, route_packet_host
from repro.api import (Experiment, NetworkSpec, RouteSpec, SimulatorCache,
                       WorkloadSpec, routing_tables, run)

# 1. an MRLS with 11052 endpoints — the paper's Table 2 headline row
big = NetworkSpec("mrls", {"n_leaves": 614, "u": 18, "d": 18, "seed": 1})
tables = routing_tables(big)
m = exact_metrics(tables.topo)
print(f"{m.name}: S={m.S} D={m.D} Θ={m.theta:.3f} "
      f"cost={m.cost_links:.1f} links/endpoint   (paper: Θ=0.748)")

# 2. Polarized routing between two leaves
rng = np.random.default_rng(0)
a, b = (int(x) for x in rng.choice(tables.topo.leaf_ids, 2, replace=False))
path = route_packet_host(tables, a, b, "polarized", max_hops=8, rng=rng)
print(f"polarized route {a}->{b}: {path}  (bound 2D*-2 = "
      f"{2 * tables.diameter_star - 2})")

# 3. simulate — small instance so this runs in seconds; the Experiment
#    spec replaces the old Simulator/SimConfig/Traffic hand-wiring and
#    JSON round-trips (try: python -m repro.api run <spec.json>)
small = NetworkSpec("mrls", {"n_leaves": 62, "u": 6, "d": 6, "seed": 1})
route = RouteSpec(policy="polarized", max_hops=8)
with SimulatorCache() as cache:  # both runs share one compiled simulator
    r = run(Experiment(network=small, route=route,
                       workload=WorkloadSpec("uniform", load=1.0),
                       warm=150, measure=200), cache=cache)
    small_topo = cache.get(small, route).tables.topo
    print(f"uniform saturation throughput: {r.throughput:.3f} flits/cycle "
          f"(Θ={exact_metrics(small_topo).theta:.3f})")
    r = run(Experiment(network=small, route=route,
                       workload=WorkloadSpec("all2all", rounds=8)),
            cache=cache)
    print(f"All2All (8 rounds): {r.slots} slots")

# 4. one train step of a reduced framework model
from repro.configs import REGISTRY, reduced
from repro.models.common import init_params
from repro.models.model import build_specs, loss_fn
from repro.launch.mesh import make_test_mesh
from repro.parallel.sharding import Sharder

cfg = reduced(REGISTRY["qwen3-1.7b"])
mesh = make_test_mesh()
sh = Sharder(mesh)
params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
with jax.set_mesh(mesh):
    loss = jax.jit(lambda p: loss_fn(p, {"tokens": tok, "labels": tok},
                                     cfg, sh))(params)
print(f"tiny {cfg.name}: initial loss {float(loss):.3f} "
      f"(ln V = {np.log(cfg.vocab):.3f})")
