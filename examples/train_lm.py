"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full production path: config -> mesh/sharder -> synthetic pipeline with
prefetch -> sharded AdamW -> fault-tolerant runner with async checkpoints.
The stream has deterministic Markov structure, so loss falls well below
ln(V) — convergence is asserted at the end.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_test_mesh
from repro.launch.train import build_training
from repro.models.model import ModelConfig
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.parallel.sharding import Sharder

# ~100M params: 12L x d768 x ffn 2048, vocab 32768
CFG_100M = ModelConfig(
    name="demo-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32768, act="swiglu", rope_theta=10_000.0,
    q_block=128, kv_block=128,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    args = ap.parse_args()

    print(f"demo-100m: {CFG_100M.param_count() / 1e6:.1f}M params")
    mesh = make_test_mesh()
    sh = Sharder(mesh)
    opt = AdamWConfig(lr=1e-3,
                      schedule=warmup_cosine(10, args.steps))
    data = SyntheticLM(DataConfig(CFG_100M.vocab, args.seq, args.batch), sh)

    with jax.set_mesh(mesh):
        state, runner, ckpt = build_training(
            CFG_100M, sh, opt, args.ckpt_dir, data)
        t0 = time.time()
        state, step, hist = runner.run(state, 0, args.steps)
    dt = time.time() - t0

    losses = [h["loss"] for h in hist]
    print(f"steps={step}  wall={dt:.0f}s  ({dt / step:.2f}s/step)")
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(ln V = {np.log(CFG_100M.vocab):.3f})")
    print(f"checkpoints: {ckpt.all_steps()}")
    drop = losses[0] - np.mean(losses[-10:])
    assert drop > 0.15, f"did not converge (drop={drop:.3f})"
    print(f"OK — loss fell by {drop:.2f} nats")


if __name__ == "__main__":
    main()
