"""Serving example: batched prefill + greedy decode with a KV cache.

Uses the reduced qwen3 config so it runs on CPU in seconds; the same
``ServeSession`` drives the full configs on real hardware.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import ServeSession
from repro.parallel.sharding import Sharder

cfg = reduced(REGISTRY["qwen3-1.7b"])
mesh = make_test_mesh()
sh = Sharder(mesh)
rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab, (4, 32), dtype=np.int32)

with jax.set_mesh(mesh):
    sess = ServeSession(cfg, sh)
    t0 = time.time()
    toks = sess.generate(prompts, max_new=12)
    dt = time.time() - t0

print(f"arch={cfg.name}  batch={prompts.shape[0]}  "
      f"prompt_len={prompts.shape[1]}  new_tokens={toks.shape[1]}")
print(f"wall {dt:.1f}s  ({dt / toks.size * 1000:.0f} ms/token incl. compile)")
for i, row in enumerate(toks):
    print(f"  request {i}: {row.tolist()}")
