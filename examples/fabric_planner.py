"""The paper's technique applied to THIS framework's own traffic.

Reads the multi-pod dry-run records (cross-pod collective byte volumes per
train step), models three candidate DCN fabrics with the paper's machinery
(MRLS / Fat-Tree / Dragonfly at matched cost), and reports per-fabric
communication time + the recommended pod-axis strategy.

This is the punchline of the reproduction: the MRLS paper's +50% All2All /
+100% vs Dragonfly advantage, measured in OUR framework's collective mix.

Run:  PYTHONPATH=src python examples/fabric_planner.py
(needs results/dryrun/*.json from `python -m repro.launch.dryrun --all`)
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.fabric.planner import plan_pod_axis, build_fabric, collective_time_s

DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

records = []
for path in sorted(glob.glob(os.path.join(DIR, "*_train_4k_2x16x16.json"))):
    rec = json.load(open(path))
    if rec.get("status") == "ok":
        records.append(rec)

if not records:
    print("no multi-pod dry-run records found — run the dry-run first")
    sys.exit(0)

print(f"{'arch':26s} {'comm bytes/dev':>14s} {'MRLS(s)':>9s} {'FT(s)':>9s} "
      f"{'DF(s)':>9s} {'best':>10s} {'compress':>9s}")
for rec in records:
    plan = plan_pod_axis(rec, n_pod_endpoints=512,
                         compute_s=rec["roofline"]["compute_s"])
    coll = sum(rec["per_device"]["collective_bytes"].values())
    est = plan.est_comm_s
    print(f"{rec['arch']:26s} {coll:14.3e} {est['mrls']:9.4f} "
          f"{est['fat_tree']:9.4f} {est['dragonfly']:9.4f} "
          f"{plan.recommended_fabric:>10s} "
          f"{'EF-int8' if plan.compress_gradients else 'no':>9s}")

print()
print("fabric models at 512 endpoints (per-NIC 400 Gb/s):")
for kind in ("mrls", "fat_tree", "dragonfly"):
    fab = build_fabric(kind, 512)
    t_a2a = collective_time_s(fab, "all2all", 1e9)
    print(f"  {kind:10s} Θ={fab.theta:5.3f} cost={fab.cost_links:.2f} "
          f"links/EP   1GB all2all: {t_a2a * 1000:.1f} ms")
