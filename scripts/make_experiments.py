"""Generate EXPERIMENTS.md from results/ artifacts.  Re-run any time:
  PYTHONPATH=src python scripts/make_experiments.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_records, table

ROOT = os.path.join(os.path.dirname(__file__), "..")
DIR = os.path.join(ROOT, "results", "dryrun")


def variant_records():
    out = []
    for path in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        name = os.path.basename(path)
        if "=" not in name and "_fused" not in name:
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        tag = name.replace(".json", "")
        out.append((tag, rec))
    return out


def fmt_rec(rec):
    r = rec["roofline"]
    return (f"compute {r['compute_s']:.3f}s · memory {r['memory_s']:.3f}s · "
            f"collective {r['collective_s']:.3f}s · bound **{r['bound_s']:.3f}s** "
            f"({r['dominant'][:-2]}) · useful {rec['useful_flops_ratio']:.3f} · "
            f"roofline frac **{rec['roofline_fraction']:.4f}**")


def get(tag):
    path = os.path.join(DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    return rec if rec.get("status") == "ok" else None


def dryrun_summary():
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skip"]
    err = [r for r in recs if r.get("status") not in ("ok", "skip")]
    rows = ["| arch | shape | mesh | compile_s | per-dev HLO flops | "
            "per-dev bytes | collective bytes | arg+temp GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in ok:
        pd = r["per_device"]
        ma = r.get("memory_analysis", {})
        gib = (ma.get("argument_bytes", 0) + ma.get("temp_bytes", 0)) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.0f} | {pd['flops']:.3e} | "
            f"{pd['bytes']:.3e} | "
            f"{sum(pd['collective_bytes'].values()):.3e} | {gib:.1f} |")
    return len(ok), len(skip), len(err), "\n".join(rows)


def bench_file(name):
    p = os.path.join(ROOT, "results", name)
    if os.path.exists(p):
        with open(p) as f:
            return f.read().strip()
    return "(not yet generated)"


n_ok, n_skip, n_err, dryrun_table = dryrun_summary()

PERF_SECTION = """## §Perf — hillclimbing log (hypothesis → change → before → after)

Three cells were selected from the baseline table per the methodology:
**worst roofline fraction + most collective-bound** (`hymba-1.5b × train_4k`),
**most representative of the paper's technique** (`deepseek-v3-671b ×
train_4k` — EP MoE whose cross-pod traffic is the paper's All2All class),
and the **best absolute candidate to push toward roofline**
(`command-r-plus-104b × train_4k`).  The paper-faithful jnp BASELINE rows
are recorded first (above); all deltas below are measured on re-lowered,
re-analyzed compiled HLO.

### Iteration 1 — Pallas-kernel cost model (all three cells)

*Hypothesis*: the baseline is memory-dominated by f32 attention score tiles
and SSM chunk states written to HBM by the XLA-level chunked implementations
(verified by top-contributor dump of the qwen HLO: `[16,512,1024]` f32
fusions × 896 trips).  The Pallas kernels (`repro.kernels.flash_attention`,
`selective_scan` — validated vs their jnp oracles in interpret mode) keep
tile interiors in VMEM; modeling their interiors as VMEM-resident
(`--fused`, keyed on the `flash_tile`/`ssm_chunk` named scopes) should
collapse the memory term by the tile traffic, leaving boundary q/k/v/o
streams.  Napkin: command-r attention tiles ≈ 33 s of the 63 s memory term.

| cell | before (bound) | after (bound) | verdict |
|---|---|---|---|
| command-r train_4k | {cr_base} | {cr_fused} | **confirmed** (memory 63.5→30.5 s) |
| hymba train_4k | {hy_base} | {hy_fused} | **confirmed** (memory 43.2→10.3 s; now collective-bound) |
| deepseek train_4k | {ds_base} | {ds_fused} | **confirmed** |

### Iteration 2 — Megatron-style sequence parallelism (command-r): **REFUTED**

*Hypothesis*: constraining the residual stream to seq-sharded over the TP
axis converts per-layer all-reduces (15.1 s) into reduce-scatter +
all-gather pairs → ~2× collective reduction.
*Result*: collective **exploded to 335 s** — GSPMD hits "involuntary full
rematerialization" at the q-block scan's `dynamic_slice` (it cannot reshard
a seq-sharded operand into the scan's block slicing and falls back to full
replication every block).  Lesson recorded: SP must be implemented at the
`shard_map` level (explicit ppermute halo), not via `with_sharding_constraint`
around an XLA-sliced scan; left as future work.
`{cr_sp}`

### Iteration 3 — remat policy `dots` (command-r, deepseek)

*Hypothesis*: full-layer remat recomputes the whole forward during backward
(useful-flops ratio 0.69-0.76); saving projection/MLP dot outputs
(`jax.checkpoint_policies.checkpoint_dots`, with the attention tile interior
still flash-recomputed by its inner checkpoint) trades ~1 GiB/layer of extra
saved activations for removing most recompute flops: compute term −25%,
memory term +saved-activation traffic.

| cell | before | after | verdict |
|---|---|---|---|
| command-r train_4k (fused) | {cr_fused} | {cr_dots} | {cr_dots_verdict} |
| deepseek train_4k (fused) | {ds_fused} | {ds_dots} | {ds_dots_verdict} |

### Iteration 4 — replicated attention for tiny-head archs (hymba)

*Hypothesis*: hymba's 25 heads force head_dim-TP, whose score-dot psums
dominate the collective term (24.2 s of f32[..,S,S]-class reductions).
Attention is <10% of hymba's flops — replicating it (TP only in
SSM/MLP/vocab) removes those psums at the cost of 16× attention compute
per device (+~1.2 s compute).

| cell | before | after | verdict |
|---|---|---|---|
| hymba train_4k (fused) | {hy_fused} | {hy_repl} | **confirmed**: collective 24.2→0.72 s, bound 24.2→11.0 s, fraction ×2.2 |

Follow-up idea logged (not yet implemented): reshard attention over
(data×model) batch instead of replicating — saves the 16× compute at the
price of two activation all-to-alls (~0.75 s) per layer pair.

### Final per-cell summary (baseline -> best variant)

| cell | baseline bound | best variant | bound | roofline frac | gain |
|---|---|---|---|---|---|
{summary_rows}

### Stopping criterion

Per cell, iterations stop when three consecutive candidates are <5% on the
dominant term; the matrix above plus the refuted SP row represents the
recorded search.  The **paper-faithful baseline** (pure-jnp XLA lowering)
and the **beyond-paper optimized** variants (Pallas kernel cost model +
remat/TP-layout changes) are both kept in `results/dryrun/` — baselines in
unsuffixed files, variants suffixed `_fused`/`_<override>`.
"""


def fill_perf():
    subs = {}
    m = {
        "cr_base": "command-r-plus-104b_train_4k_16x16",
        "cr_fused": "command-r-plus-104b_train_4k_16x16_fused",
        "cr_sp": "command-r-plus-104b_train_4k_16x16_seq_parallel=True_fused",
        "cr_dots": "command-r-plus-104b_train_4k_16x16_remat=dots_fused",
        "hy_base": "hymba-1.5b_train_4k_16x16",
        "hy_fused": "hymba-1.5b_train_4k_16x16_fused",
        "hy_repl": "hymba-1.5b_train_4k_16x16_attn_replicated=True_fused",
        "ds_base": "deepseek-v3-671b_train_4k_16x16",
        "ds_fused": "deepseek-v3-671b_train_4k_16x16_fused",
        "ds_dots": "deepseek-v3-671b_train_4k_16x16_remat=dots_fused",
    }
    for key, tag in m.items():
        rec = get(tag)
        subs[key] = fmt_rec(rec) if rec else "(pending)"
    best = {
        "command-r-plus-104b x train_4k":
            ("cr_base", "cr_fused", "fused (Pallas flash kernel)"),
        "deepseek-v3-671b x train_4k":
            ("ds_base", "ds_fused", "fused (Pallas flash kernel)"),
        "hymba-1.5b x train_4k":
            ("hy_base", "hy_repl", "fused + replicated attention"),
    }
    rows = []
    for cell, (b, a, label) in best.items():
        rb, ra = get(m[b]), get(m[a])
        if rb and ra:
            gain = ra["roofline_fraction"] / max(rb["roofline_fraction"], 1e-9)
            rows.append(
                f"| {cell} | {rb['roofline']['bound_s']:.2f}s "
                f"({rb['roofline_fraction']:.4f}) | {label} | "
                f"{ra['roofline']['bound_s']:.2f}s | "
                f"{ra['roofline_fraction']:.4f} | x{gain:.1f} |")
    subs["summary_rows"] = "\n".join(rows) or "(pending)"
    for k in ("cr_dots", "ds_dots"):
        base = get(m[k.replace("_dots", "_fused")])
        new = get(m[k])
        if base and new:
            subs[k + "_verdict"] = (
                "**confirmed**" if new["roofline_fraction"] >
                base["roofline_fraction"] else "**refuted** (bound did not improve)")
        else:
            subs[k + "_verdict"] = "(pending)"
    return PERF_SECTION.format(**subs)


DOC = f"""# EXPERIMENTS

All artifacts regenerable:
* dry-run cells: `bash scripts/dryrun_all.sh` → `results/dryrun/*.json`
* perf variants: `bash scripts/perf_iters2.sh`
* benchmarks: `PYTHONPATH=src python -m benchmarks.run` (add `--full` for
  paper-size simulator figures)
* this file: `PYTHONPATH=src python scripts/make_experiments.py`

Hardware model (TPU v5e-class target; container is CPU-only so nothing is
timed on silicon — see DESIGN.md): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
50 GB/s/ICI-link.  Meshes: single pod 16×16 (256 chips), multi-pod
2×16×16 (512 chips; "pod" axis crosses the MRLS-modeled DCN).

## §Repro — the paper's own claims

Table 2 reproduces essentially exactly (benchmarks/table2.py, full sizes):
every MRLS row matches the paper's Θ to 3 decimals (e.g. MRLS(36,11052)u18:
Θ=0.748/0.748; MRLS(36,104976)u27: Θ=1.561/1.561), OFT/FT/DF/DF+ costs and
diameters match; FT Θ computed exactly (paper rounds A≈D).

Fig. 3 thresholds (Appendix A implementation): D*≤3 boundary at S≈1.7K
(paper: ~2K), D*≤4 at ≈29K (paper: ~30K), D*≤7 supports >100M endpoints at
D=6 (paper's far-right claim).  `benchmarks/fig3_scalability.py`.

Simulator (CAMINOS-equivalent; deviations documented in DESIGN.md):
qualitative paper claims validated —
* **Fig. 7 headline reproduced**: MRLS completes All2All in 32 slots vs
  Dragonfly's 64 (+100% — the paper's exact claim) and matches DF+ latency,
  at equal link cost (`fig7.*` rows below).
* **Fig. 6 cost-proportionality** (Section 6.2): MRLS throughput scales
  with f — uniform 0.46 (f=1) → 0.99 (f=2) → 1.00 (f=3), and the f=2 MRLS
  matches the depopulated FT's uniform throughput at 2/3 the link cost
  (FT 0.723 at cost 3 vs MRLS-f2 0.995 at cost 2); the f=1 MRLS saturates
  under the 0.5-load latency test exactly as the paper reports.
* Polarized ≫ minimal under RSP on OFT (×2.6, tests/test_simulator.py);
  FT uniform ≈0.94; Polarized path lengths bounded by Theorem 4.2
  (hypothesis property test); Rabenseifner allreduce favors FT (2048 vs
  2560 slots) — the locality effect of Section 6.1.3.
* Note: at the scaled sizes the All2All differentiation vs FT needs the
  full-size run (both complete in 32 slots at 12 rounds); the 2x-vs-DF
  result is robust at every size.

Scaled + full-size figure runs:

### Scaled suite (benchmarks.run — full log in bench_output.txt)
```
{bench_file('../bench_output.txt')[:7000]}
```

### Full-size Fig.5 (11K endpoints) — exact paper networks
(regenerate with `python -m benchmarks.fig5_11k --full`; ~1 CPU-hour each —
partial results below were collected within this container's budget, the
scaled radix-12 family above covers every scenario end-to-end)
```
{bench_file('bench_fig5_full.txt')[:4000]}
```

### Full-size Fig.7 (16K endpoints, vs Dragonfly)
```
{bench_file('bench_fig7_full.txt')[:4000]}
```

### End-to-end training driver (examples/train_lm.py)
~126M-parameter LM, full production path (prefetching pipeline, sharded
AdamW, fault-tolerant runner, async checkpoints):
```
{bench_file('train_lm_run.txt')[:600]}
```
(the recorded run used the initial lr=3e-4 schedule — 0.08 nats in 200
steps on the 32K-vocab stream; the committed example uses lr=1e-3 and a
convergence assert, validated at small scale by
tests/test_system.py::test_train_loss_decreases which requires a 0.3-nat
drop in 50 steps.)

## §Dry-run — {n_ok} compiled cells ({n_skip} documented skips, {n_err} errors)

Every (architecture × shape × mesh) cell lowers **and compiles** with
`jax.jit(step).lower(...).compile()` on 512 placeholder host devices —
proving shardings are coherent and every collective is legal on both the
16×16 pod mesh and the 2×16×16 multi-pod mesh.  `memory_analysis()` and the
loop-trip-aware HLO accounting (see `repro/launch/hlo_stats.py`; XLA's own
`cost_analysis()` counts scan bodies once — verified and corrected) give the
table below.  Documented skips: the 8 full-attention archs × `long_500k`
(no sub-quadratic path; `falcon-mamba-7b` and `hymba-1.5b` run it).

Memory fit note: `deepseek-v3-671b` trains with bf16 AdamW moments
(params 2.6 + grads 2.6 + moments 5.2 + activations ≈ 12.6 GB/chip on v5e;
`repro/launch/steps.py:default_opt`); ≤100B models keep f32 moments.

{dryrun_table}

## §Roofline — baseline, single-pod mesh (per paper instruction)

Terms per chip: compute = HLO_FLOPs/197e12 · memory = HLO_bytes/819e9 ·
collective = collective_bytes/50e9.  `useful` = MODEL_FLOPS (6·N_active·D
train, 2·N_active·D inference) / global HLO FLOPs — catches remat and
dispatch waste.  `roofline_frac` = ideal-compute-time / dominant-term —
the headline score per cell.

One sentence per dominant term (all cells are memory- or collective-bound
at baseline): the pure-jnp chunked attention / SSM scans write f32 tiles to
HBM; the Pallas kernel path removes exactly that traffic — measured in
§Perf Iteration 1.  Decode cells are inherently memory-bound (weight + cache
streaming); their lever is batch, not kernels.

{table("16x16")}

### Optimized roofline (beyond-paper: Pallas-kernel cost model)

Same cells re-analyzed with the flash-attention / selective-scan tile
interiors VMEM-resident (the validated Pallas kernels replace the jnp
reference on TPU; `--fused`).  This is the honest TPU-kernel operating
point — both tables are kept so the paper-faithful baseline and the
beyond-paper gain stay visible:

{table("16x16", fused=True)}

## §Multi-pod (2×16×16) — sharding proof + cross-pod traffic

All cells also compile on the multi-pod mesh; the extra "pod" axis adds DP
gradient all-reduce bytes that cross the DCN fabric.  The fabric planner
(`examples/fabric_planner.py`) consumes exactly these bytes and ranks
MRLS / Fat-Tree / Dragonfly per arch.  Its verdict is faithfully
paper-consistent, not cherry-picked: THIS framework's cross-pod mix is
allreduce-dominated (the MoE design needs no dispatch all-to-all — DESIGN.md
§7), and the paper itself reports FT beating MRLS by 10–20% on Allreduce
(§6.1.3) — so the planner picks Fat-Tree for every arch and recommends
EF-int8 gradient compression.  On All2All-class traffic the same models
give MRLS +42% over FT and +89% over DF at 512 endpoints (1 GB all2all:
23.5 / 33.3 / 44.4 ms) — the paper's headline regime, which applies when
expert-parallel dispatch crosses pods (TP-free pod meshes).

{fill_perf()}
"""

with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
    f.write(DOC)
print(f"wrote EXPERIMENTS.md  (ok={n_ok} skip={n_skip} err={n_err})")
