#!/usr/bin/env python
"""Recalibrate the fabric planner from the design-space-search artifact.

Reads ``artifacts/PARETO_search.json`` (produced by ``python -m repro.api
search``), distills the measured per-(family, pattern) efficiencies via
:func:`repro.fabric.planner.pattern_eff_from_search`, and writes
``benchmarks/CALIB_pattern_eff.json`` — the file
:func:`repro.fabric.planner.load_pattern_eff` overlays onto the inline
defaults at import time.

Usage: PYTHONPATH=src python scripts/calibrate_planner.py \
           [artifact_json] [calib_out_json]
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.fabric.planner import (CALIB_PATH, DEFAULT_PATTERN_EFF,  # noqa: E402
                                  pattern_eff_from_search)

ARTIFACT = Path(__file__).resolve().parents[1] / "artifacts" \
    / "PARETO_search.json"


def main(argv):
    artifact = Path(argv[1]) if len(argv) > 1 else ARTIFACT
    out = Path(argv[2]) if len(argv) > 2 else CALIB_PATH
    with open(artifact) as f:
        doc = json.load(f)
    eff = pattern_eff_from_search(doc)
    if not eff:
        print(f"error: no fully-evaluated candidates with a mappable "
              f"workload pattern in {artifact}", file=sys.stderr)
        return 1
    calib = {"source": str(artifact.name), "eff": eff,
             "defaults": DEFAULT_PATTERN_EFF}
    with open(out, "w") as f:
        json.dump(calib, f, indent=2)
        f.write("\n")
    for fam, pats in sorted(eff.items()):
        for pattern, e in sorted(pats.items()):
            d = DEFAULT_PATTERN_EFF.get(fam, {}).get(pattern)
            drift = "" if d is None else f"  (default {d:.2f})"
            print(f"{fam:>12s}.{pattern:<9s} eff={e:.3f}{drift}")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
