"""Capture bitwise parity goldens for the simulator engine.

Runs every routing policy on the tiny MRLS fabric and records the exact
throughput / avg-hops / latency-histogram outputs.  The committed file
``tests/golden/engine_parity.json`` is the acceptance gate for engine
refactors (compact routing tables, free-list pool, donated buffers): the
rebuilt ``backend="xla"`` engine must reproduce these numbers bitwise.

To regenerate (only legitimate when a PR *intentionally* changes simulated
behaviour, which parity-preserving perf work must not):

    PYTHONPATH=src python scripts/capture_parity_golden.py
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import mrls, build_tables
from repro.core.routing import POLICIES
from repro.simulator.engine import Simulator, SimConfig, Traffic

OUT = pathlib.Path(__file__).resolve().parents[1] / "tests" / "golden" / \
    "engine_parity.json"

FABRIC = {"n_leaves": 14, "u": 3, "d": 3, "seed": 0}
WARM, MEASURE = 60, 120


def main():
    topo = mrls(**FABRIC)
    tables = build_tables(topo)
    golden = {"fabric": FABRIC, "warm": WARM, "measure": MEASURE,
              "policies": {}}
    for policy in POLICIES:
        sim = Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                          pool=4096))
        thr = sim.run_throughput(Traffic("uniform", load=0.7),
                                 warm=WARM, measure=MEASURE, seed=0)
        lat = sim.run_latency(Traffic("uniform", load=0.5),
                              warm=WARM, measure=MEASURE, seed=0)
        hist = np.asarray(lat["hist"])
        nz = np.nonzero(hist)[0]
        golden["policies"][policy] = {
            "throughput": float(thr["throughput"]),
            "avg_hops": float(thr["avg_hops"]),
            "ejected": int(thr["ejected"]),
            "pool_stall": int(thr["pool_stall"]),
            "lat_hist_nonzero": {int(i): int(hist[i]) for i in nz},
        }
        sim.close()
        print(policy, golden["policies"][policy]["throughput"],
              golden["policies"][policy]["ejected"])
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(golden, indent=1, sort_keys=True) + "\n")
    print("wrote", OUT)


if __name__ == "__main__":
    main()
