"""Capture the host-loop collective golden (tests/golden/collective_parity.json).

Records, for every routing policy on the tiny MRLS fabric, the per-phase
completion slots / total slots / pool stalls of the *host-loop* Rabenseifner
allreduce: one ``Traffic("phase")`` state per phase (fresh seed arrays, fresh
PRNG key, fresh pool), driven to completion with ``run_completion``.  This is
the execution the device-resident program scheduler (``Traffic("program")``
with ``schedule="barrier"``) must reproduce bitwise — see
``tests/test_engine_parity.py::test_collective_golden_parity``.

Regenerating this file is only legitimate for PRs that intentionally change
collective behaviour.
"""
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

from repro.core import build_tables, mrls  # noqa: E402
from repro.core.collectives import rabenseifner_phases  # noqa: E402
from repro.simulator.engine import SimConfig, Simulator, Traffic  # noqa: E402

import numpy as np  # noqa: E402

FABRIC = {"n_leaves": 14, "u": 3, "d": 3, "seed": 0}
RANKS = 16
VEC_PACKETS = 8
MAX_SLOTS = 3000
CHUNK = 16
SEED = 0
POLICIES = ("polarized", "minimal_adaptive", "ksp", "ugal", "valiant")


def host_loop_allreduce(sim: Simulator, ranks: int, vec_packets: int,
                        seed: int, chunk: int, max_slots: int) -> dict:
    """The pre-program-scheduler path: one fresh state + completion run per
    Rabenseifner phase (full host sync and state re-init between phases)."""
    total, ok, stall, per_phase = 0, True, 0, []
    for ph in rabenseifner_phases(ranks, vec_packets):
        tr = Traffic("phase", phase_packets=ph["packets"])
        st = sim.make_state(tr, seed=seed)
        partner = np.arange(sim.S, dtype=np.int32)
        partner[:ranks] = ph["partner"]
        st["partner"] = np.asarray(partner)
        r = sim.run_completion(tr, expected=sim.S * ph["packets"],
                               chunk=chunk, max_slots=max_slots, state=st)
        ok &= r["completed"]
        total += r["slots"]
        stall += r["pool_stall"]
        per_phase.append(int(r["slots"]))
    return {"slots": int(total), "completed": bool(ok),
            "pool_stall": int(stall), "phase_slots": per_phase}


def main() -> None:
    tables = build_tables(mrls(**FABRIC))
    doc = {
        "fabric": FABRIC, "ranks": RANKS, "vec_packets": VEC_PACKETS,
        "max_slots": MAX_SLOTS, "chunk": CHUNK, "seed": SEED,
        "policies": {},
    }
    for policy in POLICIES:
        with Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                         pool=4096)) as sim:
            doc["policies"][policy] = host_loop_allreduce(
                sim, RANKS, VEC_PACKETS, SEED, CHUNK, MAX_SLOTS)
        print(policy, doc["policies"][policy])
    out = _ROOT / "tests" / "golden" / "collective_parity.json"
    out.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
