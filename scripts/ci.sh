#!/usr/bin/env bash
# Tier-1 CI: test suite + declarative-API smoke run + step-loop benchmark.
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: repro.api CLI on a tiny spec =="
python -m repro.api run examples/specs/tiny_mrls.json

echo "== smoke: batched (vmapped) replicas=2 completion run =="
mkdir -p artifacts
python -m repro.api run examples/specs/tiny_mrls_a2a.json \
    --replicas 2 --out artifacts/batched_smoke_result.json

echo "== smoke: workload programs (adversarial + collective schedules) =="
# tornado/hotspot/bursty Bernoulli families, ring allreduce, and windowed
# all2all/allreduce, all through the declarative CLI
python -m repro.api run examples/specs/tiny_workloads.json \
    --out artifacts/workloads_smoke_result.json

echo "== bench: step-loop slots/sec on the tiny fabric =="
# emits artifacts/BENCH_step.json and fails if the post-overhaul engine
# regresses >20% against the committed benchmarks/BENCH_step.json baseline
python benchmarks/bench_step.py --fabric tiny \
    --out artifacts/BENCH_step.json --check benchmarks/BENCH_step.json

echo "== bench: collective host-loop vs device-resident program =="
# emits artifacts/BENCH_collective.json and fails if the program
# executor's speedup over the emulated host phase loop regresses >20%
# against the committed benchmarks/BENCH_collective.json baseline
python benchmarks/bench_collective.py --fabric tiny \
    --out artifacts/BENCH_collective.json \
    --check benchmarks/BENCH_collective.json

echo "CI OK"
