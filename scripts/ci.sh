#!/usr/bin/env bash
# Tier-1 CI driver.  Two lanes:
#
#   bash scripts/ci.sh        # full lane (default): entire test suite,
#                             # every smoke, every bench + regression gate
#                             # (nightly schedule / manual dispatch)
#   bash scripts/ci.sh pr     # PR lane: pytest -m "not slow" + the tiny
#                             # smokes — minutes, not tens of minutes
#
# Every smoke/bench writes into artifacts/; the directory is created up
# front so the workflow's artifact-upload steps never race a step that
# failed before creating it.
set -euo pipefail
cd "$(dirname "$0")/.."

LANE="${1:-full}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
mkdir -p artifacts

if [ "$LANE" = "pr" ]; then
    echo "== PR lane: pytest -m 'not slow' =="
    python -m pytest -x -q -m "not slow"

    echo "== smoke: repro.api CLI on a tiny spec =="
    python -m repro.api run examples/specs/tiny_mrls.json

    echo "== smoke: memory estimator on the tiny all2all spec =="
    python -m repro.api estimate examples/specs/tiny_mrls_a2a.json \
        --out artifacts/tiny_estimate.json

    echo "== smoke: open-loop serving sweep (tiny SLO curve + LM bridge) =="
    python -m repro.api serve-sweep examples/specs/tiny_serving.json \
        --out artifacts/tiny_serving_slo.json

    echo "== smoke: degraded-routing resilience sweep on a tiny fabric =="
    python -m repro.api degrade examples/specs/tiny_faults.json \
        --out artifacts/tiny_degrade.json

    echo "== smoke: kill-resume parity (SIGKILL mid-run, resume, compare) =="
    # supervised child runs the tiny all2all through run_resumable, gets
    # SIGKILLed a few seconds in, resumes from the snapshot, and the final
    # Result must be identical to an uninterrupted repro.api.run
    python scripts/kill_resume_smoke.py

    echo "== smoke: design-space search (tiny, deterministic frontier) =="
    # budget-8 search over (family, radix, f, vcs) at 64 endpoints; the
    # 0.6 MiB mem budget must prune >= 1 candidate before it compiles,
    # and the frontier must be non-empty and identical across two runs
    # under the fixed spec seed
    python -m repro.api search examples/specs/tiny_search.json \
        --pareto-out artifacts/tiny_pareto.json \
        --out artifacts/tiny_search.json
    python -m repro.api search examples/specs/tiny_search.json \
        --pareto-out artifacts/tiny_pareto_rerun.json
    python scripts/check_pareto.py artifacts/tiny_pareto.json \
        --require-pruned
    python - <<'PY'
import json
a = json.load(open("artifacts/tiny_pareto.json"))
b = json.load(open("artifacts/tiny_pareto_rerun.json"))
assert a == b, "tiny search is not deterministic under its fixed seed"
print("tiny search deterministic OK")
PY

    echo "CI OK (pr lane)"
    exit 0
elif [ "$LANE" != "full" ]; then
    echo "unknown lane '$LANE' (expected: pr | full)" >&2
    exit 2
fi

echo "== tier-1: pytest (full suite, slow tests included) =="
python -m pytest -x -q

echo "== smoke: repro.api CLI on a tiny spec =="
python -m repro.api run examples/specs/tiny_mrls.json

echo "== smoke: batched (vmapped) replicas=2 completion run =="
python -m repro.api run examples/specs/tiny_mrls_a2a.json \
    --replicas 2 --out artifacts/batched_smoke_result.json

echo "== smoke: workload programs (adversarial + collective schedules) =="
# tornado/hotspot/bursty Bernoulli families, ring allreduce, and windowed
# all2all/allreduce, all through the declarative CLI
python -m repro.api run examples/specs/tiny_workloads.json \
    --out artifacts/workloads_smoke_result.json

echo "== smoke: memory estimator on the headline all2all ladder =="
# prices every (size, family) point up to 100k endpoints — builds the
# topologies but no simulators, so this is minutes of numpy, no jit
python -m repro.api estimate examples/specs/headline_a2a.json \
    --out artifacts/headline_estimates.json

echo "== bench: step-loop slots/sec on the tiny fabric =="
# emits artifacts/BENCH_step.json and fails if the post-overhaul engine
# regresses >20% against the committed benchmarks/BENCH_step.json baseline
python benchmarks/bench_step.py --fabric tiny \
    --out artifacts/BENCH_step.json --check benchmarks/BENCH_step.json

echo "== bench: collective host-loop vs device-resident program =="
# emits artifacts/BENCH_collective.json and fails if the program
# executor's speedup over the emulated host phase loop regresses >20%
# against the committed benchmarks/BENCH_collective.json baseline
python benchmarks/bench_collective.py --fabric tiny \
    --out artifacts/BENCH_collective.json \
    --check benchmarks/BENCH_collective.json

echo "== smoke: open-loop serving sweep (tiny SLO curve + LM bridge) =="
python -m repro.api serve-sweep examples/specs/tiny_serving.json \
    --out artifacts/tiny_serving_slo.json

echo "== bench: open-loop serving source vs Bernoulli baseline =="
# emits artifacts/BENCH_serve.json and fails if the arrival source's
# slots/sec ratio to plain Bernoulli injection regresses >20% against
# the committed benchmarks/BENCH_serve.json baseline (both lanes timed
# on one host, so the gate is host-speed independent)
python benchmarks/bench_serve.py --fabric tiny \
    --out artifacts/BENCH_serve.json --check benchmarks/BENCH_serve.json

echo "== bench: extreme-scale headline sweep (tiny points) =="
# emits artifacts/BENCH_scale.json and fails if the windowed-program /
# raw-pattern slots-per-sec ratio regresses >20% against the committed
# benchmarks/BENCH_scale.json tiny baseline (same-process interleaved
# measurement, so the gate is host-speed independent)
python benchmarks/bench_scale.py --sizes tiny \
    --out artifacts/BENCH_scale.json --check benchmarks/BENCH_scale.json

echo "== bench: supervised scale point with injected SIGKILL =="
# the same tiny point under the worker supervisor: admission preflight,
# RSS budget = host RAM, SIGKILL injected 8s into the first attempt —
# the retry must resume the checkpointed completion run and finish
python benchmarks/bench_scale.py --sizes tiny --families mrls \
    --supervised --inject-kill 8 \
    --out artifacts/BENCH_scale_supervised.json

echo "== search: 1k design-space search vs committed Pareto frontier =="
# re-runs the committed 1k uniform + all2all searches (evolutionary lane
# included), gates the fresh frontier against artifacts/PARETO_search.json
# (same frontier members, full-candidate throughput within 20%), and
# re-distills the planner calibration — jellyfish must appear among the
# fully evaluated candidates
python -m repro.api search examples/specs/search_1k.json \
    --pareto-out artifacts/PARETO_search_ci.json
python scripts/check_pareto.py artifacts/PARETO_search_ci.json \
    --against artifacts/PARETO_search.json --require-family jellyfish
python scripts/calibrate_planner.py artifacts/PARETO_search_ci.json \
    artifacts/CALIB_pattern_eff_ci.json

echo "== bench: fault injection (delta rebuild + degradation curve) =="
# emits artifacts/BENCH_faults.json and fails if the delta-vs-full
# rebuild speed ratio or the throughput retention at 10% links down
# regresses >20% against the committed benchmarks/BENCH_faults.json
# tiny baseline (ratio is same-host relative, so host-speed independent)
python benchmarks/bench_faults.py --fabric tiny \
    --out artifacts/BENCH_faults.json --check benchmarks/BENCH_faults.json

echo "CI OK (full lane)"
