"""CI smoke: SIGKILL a checkpointed run mid-flight, resume, assert parity.

Proves the resilient-runtime contract end to end on a tiny fabric:

1. compute the uninterrupted reference ``Result`` via plain
   :func:`repro.api.run`;
2. launch a child that executes the same experiment through
   :func:`repro.api.run_resumable` (checkpoint every chunk) under
   :class:`repro.runtime.supervisor.Supervisor` with an injected SIGKILL
   a few seconds in — the first attempt dies mid-run, the retry resumes
   from the latest intact snapshot;
3. assert the supervisor actually killed (and retried) the first
   attempt, and that the final ``result.json`` is **identical** to the
   uninterrupted reference.

Run from the repo root: ``python scripts/kill_resume_smoke.py``.
The PR lane of ``scripts/ci.sh`` runs this; ``--kill-after S`` tunes
where the SIGKILL lands (default 3 s — inside the run on any host fast
enough to finish CI).
"""
import json
import pathlib
import sys
import tempfile

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

SPEC = _ROOT / "examples" / "specs" / "tiny_mrls_a2a.json"


def child(ckpt_dir: str) -> None:
    from repro.api import Experiment, run_resumable
    exp = Experiment.from_json(SPEC.read_text())
    run_resumable(exp, ckpt_dir, every=1)


def main(kill_after: float) -> None:
    from repro.api import Experiment, run
    from repro.runtime.supervisor import Supervisor, SupervisorConfig

    exp = Experiment.from_json(SPEC.read_text())
    reference = run(exp)
    print(f"reference: slots={reference.slots} "
          f"completed={reference.completed} "
          f"pool_stall={reference.pool_stall}")

    work = tempfile.mkdtemp(prefix="kill_resume_smoke_")
    ckpt = str(pathlib.Path(work) / "ckpt")
    sup = Supervisor(SupervisorConfig(max_retries=3,
                                      inject_kill_s=kill_after))
    res = sup.run([sys.executable, str(pathlib.Path(__file__).resolve()),
                   "--child", ckpt], cwd=str(_ROOT))
    kinds = [a.killed or f"rc={a.returncode}" for a in res.attempts]
    print(f"supervisor: ok={res.ok} attempts={kinds} "
          f"peak_rss={res.peak_rss_bytes / 2**20:.0f}MiB")
    if not res.ok:
        sys.exit(f"supervised child failed after {len(res.attempts)} "
                 f"attempts ({', '.join(kinds)})")
    if res.retries < 1 or res.attempts[0].killed != "injected":
        sys.exit("injected SIGKILL did not land — the smoke proved "
                 "nothing; lower --kill-after")

    resumed = json.loads((pathlib.Path(ckpt) / "result.json").read_text())
    refdoc = json.loads(reference.to_json())
    if resumed != refdoc:
        sys.exit("MISMATCH: resumed result differs from uninterrupted "
                 f"reference\n  resumed:   {resumed}\n"
                 f"  reference: {refdoc}")
    print("kill-resume smoke OK: resumed Result identical to "
          "uninterrupted reference")


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--child" in argv:
        child(argv[argv.index("--child") + 1])
    else:
        ka = (float(argv[argv.index("--kill-after") + 1])
              if "--kill-after" in argv else 3.0)
        main(ka)
