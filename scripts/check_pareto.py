#!/usr/bin/env python
"""CI gate for design-space-search Pareto artifacts.

Usage:
    python scripts/check_pareto.py ARTIFACT [--against BASELINE]
        [--require-pruned] [--require-family FAM] [--tolerance 0.2]

Structural checks on ARTIFACT (every search record): statuses consistent,
non-empty frontier of fully-evaluated candidates, frontier actually
non-dominated.  ``--require-pruned`` additionally demands at least one
candidate pruned by the estimator/admission gate before compiling
(``est_peak_bytes`` present, no measured throughput).
``--require-family`` demands the family appear among fully evaluated
candidates.  ``--against`` compares to the committed baseline: same
frontier labels, full-candidate throughput within ``--tolerance``
relative.
"""
import argparse
import json
import sys


def _records(doc):
    return doc.get("searches", [doc]) if isinstance(doc, dict) else doc


def check(artifact, baseline=None, require_pruned=False,
          require_family=None, tolerance=0.2):
    errors = []
    for rec in _records(artifact):
        name = rec.get("name", "?")
        cands = rec.get("candidates", [])
        full = [c for c in cands if c.get("status") == "full"]
        pruned = [c for c in cands if c.get("status") == "pruned"]
        if not rec.get("frontier"):
            errors.append(f"{name}: empty frontier")
        by_id = {c["id"]: c for c in cands}
        for cid in rec.get("frontier", []):
            c = by_id.get(cid)
            if c is None or c.get("status") != "full":
                errors.append(f"{name}: frontier id {cid} is not a fully "
                              "evaluated candidate")
            elif c.get("dominated"):
                errors.append(f"{name}: frontier id {cid} is dominated")
        counts = rec.get("counts", {})
        for status, n in counts.items():
            actual = sum(1 for c in cands if c.get("status") == status)
            if actual != n:
                errors.append(f"{name}: counts[{status}]={n} but "
                              f"{actual} candidates carry it")
        if require_pruned:
            if not pruned:
                errors.append(f"{name}: no candidate was pruned before "
                              "compiling")
            for c in pruned:
                if "est_peak_bytes" not in c:
                    errors.append(f"{name}: pruned candidate {c.get('id')} "
                                  "lacks the memory estimate")
                if "screen" in c or "full" in c:
                    errors.append(f"{name}: pruned candidate {c.get('id')} "
                                  "was simulated anyway")
        if require_family and not any(c["family"] == require_family
                                      for c in full):
            errors.append(f"{name}: family {require_family!r} absent from "
                          "fully evaluated candidates")
    if baseline is not None:
        base = {r.get("name"): r for r in _records(baseline)}
        for rec in _records(artifact):
            name = rec.get("name", "?")
            ref = base.get(name)
            if ref is None:
                errors.append(f"{name}: missing from baseline")
                continue
            lab = lambda r: [c["label"] for c in r["candidates"]  # noqa: E731
                             if c["id"] in set(r.get("frontier", []))]
            if lab(rec) != lab(ref):
                errors.append(f"{name}: frontier drifted — fresh {lab(rec)} "
                              f"vs committed {lab(ref)}")
            ref_thr = {c["label"]: c["throughput"]
                       for c in ref["candidates"]
                       if c.get("status") == "full"}
            for c in rec["candidates"]:
                if c.get("status") != "full":
                    continue
                r = ref_thr.get(c["label"])
                if r is None or r <= 0:
                    continue
                drift = abs(c["throughput"] - r) / r
                if drift > tolerance:
                    errors.append(
                        f"{name}: {c['label']} throughput drifted "
                        f"{drift:.1%} (> {tolerance:.0%}) vs committed")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact")
    ap.add_argument("--against")
    ap.add_argument("--require-pruned", action="store_true")
    ap.add_argument("--require-family")
    ap.add_argument("--tolerance", type=float, default=0.2)
    args = ap.parse_args(argv)
    with open(args.artifact) as f:
        artifact = json.load(f)
    baseline = None
    if args.against:
        with open(args.against) as f:
            baseline = json.load(f)
    errors = check(artifact, baseline, args.require_pruned,
                   args.require_family, args.tolerance)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        n = len(_records(artifact))
        print(f"pareto artifact OK ({n} search record(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
