"""Design-space search: spec round-trips, pruning, halving, Pareto, CLI."""
import dataclasses
import json
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import DegradeSpec, degrade_sweep, register_topology
from repro.api.cli import Subcommand, load_spec, register_subcommand
from repro.api.memory import estimate_memory
from repro.api.specs import Experiment, NetworkSpec, WorkloadSpec
from repro.search import (Candidate, DesignError, SearchSpec, design_network,
                          designer_families, dominated_flags, frontier_ids,
                          register_designer, search)
from repro.search.space import candidate_experiment


# ---------------------------------------------------------------------- #
# SearchSpec discipline
# ---------------------------------------------------------------------- #
def test_searchspec_roundtrip():
    spec = SearchSpec(endpoints=64, radix=(8, 16), f=(1.0, 2.0),
                      vcs=(2, 8), budget=4, mem_budget_mib=0.5,
                      strategy="evolutionary", name="rt")
    again = SearchSpec.from_dict(json.loads(spec.to_json()))
    assert again == spec
    assert hash(again) == hash(spec)


def test_searchspec_casts_scalars_and_lists():
    spec = SearchSpec(endpoints=64, families="mrls", radix=[8], f=2,
                      vcs=[4])
    assert spec.families == ("mrls",)
    assert spec.radix == (8,)
    assert spec.f == (2.0,)


@pytest.mark.parametrize("kw", [
    {"endpoints": 2},
    {"endpoints": 64, "objective": "latency"},
    {"endpoints": 64, "strategy": "anneal"},
    {"endpoints": 64, "policies": ("shortest",)},
    {"endpoints": 64, "budget": 0},
    {"endpoints": 64, "survivors": 0.0},
    {"endpoints": 64, "survivors": 1.5},
    {"endpoints": 64, "screen_measure": 0},
    {"endpoints": 64, "mem_budget_mib": -1},
    {"endpoints": 64, "families": ()},
])
def test_searchspec_validation(kw):
    with pytest.raises(ValueError):
        SearchSpec(**kw)


# ---------------------------------------------------------------------- #
# designers
# ---------------------------------------------------------------------- #
def test_designers_cover_builtin_families():
    assert {"mrls", "jellyfish", "fat_tree"} <= set(designer_families())


def test_design_network_reaches_endpoint_floor():
    for fam in ("mrls", "jellyfish", "fat_tree"):
        net = design_network(Candidate(fam, 16, 1.0, "polarized", 4), 128)
        from repro.api.registry import build_network
        assert build_network(net).n_endpoints >= 128


def test_design_infeasible_points_raise():
    with pytest.raises(DesignError):            # odd fat-tree radix
        design_network(Candidate("fat_tree", 15, 1.0, "polarized", 4), 64)
    with pytest.raises(KeyError):               # unknown family
        design_network(Candidate("torus", 16, 1.0, "polarized", 4), 64)


def test_register_designer_idempotent_and_conflicting():
    def designer(endpoints, radix, f, seed):
        return {"radix": radix, "h": 1}
    register_designer("_tmp_fam", designer)
    register_designer("_tmp_fam", designer)     # same object: no-op
    with pytest.raises(ValueError):
        register_designer("_tmp_fam", lambda *a: {})
    register_designer("_tmp_fam", lambda *a: {}, overwrite=True)


def test_candidate_experiment_stages():
    spec = SearchSpec(endpoints=64, screen_warm=5, screen_measure=10,
                      warm=50, measure=100)
    cand = Candidate("mrls", 16, 1.0, "minimal_adaptive", 2)
    net = design_network(cand, 64)
    scr = candidate_experiment(spec, cand, net, stage="screen")
    full = candidate_experiment(spec, cand, net, stage="full")
    assert (scr.warm, scr.measure) == (5, 10)
    assert (full.warm, full.measure) == (50, 100)
    assert scr.route.policy == "minimal_adaptive" and scr.route.vcs == 2
    # same fabric + route key -> one compiled simulator for both stages
    assert (scr.network, scr.route) == (full.network, full.route)


# ---------------------------------------------------------------------- #
# Pareto layer
# ---------------------------------------------------------------------- #
def test_pareto_dominance():
    pts = [
        {"throughput": 0.9, "cost_links": 2.0},   # dominated by 2
        {"throughput": 0.5, "cost_links": 1.0},   # frontier (cheap)
        {"throughput": 0.9, "cost_links": 1.5},   # frontier (fast)
        {"throughput": 0.4, "cost_links": 1.0},   # dominated by 1
    ]
    assert dominated_flags(pts) == [True, False, False, True]
    assert frontier_ids(pts) == [1, 2]            # sorted by cost


def test_pareto_equal_points_not_mutually_dominating():
    pts = [{"throughput": 0.5, "cost_links": 1.0}] * 2
    assert dominated_flags(pts) == [False, False]


# ---------------------------------------------------------------------- #
# the search loop (tiny fabrics; slow-ish but deliberately small windows)
# ---------------------------------------------------------------------- #
TINY = dict(endpoints=32, families=("mrls", "jellyfish"), radix=(8,),
            f=(1.0, 2.0), vcs=(2,), budget=3, survivors=0.5,
            screen_warm=5, screen_measure=10, warm=10, measure=20, seed=2)


def test_search_deterministic_and_structured():
    rec1 = search(SearchSpec(**TINY))
    rec2 = search(SearchSpec(**TINY))
    assert json.dumps(rec1, sort_keys=True) == json.dumps(rec2,
                                                          sort_keys=True)
    assert rec1["n_candidates"] <= 3
    full = [r for r in rec1["candidates"] if r["status"] == "full"]
    assert full and rec1["frontier"]
    for r in full:
        assert {"throughput", "objective", "dominated",
                "cost_links", "theta"} <= set(r)
    assert rec1["counts"]["full"] == len(full)


def test_search_prunes_on_mem_budget_without_compiling(monkeypatch):
    # 1 KiB budget: every candidate must be pruned by the estimator; a
    # compile attempt would crash via the poisoned simulator factory
    import repro.api.runner as runner

    def boom(*a, **kw):
        raise AssertionError("pruned candidate reached the simulator")
    monkeypatch.setattr(runner, "_make_simulator", boom)
    rec = search(SearchSpec(**{**TINY, "mem_budget_mib": 0.001}))
    assert rec["counts"]["pruned"] == rec["n_candidates"] > 0
    assert rec["counts"]["screened"] == rec["counts"]["full"] == 0
    assert rec["frontier"] == []
    for r in rec["candidates"]:
        assert r["status"] == "pruned" and "est_peak_bytes" in r


def test_search_evolutionary_deterministic():
    spec = SearchSpec(**{**TINY, "strategy": "evolutionary", "budget": 4})
    rec1, rec2 = search(spec), search(spec)
    assert json.dumps(rec1, sort_keys=True) == json.dumps(rec2,
                                                          sort_keys=True)
    assert rec1["strategy"] == "evolutionary"
    assert rec1["counts"]["full"] >= 1


def test_search_rejects_non_all2all_collectives():
    with pytest.raises(ValueError, match="all2all"):
        search(SearchSpec(**{**TINY},
                          workload=WorkloadSpec("allreduce", ranks=8,
                                                vec_packets=4)))


def test_promotion_keeps_screen_frontier():
    from repro.search.loop import _promote
    spec = SearchSpec(endpoints=64, survivors=0.5)
    mk = lambda i, thr, cost, obj: {          # noqa: E731
        "id": i, "cost_links": cost,
        "screen": {"throughput": thr, "objective": obj}}
    screened = [
        mk(0, 0.9, 2.0, 0.45),    # top objective
        mk(1, 0.8, 2.0, 0.40),
        mk(2, 0.1, 0.5, 0.20),    # cheap + slow: frontier, worst objective
        mk(3, 0.0, 0.4, 0.00),    # failed run: never promoted
    ]
    promoted, demoted = _promote(spec, screened)
    pids = {r["id"] for r in promoted}
    # frontier = {0 (best), 2 (cheapest with nonzero thr)}; it fills the
    # ceil(0.5*4)=2 quota, so objective runner-up 1 stays demoted and the
    # failed run 3 is never promoted despite being cheapest overall
    assert pids == {0, 2}
    assert {r["id"] for r in demoted} == {1, 3}


# ---------------------------------------------------------------------- #
# CLI registry + spec loading
# ---------------------------------------------------------------------- #
def test_register_subcommand_idempotent_and_conflicting():
    cmd = Subcommand(name="_tmp_cmd", help="x", fn=lambda a: 0)
    register_subcommand(cmd)
    register_subcommand(cmd)                     # equal spec: no-op
    with pytest.raises(ValueError):
        register_subcommand(Subcommand(name="_tmp_cmd", help="y",
                                       fn=lambda a: 1))


def test_search_subcommand_registered():
    from repro.api.cli import registered_subcommands
    names = list(registered_subcommands())
    assert "search" in names
    for expected in ("run", "sweep", "serve-sweep", "degrade", "estimate"):
        assert expected in names


def test_load_spec_plural_forms(tmp_path):
    p = tmp_path / "s.json"
    p.write_text(json.dumps({"searches": [{"a": 1}, {"a": 2}]}))
    assert load_spec(str(p), key="search", plural="searches") == [
        {"a": 1}, {"a": 2}]
    p.write_text(json.dumps({"search": {"a": 1}}))
    assert load_spec(str(p), key="search", plural="searches") == [{"a": 1}]
    p.write_text(json.dumps({"a": 3}))
    assert load_spec(str(p), key="search", plural="searches") == [{"a": 3}]


# ---------------------------------------------------------------------- #
# register_topology idempotence (satellite regression)
# ---------------------------------------------------------------------- #
def test_register_topology_idempotent_and_conflicting():
    from repro.core.topology import fat_tree
    register_topology("_tmp_topo", fat_tree)
    register_topology("_tmp_topo", fat_tree)     # same builder: no-op
    with pytest.raises(ValueError):
        register_topology("_tmp_topo", lambda **kw: None)
    register_topology("_tmp_topo", lambda **kw: None, overwrite=True)


# ---------------------------------------------------------------------- #
# degrade spec-first migration (satellite regression)
# ---------------------------------------------------------------------- #
def _tiny_base():
    return Experiment(
        network=NetworkSpec("mrls", {"n_leaves": 8, "u": 4, "d": 4,
                                     "seed": 0}),
        workload=WorkloadSpec("uniform", load=0.5),
        warm=5, measure=10, name="deg")


def test_degradespec_roundtrip():
    spec = DegradeSpec(base=_tiny_base(), rates=(0.0, 0.05), fail_seed=3)
    assert DegradeSpec.from_dict(spec.to_dict()) == spec


def test_degrade_sweep_legacy_signatures_warn():
    base = _tiny_base()
    spec = DegradeSpec(base=base, rates=(0.0,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rec = degrade_sweep(spec)                # spec-first: no warning
    assert [p["rate"] for p in rec["points"]] == [0.0]
    with pytest.warns(DeprecationWarning):
        legacy = degrade_sweep(base, rates=(0.0,))
    assert legacy["points"][0]["delivered"] == rec["points"][0]["delivered"]
    with pytest.warns(DeprecationWarning):
        from repro.api.degrade import degrade_sweep_from_dict
        degrade_sweep_from_dict({"base": base.to_dict(), "rates": [0.0]})
    with pytest.raises(TypeError):
        degrade_sweep(spec, rates=(0.0, 0.1))    # spec + override: error


# ---------------------------------------------------------------------- #
# planner recalibration (satellite)
# ---------------------------------------------------------------------- #
def test_pattern_eff_from_search_picks_best_candidate():
    from repro.fabric.planner import pattern_eff_from_search
    rec = {
        "spec": {"workload": {"pattern": "uniform"}},
        "candidates": [
            {"status": "full", "family": "mrls", "theta": 0.8,
             "throughput": 0.6},
            {"status": "full", "family": "mrls", "theta": 2.0,
             "throughput": 0.9},
            {"status": "pruned", "family": "mrls"},
        ],
    }
    eff = pattern_eff_from_search(rec)
    assert eff == {"mrls": {"uniform": 0.9}}     # 0.9/min(1,2) beats 0.75
    wrapped = pattern_eff_from_search({"searches": [rec]})
    assert wrapped == eff


def test_load_pattern_eff_overlays_defaults(tmp_path):
    from repro.fabric.planner import DEFAULT_PATTERN_EFF, load_pattern_eff
    calib = tmp_path / "calib.json"
    calib.write_text(json.dumps(
        {"eff": {"mrls": {"all2all": 0.77}, "jellyfish": {"uniform": 0.5}}}))
    table = load_pattern_eff(calib)
    assert table["mrls"]["all2all"] == 0.77
    assert table["mrls"]["allreduce"] == \
        DEFAULT_PATTERN_EFF["mrls"]["allreduce"]
    assert table["jellyfish"] == {"uniform": 0.5}
    assert load_pattern_eff(tmp_path / "missing.json") == \
        {f: dict(p) for f, p in DEFAULT_PATTERN_EFF.items()}


def test_committed_calibration_artifact_loads():
    from repro.fabric.planner import PATTERN_EFF
    # whatever calibration is committed, the planner table must stay
    # complete for its three modeled fabrics
    for fam in ("mrls", "fat_tree", "dragonfly"):
        for pattern in ("all2all", "allreduce", "uniform"):
            assert 0.0 < PATTERN_EFF[fam][pattern] <= 1.0
