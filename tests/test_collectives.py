"""Collective traffic programs (Section 5.2.3)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.collectives import (all2all_rounds, rabenseifner_phases,
                                    all2all_lower_bound_slots)


def test_all2all_rounds_cover_distinct_destinations():
    S, R = 50, 10
    d = all2all_rounds(S, R)
    assert d.shape == (R, S)
    for i in range(S):
        dsts = d[:, i]
        assert len(set(dsts.tolist())) == R        # no repeats
        assert i not in dsts                       # never self


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(2, 10))
def test_rabenseifner_structure(logn):
    n = 1 << logn
    phases = rabenseifner_phases(n, vec_packets=1 << logn)
    assert len(phases) == 2 * logn
    for ph in phases:
        p = ph["partner"]
        assert (p[p] == np.arange(n)).all()        # involution (pairing)
        assert (p != np.arange(n)).all()
        assert ph["packets"] >= 1
    # reduce-scatter halves sizes; all-gather doubles back
    rs = [ph["packets"] for ph in phases[:logn]]
    ag = [ph["packets"] for ph in phases[logn:]]
    assert all(a >= b for a, b in zip(rs, rs[1:]))
    assert all(a <= b for a, b in zip(ag, ag[1:]))
    assert rs == ag[::-1]


def test_lower_bound_monotone_in_theta():
    assert all2all_lower_bound_slots(100, 10, 0.5) > \
        all2all_lower_bound_slots(100, 10, 1.0)
