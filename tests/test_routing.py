"""Routing: BFS correctness, Polarized Theorem 4.2 bound, deroutes."""
import networkx as nx
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (mrls, oft, fat_tree, build_tables, bfs_distances,
                        route_packet_host, find_corners)


def _to_nx(topo):
    g = nx.Graph()
    g.add_nodes_from(range(topo.n_switches))
    c, p = np.nonzero(topo.nbrs >= 0)
    for a, b in zip(c, topo.nbrs[c, p]):
        g.add_edge(int(a), int(b))
    return g


def test_bfs_matches_networkx():
    t = mrls(30, u=4, d=4, seed=3)
    g = _to_nx(t)
    dist = bfs_distances(t, t.leaf_ids)
    for i, src in enumerate(t.leaf_ids[:6]):
        ref = nx.single_source_shortest_path_length(g, int(src))
        for node, d in ref.items():
            assert dist[i, node] == d


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 30))
def test_polarized_bound_theorem_4_2(seed):
    """Route length <= 2 D* - 2 (Theorem 4.2) and no corners."""
    t = mrls(40, u=5, d=5, seed=seed)
    tb = build_tables(t, full=True)
    bound = 2 * tb.diameter_star - 2
    rng = np.random.default_rng(seed)
    leaves = t.leaf_ids
    for _ in range(30):
        a, b = rng.choice(leaves, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "polarized",
                                 max_hops=bound, rng=rng)
        assert len(path) - 1 <= bound
        assert path[0] == a and path[-1] == b


def test_no_corners_on_paper_mrls():
    t = mrls(614, u=18, d=18, seed=1)
    tb = build_tables(t)
    assert find_corners(tb, n_samples=300) == 0


def test_polarized_routes_alternate_updown():
    """Routes follow the [Up-Down]* structure of Section 4.3."""
    t = mrls(40, u=5, d=5, seed=0)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    for _ in range(20):
        a, b = rng.choice(t.leaf_ids, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "polarized", rng=rng)
        levels = [int(t.level[s]) for s in path]
        assert levels[0] == 0 and levels[-1] == 0
        for x, y in zip(levels, levels[1:]):
            assert x != y                 # bipartite: always level change


def test_polarized_deroutes_around_congestion():
    t = oft(5)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    p0 = route_packet_host(tb, 0, 7, "polarized", max_hops=6, rng=rng)
    assert len(p0) - 1 == 2               # minimal through the shared spine
    occ = np.zeros_like(t.nbrs, float)
    occ[0, list(t.nbrs[0]).index(p0[1])] = 100.0
    p1 = route_packet_host(tb, 0, 7, "polarized", max_hops=6,
                           occupancy=occ, rng=rng)
    assert len(p1) - 1 == 4               # expansion + contraction deroute
    assert p1[1] != p0[1]


def test_minimal_adaptive_on_fat_tree():
    t = fat_tree(8, 2)
    tb = build_tables(t)
    rng = np.random.default_rng(1)
    for _ in range(20):
        a, b = rng.choice(t.leaf_ids, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "minimal_adaptive",
                                 rng=rng)
        assert len(path) - 1 == tb.dist_leaf[tb.leaf_rank[a], b]


def test_ksp_randomizes_paths():
    t = mrls(60, u=6, d=6, seed=2)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    total_paths, pairs = 0, 0
    for i in range(10):
        a, b = (int(x) for x in rng.choice(t.leaf_ids, 2, replace=False))
        paths = {tuple(route_packet_host(tb, a, b, "ksp", rng=rng))
                 for _ in range(12)}
        total_paths += len(paths)
        pairs += 1
    assert total_paths > pairs            # randomization across equal paths
