"""Routing: BFS correctness, Polarized Theorem 4.2 bound, deroutes."""
import networkx as nx
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (mrls, oft, fat_tree, build_tables, bfs_distances,
                        route_packet_host, find_corners)


def _to_nx(topo):
    g = nx.Graph()
    g.add_nodes_from(range(topo.n_switches))
    c, p = np.nonzero(topo.nbrs >= 0)
    for a, b in zip(c, topo.nbrs[c, p]):
        g.add_edge(int(a), int(b))
    return g


def test_bfs_matches_networkx():
    t = mrls(30, u=4, d=4, seed=3)
    g = _to_nx(t)
    dist = bfs_distances(t, t.leaf_ids)
    for i, src in enumerate(t.leaf_ids[:6]):
        ref = nx.single_source_shortest_path_length(g, int(src))
        for node, d in ref.items():
            assert dist[i, node] == d


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 30))
def test_polarized_bound_theorem_4_2(seed):
    """Route length <= 2 D* - 2 (Theorem 4.2) and no corners."""
    t = mrls(40, u=5, d=5, seed=seed)
    tb = build_tables(t, full=True)
    bound = 2 * tb.diameter_star - 2
    rng = np.random.default_rng(seed)
    leaves = t.leaf_ids
    for _ in range(30):
        a, b = rng.choice(leaves, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "polarized",
                                 max_hops=bound, rng=rng)
        assert len(path) - 1 <= bound
        assert path[0] == a and path[-1] == b


def test_no_corners_on_paper_mrls():
    t = mrls(614, u=18, d=18, seed=1)
    tb = build_tables(t)
    assert find_corners(tb, n_samples=300) == 0


def test_polarized_routes_alternate_updown():
    """Routes follow the [Up-Down]* structure of Section 4.3."""
    t = mrls(40, u=5, d=5, seed=0)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    for _ in range(20):
        a, b = rng.choice(t.leaf_ids, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "polarized", rng=rng)
        levels = [int(t.level[s]) for s in path]
        assert levels[0] == 0 and levels[-1] == 0
        for x, y in zip(levels, levels[1:]):
            assert x != y                 # bipartite: always level change


def test_polarized_deroutes_around_congestion():
    t = oft(5)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    p0 = route_packet_host(tb, 0, 7, "polarized", max_hops=6, rng=rng)
    assert len(p0) - 1 == 2               # minimal through the shared spine
    occ = np.zeros_like(t.nbrs, float)
    occ[0, list(t.nbrs[0]).index(p0[1])] = 100.0
    p1 = route_packet_host(tb, 0, 7, "polarized", max_hops=6,
                           occupancy=occ, rng=rng)
    assert len(p1) - 1 == 4               # expansion + contraction deroute
    assert p1[1] != p0[1]


def test_minimal_adaptive_on_fat_tree():
    t = fat_tree(8, 2)
    tb = build_tables(t)
    rng = np.random.default_rng(1)
    for _ in range(20):
        a, b = rng.choice(t.leaf_ids, 2, replace=False)
        path = route_packet_host(tb, int(a), int(b), "minimal_adaptive",
                                 rng=rng)
        assert len(path) - 1 == tb.dist_leaf[tb.leaf_rank[a], b]


def test_ksp_randomizes_paths():
    t = mrls(60, u=6, d=6, seed=2)
    tb = build_tables(t)
    rng = np.random.default_rng(0)
    total_paths, pairs = 0, 0
    for i in range(10):
        a, b = (int(x) for x in rng.choice(t.leaf_ids, 2, replace=False))
        paths = {tuple(route_packet_host(tb, a, b, "ksp", rng=rng))
                 for _ in range(12)}
        total_paths += len(paths)
        pairs += 1
    assert total_paths > pairs            # randomization across equal paths


# ---------------------------------------------------------------------- #
# leaf-blocked mask layout (ISSUE 5): blocked == dense, always
# ---------------------------------------------------------------------- #
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000),
       n_leaves=st.sampled_from([12, 14, 20, 30]),
       u=st.integers(2, 5),
       block=st.integers(1, 40))
def test_blocked_mask_blocks_tile_dense(seed, n_leaves, u, block):
    """Streamed leaf blocks tile the dense tables exactly: same values,
    full disjoint coverage, any block size."""
    from repro.core import build_tables, mrls

    t = mrls(n_leaves, u=u, d=u, seed=seed)
    dense = build_tables(t, masks="dense")
    blocked = build_tables(t, masks="blocked", leaf_block=block)
    assert dense.mask_layout == "dense" and dense.min_mask is not None
    assert blocked.mask_layout == "blocked" and blocked.min_mask is None
    covered = np.zeros(t.n_leaves, bool)
    for lo, hi, min_b, away_b in blocked.mask_blocks():
        assert 0 <= lo < hi <= t.n_leaves
        assert not covered[lo:hi].any()          # disjoint
        covered[lo:hi] = True
        np.testing.assert_array_equal(min_b, dense.min_mask[lo:hi])
        np.testing.assert_array_equal(away_b, dense.away_mask[lo:hi])
    assert covered.all()                         # complete


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), block=st.integers(1, 17))
def test_blocked_gather_matches_dense_gather(seed, block):
    """The engine-style flat assembly of streamed blocks gathers the same
    words as indexing the dense [N1, N, W] arrays, and every unpacked bit
    agrees with the distance predicate it encodes."""
    from repro.core import build_tables, mrls

    t = mrls(16, u=3, d=3, seed=seed)
    n1, n, p = t.n_leaves, t.n_switches, t.max_ports
    dense = build_tables(t, masks="dense")
    blocked = build_tables(t, masks="blocked", leaf_block=block)
    w = dense.min_mask.shape[-1]
    flat = {
        "min": np.concatenate([b.reshape(-1, w)
                               for _, _, b, _ in blocked.mask_blocks()]),
        "away": np.concatenate([b.reshape(-1, w)
                                for _, _, _, b in blocked.mask_blocks()]),
    }
    np.testing.assert_array_equal(flat["min"], dense.min_mask.reshape(-1, w))
    np.testing.assert_array_equal(flat["away"],
                                  dense.away_mask.reshape(-1, w))
    rng = np.random.default_rng(seed)
    dist = dense.dist_leaf
    for _ in range(50):
        tl, c = int(rng.integers(n1)), int(rng.integers(n))
        words = flat["min"][tl * n + c]
        bits = (words[np.arange(p) // 32] >> (np.arange(p) % 32)) & 1
        nbr = t.nbrs[c]
        toward = (nbr >= 0) & (dist[tl, np.maximum(nbr, 0)]
                               == dist[tl, c] - 1)
        np.testing.assert_array_equal(bits.astype(bool), toward)


def test_build_tables_auto_layout_threshold(monkeypatch):
    """"auto" resolves to dense below DENSE_MASK_LIMIT and blocked above
    it (forced low here so a tiny fabric crosses the line)."""
    from repro.core import build_tables, mrls
    from repro.core import routing as routing_mod

    t = mrls(14, u=3, d=3, seed=0)
    assert build_tables(t).mask_layout == "dense"
    monkeypatch.setattr(routing_mod, "DENSE_MASK_LIMIT", 64)
    assert build_tables(t).mask_layout == "blocked"
    with pytest.raises(ValueError, match="mask layout"):
        build_tables(t, masks="sparse")
