"""Bitwise parity gates for the device-resident step overhaul.

Two independent locks:

* **Golden parity** — the compact-table + free-list + donated-buffer engine
  (``backend="xla"``) must reproduce the committed pre-overhaul outputs
  (``tests/golden/engine_parity.json``, captured from the seed engine)
  *bitwise* for every routing policy on the tiny MRLS fabric: throughput,
  steady-state avg hops, ejected count, pool stalls, and the full latency
  histogram.
* **Backend parity** — ``backend="pallas"`` (fused arbitration kernel,
  interpret mode on CPU) must produce the *identical state pytree* as
  ``backend="xla"`` after a chunked run, for every policy.

Both engines share one PRNG stream by construction, so any divergence is
a real behaviour change, not noise.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import mrls, build_tables
from repro.simulator.engine import Simulator, SimConfig, Traffic

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "engine_parity.json")
    .read_text())


@pytest.fixture(scope="module")
def tables():
    return build_tables(mrls(**GOLDEN["fabric"]))


@pytest.mark.parametrize("policy", sorted(GOLDEN["policies"]))
def test_golden_parity_bitwise(tables, policy):
    gp = GOLDEN["policies"][policy]
    warm, measure = GOLDEN["warm"], GOLDEN["measure"]
    with Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                     pool=4096)) as sim:
        thr = sim.run_throughput(Traffic("uniform", load=0.7),
                                 warm=warm, measure=measure, seed=0)
        lat = sim.run_latency(Traffic("uniform", load=0.5),
                              warm=warm, measure=measure, seed=0)
    assert thr["throughput"] == gp["throughput"]        # bitwise, no approx
    assert thr["avg_hops"] == gp["avg_hops"]
    assert thr["ejected"] == gp["ejected"]
    assert thr["pool_stall"] == gp["pool_stall"]
    hist = np.asarray(lat["hist"])
    golden_hist = np.zeros_like(hist)
    for bin_, count in gp["lat_hist_nonzero"].items():
        golden_hist[int(bin_)] = count
    np.testing.assert_array_equal(hist, golden_hist)


@pytest.mark.parametrize("policy", sorted(GOLDEN["policies"]))
def test_pallas_backend_matches_xla_bitwise(tables, policy):
    import jax
    tr = Traffic("uniform", load=0.7)
    states = {}
    for backend in ("xla", "pallas"):
        with Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                         pool=4096, backend=backend)) as sim:
            st = sim.make_state(tr, seed=0)
            st = sim.run_chunk(st, tr, 24)
            states[backend] = jax.device_get(st)
    for key in states["xla"]:
        np.testing.assert_array_equal(
            states["xla"][key], states["pallas"][key],
            err_msg=f"state[{key!r}] diverges between backends")


def test_unknown_backend_rejected(tables):
    with pytest.raises(ValueError, match="backend"):
        Simulator(tables, SimConfig(backend="cuda"))
