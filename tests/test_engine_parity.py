"""Bitwise parity gates for the device-resident step overhaul.

Three independent locks:

* **Golden parity** — the compact-table + free-list + donated-buffer engine
  (``backend="xla"``) must reproduce the committed pre-overhaul outputs
  (``tests/golden/engine_parity.json``, captured from the seed engine)
  *bitwise* for every routing policy on the tiny MRLS fabric: throughput,
  steady-state avg hops, ejected count, pool stalls, and the full latency
  histogram.
* **Backend parity** — ``backend="pallas"`` (fused arbitration kernel,
  interpret mode on CPU) must produce the *identical state pytree* as
  ``backend="xla"`` after a chunked run, for every policy.
* **Collective parity** — the device-resident program scheduler
  (``Traffic("program")``, ``schedule="barrier"``) must reproduce the
  committed host-loop Rabenseifner outputs
  (``tests/golden/collective_parity.json``, captured from the pre-program
  per-phase ``run_completion`` loop by
  ``scripts/capture_collective_golden.py``) *bitwise* for every policy:
  per-phase ``phase_slots``, total ``slots``, ``completed``, and
  ``pool_stall`` — including the chunk-granular timeout slots of phases
  that never complete (the ``valiant`` rows).

All engines share one PRNG stream by construction, so any divergence is
a real behaviour change, not noise.
"""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core import mrls, build_tables
from repro.simulator.engine import Simulator, SimConfig, Traffic
from repro.workloads import compile_program, rabenseifner_program

# the host-loop oracle lives next to the golden capture script — one
# implementation for capture, test, and docs, so they cannot drift
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "scripts"))
from capture_collective_golden import host_loop_allreduce  # noqa: E402

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "engine_parity.json")
    .read_text())
COLLECTIVE = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "collective_parity.json")
    .read_text())


# every golden below replays bitwise under BOTH mask layouts: the blocked
# (streamed) tables must be indistinguishable from the dense ones.  The
# blocked rerun doubles this module's cost, so it rides the slow lane —
# the PR lane still proves blocked == dense via the cheap table-level
# invariants in test_routing.py.
MASK_LAYOUTS = ("dense",
                pytest.param("blocked", marks=pytest.mark.slow))

# golden replays cost ~25s per policy; the PR lane keeps the two
# policies that exercise distinct code paths end to end (Polarized's
# toward+away classification and the minimal bit-test path) and defers
# the other three to the nightly full lane
_FAST_POLICIES = ("polarized", "minimal_adaptive")


def _policy_params(policies):
    return [p if p in _FAST_POLICIES
            else pytest.param(p, marks=pytest.mark.slow)
            for p in sorted(policies)]


@pytest.fixture(scope="module", params=MASK_LAYOUTS)
def tables(request):
    return build_tables(mrls(**GOLDEN["fabric"]), masks=request.param)


@pytest.mark.parametrize("policy", _policy_params(GOLDEN["policies"]))
def test_golden_parity_bitwise(tables, policy):
    gp = GOLDEN["policies"][policy]
    warm, measure = GOLDEN["warm"], GOLDEN["measure"]
    with Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                     pool=4096)) as sim:
        thr = sim.run_throughput(Traffic("uniform", load=0.7),
                                 warm=warm, measure=measure, seed=0)
        lat = sim.run_latency(Traffic("uniform", load=0.5),
                              warm=warm, measure=measure, seed=0)
    assert thr["throughput"] == gp["throughput"]        # bitwise, no approx
    assert thr["avg_hops"] == gp["avg_hops"]
    assert thr["ejected"] == gp["ejected"]
    assert thr["pool_stall"] == gp["pool_stall"]
    hist = np.asarray(lat["hist"])
    golden_hist = np.zeros_like(hist)
    for bin_, count in gp["lat_hist_nonzero"].items():
        golden_hist[int(bin_)] = count
    np.testing.assert_array_equal(hist, golden_hist)


@pytest.mark.parametrize("policy", sorted(GOLDEN["policies"]))
def test_pallas_backend_matches_xla_bitwise(tables, policy):
    import jax
    tr = Traffic("uniform", load=0.7)
    states = {}
    for backend in ("xla", "pallas"):
        with Simulator(tables, SimConfig(policy=policy, max_hops=10,
                                         pool=4096, backend=backend)) as sim:
            st = sim.make_state(tr, seed=0)
            st = sim.run_chunk(st, tr, 24)
            states[backend] = jax.device_get(st)
    for key in states["xla"]:
        np.testing.assert_array_equal(
            states["xla"][key], states["pallas"][key],
            err_msg=f"state[{key!r}] diverges between backends")


def test_unknown_backend_rejected(tables):
    with pytest.raises(ValueError, match="backend"):
        Simulator(tables, SimConfig(backend="cuda"))


# ---------------------------------------------------------------------- #
# collective parity: device-resident barrier programs == host phase loop
# ---------------------------------------------------------------------- #
def _device_program_allreduce(sim, ranks, vec_packets, seed, chunk,
                              max_slots):
    cp = compile_program(rabenseifner_program(sim.S, ranks, vec_packets),
                         schedule="barrier")
    r = sim.run_program(cp, chunk=chunk, max_slots=max_slots, seed=seed)
    return {"slots": int(r["slots"]), "completed": bool(r["completed"]),
            "pool_stall": int(r["pool_stall"]),
            "phase_slots": [int(s) for s in r["phase_slots"]]}


@pytest.fixture(scope="module", params=MASK_LAYOUTS)
def collective_tables(request):
    return build_tables(mrls(**COLLECTIVE["fabric"]), masks=request.param)


@pytest.mark.parametrize("policy", _policy_params(COLLECTIVE["policies"]))
def test_collective_golden_parity_bitwise(collective_tables, policy):
    gp = COLLECTIVE["policies"][policy]
    with Simulator(collective_tables,
                   SimConfig(policy=policy, max_hops=10, pool=4096)) as sim:
        got = _device_program_allreduce(
            sim, COLLECTIVE["ranks"], COLLECTIVE["vec_packets"],
            COLLECTIVE["seed"], COLLECTIVE["chunk"],
            COLLECTIVE["max_slots"])
    assert got == gp                                  # bitwise, no approx


def test_program_path_matches_live_host_loop(collective_tables):
    # belt-and-suspenders: beyond the committed golden, the surviving
    # host-loop primitive (``Traffic("phase")`` + ``run_completion``) must
    # agree with the program scheduler when both run today
    with Simulator(collective_tables,
                   SimConfig(policy="polarized", max_hops=10,
                             pool=4096)) as sim:
        args = (sim, COLLECTIVE["ranks"], COLLECTIVE["vec_packets"],
                COLLECTIVE["seed"], COLLECTIVE["chunk"],
                COLLECTIVE["max_slots"])
        assert _device_program_allreduce(*args) == host_loop_allreduce(*args)
