"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU) + hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.minplus.kernel import minplus
from repro.kernels.minplus.ref import minplus_ref, adjacency_matrix, all_pairs_ref
from repro.kernels.minplus.ops import all_pairs_distances
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.selective_scan.kernel import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


# ---------------------------------------------------------------------- #
# minplus
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (100, 70, 130, 32, 128, 32),      # ragged -> padding path
    (128, 256, 128, 128, 128, 128),
    (8, 8, 8, 32, 32, 32),            # smaller than one block
])
def test_minplus_shapes(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0, 10, (m, k)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 10, (k, n)).astype(np.float32))
    out = minplus(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(minplus_ref(a, b)),
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 60), k=st.integers(4, 60), n=st.integers(4, 60),
       seed=st.integers(0, 5))
def test_minplus_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0, 5, (m, k)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 5, (k, n)).astype(np.float32))
    out = minplus(a, b, bm=32, bn=128, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(minplus_ref(a, b)),
                               rtol=1e-6)


def test_minplus_all_pairs_equals_bfs():
    from repro.core import mrls, bfs_distances
    t = mrls(20, u=3, d=3, seed=0)
    d_kernel = np.asarray(all_pairs_distances(t.nbrs, interpret=True))
    d_bfs = bfs_distances(t, np.arange(t.n_switches))
    np.testing.assert_array_equal(d_kernel.astype(np.int32), d_bfs)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,H,Hkv,D,causal,dtype", [
    (2, 256, 4, 2, 64, True, jnp.float32),
    (1, 128, 8, 1, 64, True, jnp.float32),     # MQA
    (2, 128, 4, 4, 128, False, jnp.float32),   # MHA bidirectional
    (1, 256, 4, 2, 64, True, jnp.bfloat16),
    (2, 192, 6, 3, 32, True, jnp.float32),     # non-pow2 seq (bq=64)
])
def test_flash_attention_shapes(B, S, H, Hkv, D, causal, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_reference():
    """Kernel agrees with the model's chunked online-softmax core."""
    from repro.models.attention import attention_core
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    b = attention_core(q, k, v, causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------- #
# selective scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,Di,N,bd", [
    (2, 32, 64, 16, 32),
    (1, 64, 128, 16, 64),
    (3, 16, 32, 8, 32),
])
def test_selective_scan_shapes(B, T, Di, N, bd):
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(B, T, Di)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, T, Di)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (Di, N)).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    Cc = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(B, Di, N)).astype(np.float32))
    y, h = selective_scan(u, dt, A, Bc, Cc, h0, bd=bd, interpret=True)
    for i in range(B):
        yr, hr = selective_scan_ref(u[i], dt[i], A, Bc[i], Cc[i], h0[i])
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h[i]), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)


def test_selective_scan_matches_model_ssm():
    """Kernel chunk == the model's associative-scan chunk decomposition."""
    from repro.models.ssm import ssm_prefill
    # indirect check: associativity — scanning in 2 chunks == 1 chunk
    rng = np.random.default_rng(2)
    B, T, Di, N = 1, 32, 16, 8
    u = jnp.asarray(rng.normal(size=(B, T, Di)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, T, Di)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (Di, N)).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    Cc = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    y1, h1 = selective_scan(u, dt, A, Bc, Cc, h0, bd=16, interpret=True)
    ya, ha = selective_scan(u[:, :16], dt[:, :16], A, Bc[:, :16], Cc[:, :16],
                            h0, bd=16, interpret=True)
    yb, hb = selective_scan(u[:, 16:], dt[:, 16:], A, Bc[:, 16:], Cc[:, 16:],
                            ha, bd=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1),
                               np.concatenate([ya, yb], axis=1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(hb), rtol=1e-5)
