"""Sharded-vs-single-device engine parity (ISSUE 5).

The contract: sharding is a *placement* decision, never a behaviour
change.

* ``run_chunk_sharded`` splits the replica batch over the mesh's
  ``replica`` axis with ``jax.shard_map`` — replicas are independent, so
  every replica must be **bitwise identical** to the single-device
  ``run_chunk_batch`` result, and replica 0 (seed 0) must still
  reproduce the committed seed-engine golden
  (``tests/golden/engine_parity.json``).
* ``shard_state`` places a scalar state on the ``switch`` axis and lets
  GSPMD partition the jitted step — again bitwise.

The in-process tests run on the default 1-device CPU mesh (the shard_map
code path, trivial partitioning); the subprocess test forces 2 host
devices (``--xla_force_host_platform_device_count``, which must not leak
into this process — see conftest) and checks real multi-device splits
for both axes.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import build_tables, mrls
from repro.parallel.sharding import Sharder, ShardingRules, make_sim_mesh
from repro.simulator.engine import SimConfig, Simulator, Traffic

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "engine_parity.json")
    .read_text())


@pytest.fixture(scope="module")
def tables():
    # blocked layout on purpose: the sharded path and the streamed tables
    # are the two halves of the extreme-scale story
    return build_tables(mrls(**GOLDEN["fabric"]), masks="blocked")


def test_sim_sharder_profile_resolves_replica_axis():
    sh = Sharder.for_simulator()
    assert sh.rules.replica == "replica" and sh.rules.switch is None
    assert sh.pspec(("replica", None))[0] == "replica"
    sw = Sharder.for_simulator(axis="switch")
    assert sw.rules.switch == "switch" and sw.rules.replica is None
    # the model-side logical names resolve to replicated, not an error
    assert sh.pspec(("fsdp", "tp")) == sh.pspec((None, None))


def test_sharded_chunk_bitwise_equals_batch(tables):
    import jax
    tr = Traffic("uniform", load=0.7)
    sh = Sharder.for_simulator()
    with Simulator(tables, SimConfig(policy="polarized", max_hops=10,
                                     pool=4096)) as sim:
        st = sim.make_batch_state(tr, [0, 1])
        ref = jax.device_get(sim.run_chunk_batch(st, tr, 24))
        st2 = sim.make_batch_state(tr, [0, 1])
        got = jax.device_get(sim.run_chunk_sharded(st2, tr, 24, sh))
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k], err_msg=f"state[{k!r}]")


def test_sharded_throughput_reproduces_golden(tables):
    """Replica 0 of a sharded batched run == the committed seed-engine
    golden — the sharded path re-derives the same numbers the pre-overhaul
    engine produced."""
    gp = GOLDEN["policies"]["polarized"]
    sh = Sharder.for_simulator()
    with Simulator(tables, SimConfig(policy="polarized", max_hops=10,
                                     pool=4096)) as sim:
        r = sim.run_throughput_batch(Traffic("uniform", load=0.7),
                                     seeds=[0, 1], warm=GOLDEN["warm"],
                                     measure=GOLDEN["measure"], sharder=sh)
    assert float(r["throughput"][0]) == gp["throughput"]
    assert float(r["avg_hops"][0]) == gp["avg_hops"]
    assert int(r["ejected"][0]) == gp["ejected"]
    assert int(r["pool_stall"][0]) == gp["pool_stall"]


def test_sharded_rejects_bad_inputs(tables):
    tr = Traffic("uniform", load=0.7)
    with Simulator(tables, SimConfig(policy="polarized", max_hops=10,
                                     pool=4096)) as sim:
        scalar = sim.make_state(tr, 0)
        sh = Sharder.for_simulator()
        with pytest.raises(ValueError, match="batched"):
            sim.run_chunk_sharded(scalar, tr, 4, sh)
        no_replica = Sharder(make_sim_mesh(axis="switch"),
                             ShardingRules.for_sim_mesh(
                                 make_sim_mesh(axis="switch")))
        batch = sim.make_batch_state(tr, [0, 1])
        with pytest.raises(ValueError, match="replica"):
            sim.run_chunk_sharded(batch, tr, 4, no_replica)


_TWO_DEVICE_SCRIPT = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax
    from repro.core import build_tables, mrls
    from repro.parallel.sharding import Sharder
    from repro.simulator.engine import SimConfig, Simulator, Traffic

    fabric = json.loads(sys.argv[1])
    assert len(jax.devices()) == 2, jax.devices()
    tables = build_tables(mrls(**fabric), masks="blocked")
    tr = Traffic("uniform", load=0.7)
    with Simulator(tables, SimConfig(policy="polarized", max_hops=10,
                                     pool=4096)) as sim:
        st = sim.make_batch_state(tr, [0, 1])
        ref = jax.device_get(sim.run_chunk_batch(st, tr, 24))
        sh = Sharder.for_simulator(n_devices=2)
        st2 = sim.make_batch_state(tr, [0, 1])
        got = jax.device_get(sim.run_chunk_sharded(st2, tr, 24, sh))
        for k in ref:
            np.testing.assert_array_equal(ref[k], got[k], err_msg=k)
        with np.testing.assert_raises(ValueError):   # 3 % 2 != 0
            sim.run_chunk_sharded(sim.make_batch_state(tr, [0, 1, 2]),
                                  tr, 4, sh)
        # switch-axis GSPMD placement, scalar state
        sw = Sharder.for_simulator(n_devices=2, axis="switch")
        s1 = sim.shard_state(sim.make_state(tr, 0), sw)
        s1 = jax.device_get(sim.run_chunk(s1, tr, 24))
        s2 = jax.device_get(sim.run_chunk(sim.make_state(tr, 0), tr, 24))
        for k in s2:
            np.testing.assert_array_equal(s2[k], s1[k], err_msg=k)
    print("TWO_DEVICE_PARITY_OK")
""")


@pytest.mark.slow
def test_sharded_two_devices_bitwise_subprocess():
    """Real 2-way splits for both axes (forced host devices), bitwise."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(pathlib.Path(__file__).resolve().parents[1] / "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, "-c", _TWO_DEVICE_SCRIPT,
         json.dumps(GOLDEN["fabric"])],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TWO_DEVICE_PARITY_OK" in out.stdout
