"""Fallback shim for ``hypothesis`` when the real package is unavailable.

The container that runs tier-1 has no ``hypothesis`` wheel and installing one
is off-limits, so :func:`install` registers a minimal, deterministic stand-in
covering exactly the API surface the test-suite uses: ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)``, and the
``st.integers`` / ``st.sampled_from`` / ``st.booleans`` / ``st.floats``
strategies.  Each property runs ``max_examples`` times on a fixed-seed RNG —
a property *sampler*, not a shrinking fuzzer, but it executes the same
assertions over the same domains.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(min_value=0, max_value=1 << 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def _given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        del wrapper.__wrapped__
        return wrapper
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` in ``sys.modules`` (idempotent)."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.sampled_from = _sampled_from
    st.booleans = _booleans
    st.floats = _floats
    mod.strategies = st
    mod.given = _given
    mod.settings = _settings
    mod.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
