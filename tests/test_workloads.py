"""repro.workloads: pattern registry, program IR/compiler invariants, and
the engine's on-device program executor."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api.specs import WorkloadSpec
from repro.core import build_tables, mrls
from repro.simulator.engine import SimConfig, Simulator, Traffic
from repro.workloads import (WorkloadProgram, all2all_program,
                             build_collective_program, compile_program,
                             pattern_kinds, rabenseifner_program,
                             rd_allreduce_program, ring_allreduce_program)
from repro.workloads.patterns import (BERNOULLI_PATTERNS,
                                      COLLECTIVE_PATTERNS, check_pattern)


# ---------------------------------------------------------------------- #
# shared pattern registry: WorkloadSpec and engine Traffic raise the same
# way on unknowns (regression: the engine used to accept any string and
# silently inject nothing)
# ---------------------------------------------------------------------- #
def test_engine_traffic_rejects_unknown_pattern():
    with pytest.raises(ValueError, match="unknown pattern"):
        Traffic("nonsense")


def test_workload_spec_rejects_unknown_pattern():
    with pytest.raises(ValueError, match="unknown pattern"):
        WorkloadSpec("nonsense")


def test_engine_only_patterns_hidden_from_specs():
    # engine-level patterns stay constructible as Traffic but are not
    # WorkloadSpec vocabulary (reached via collectives instead)
    for pat in ("phase", "program"):
        assert check_pattern(pat, engine=True) == "engine"
        with pytest.raises(ValueError, match="unknown pattern"):
            WorkloadSpec(pat)


def test_spec_bernoulli_patterns_are_engine_patterns():
    # every Bernoulli spec pattern must be executable by the raw engine —
    # one registry, no drift
    for pat in BERNOULLI_PATTERNS:
        assert check_pattern(pat) == "bernoulli"
        assert check_pattern(pat, engine=True) == "bernoulli"
    # built-ins are a subset: register_program_builder may have added more
    assert set(BERNOULLI_PATTERNS + COLLECTIVE_PATTERNS) <= {
        n for n, k in pattern_kinds().items() if k != "engine"}


def test_workload_spec_schedule_validation():
    with pytest.raises(ValueError, match="unknown schedule"):
        WorkloadSpec("allreduce", schedule="eager")
    with pytest.raises(ValueError, match="collective"):
        WorkloadSpec("uniform", schedule="barrier")
    with pytest.raises(ValueError, match="schedule='window'"):
        WorkloadSpec("allreduce", schedule="barrier", window=4)
    with pytest.raises(ValueError, match="window"):
        WorkloadSpec("all2all", rounds=2, schedule="window", window=0)
    assert WorkloadSpec("all2all", rounds=2, schedule="window",
                        window=4).window == 4


def test_adversarial_knob_validation():
    with pytest.raises(ValueError, match="shift"):
        WorkloadSpec("shift", shift=0)
    with pytest.raises(ValueError, match="hot_frac"):
        WorkloadSpec("hotspot", hot_frac=0.0)
    with pytest.raises(ValueError, match="hot_count"):
        WorkloadSpec("hotspot", hot_count=0)
    with pytest.raises(ValueError, match="burst_load"):
        WorkloadSpec("bursty", burst_load=0.0)
    with pytest.raises(ValueError, match="burst_len"):
        WorkloadSpec("bursty", burst_len=0.5)
    # an in-burst intensity below the requested long-run load could never
    # realize that load — reject rather than silently cap
    with pytest.raises(ValueError, match="exceeds burst_load"):
        WorkloadSpec("bursty", load=0.8, burst_load=0.5)
    # even load <= burst_load can be unreachable once the ON fraction
    # saturates at burst_len/(burst_len+1): reject, don't undershoot
    with pytest.raises(ValueError, match="unreachable"):
        WorkloadSpec("bursty", load=0.99, burst_load=1.0, burst_len=8.0)
    with pytest.raises(ValueError, match="power of two"):
        WorkloadSpec("rd_allreduce", ranks=12)
    with pytest.raises(ValueError, match="ranks >= 2"):
        WorkloadSpec("ring_allreduce", ranks=-3)


# ---------------------------------------------------------------------- #
# IR validation
# ---------------------------------------------------------------------- #
def test_ir_rejects_malformed_programs():
    with pytest.raises(ValueError, match="shape"):
        WorkloadProgram("bad", np.zeros((2, 4)), np.ones((2, 5)))
    with pytest.raises(ValueError, match=r"\[0, S\)"):
        WorkloadProgram("bad", np.full((1, 4), 7), np.ones((1, 4)))
    with pytest.raises(ValueError, match="packets"):
        WorkloadProgram("bad", np.zeros((1, 4)), np.full((1, 4), -1))
    with pytest.raises(ValueError, match="no packets"):
        WorkloadProgram("bad", np.zeros((2, 4)),
                        np.stack([np.ones(4), np.zeros(4)]))


def test_compile_rejects_int32_overflow():
    prog = WorkloadProgram("big", np.zeros((1, 4), np.int32),
                           np.full((1, 4), 1 << 29, np.int32))
    with pytest.raises(ValueError, match="int32"):
        compile_program(prog)


def test_program_builder_registry_unknown():
    with pytest.raises(KeyError, match="no program builder"):
        build_collective_program("uniform", 16)


# ---------------------------------------------------------------------- #
# compiler invariants (hypothesis): every library program's phases are
# valid pairings/permutations, expected == sum(packets) per phase, and a
# windowed compilation conserves total packets vs the barrier one
# ---------------------------------------------------------------------- #
def _build(kind: str, S: int, logn: int, vec: int, rounds: int):
    if kind == "all2all":
        return all2all_program(S, rounds)
    if kind == "ring":
        return ring_allreduce_program(S, (1 << logn) + 1, vec)  # non-pow2 ok
    if kind == "rabenseifner":
        return rabenseifner_program(S, 1 << logn, vec)
    return rd_allreduce_program(S, 1 << logn, vec)


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["all2all", "ring", "rabenseifner", "rd"]),
       logn=st.integers(1, 5), vec=st.integers(1, 64),
       rounds=st.integers(1, 12), window=st.integers(1, 6))
def test_program_invariants(kind, logn, vec, rounds, window):
    S = 40
    prog = _build(kind, S, logn, vec, rounds)
    # every phase's partner row is a permutation of the endpoints (pairing
    # or rotation on the active ranks, identity on the idle ones)
    for p in range(prog.n_phases):
        row = prog.partner[p]
        assert np.array_equal(np.sort(row), np.arange(S))
    barrier = compile_program(prog, schedule="barrier")
    windowed = compile_program(prog, schedule="window", window=window)
    # per-phase ejection target is exactly the phase's packet total
    np.testing.assert_array_equal(np.asarray(barrier.expected),
                                  prog.packets.sum(axis=1))
    np.testing.assert_array_equal(
        np.asarray(barrier.expected_cum),
        np.cumsum(prog.packets.sum(axis=1)))
    # schedule choice never creates or drops packets
    assert barrier.total_packets == windowed.total_packets
    assert windowed.window == window and barrier.window == 1


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(1, 6), vec=st.integers(1, 128))
def test_rabenseifner_program_matches_phase_list(logn, vec):
    from repro.core.collectives import rabenseifner_phases
    S, n = 80, 1 << logn
    prog = rabenseifner_program(S, n, vec)
    phases = rabenseifner_phases(n, vec)
    assert prog.n_phases == len(phases)
    for p, ph in enumerate(phases):
        np.testing.assert_array_equal(prog.partner[p, :n], ph["partner"])
        np.testing.assert_array_equal(prog.partner[p, n:],
                                      np.arange(n, S))
        assert (prog.packets[p] == ph["packets"]).all()


# ---------------------------------------------------------------------- #
# on-device program executor semantics (tiny fabric)
# ---------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def sim():
    tables = build_tables(mrls(n_leaves=14, u=3, d=3, seed=0))
    with Simulator(tables, SimConfig(policy="polarized", max_hops=10,
                                     pool=4096)) as s:
        yield s


def test_windowed_all2all_with_full_window_is_legacy_all2all(sim):
    # window >= rounds removes every dependency, which is exactly the
    # engine's free-running all2all — same PRNG stream, bitwise-equal slots
    rounds = 4
    cp = compile_program(all2all_program(sim.S, rounds), schedule="window",
                         window=rounds)
    r = sim.run_program(cp, chunk=16, max_slots=4000)
    legacy = sim.run_completion(Traffic("all2all", rounds=rounds),
                                expected=sim.S * rounds, chunk=16,
                                max_slots=4000)
    assert r["completed"] and legacy["completed"]
    assert int(r["phase_slots"][-1]) == legacy["slots"]
    assert int(r["slots"]) == legacy["slots"]


def test_window_tightens_to_barrier_like_and_loosens_to_pipelined(sim):
    rounds = 4
    slots = {}
    for w in (1, 2, rounds):
        cp = compile_program(all2all_program(sim.S, rounds),
                             schedule="window", window=w)
        r = sim.run_program(cp, chunk=16, max_slots=4000)
        assert r["completed"]
        done = np.asarray(r["phase_slots"])
        assert (np.diff(done) >= 0).all()     # cumulative, monotone
        slots[w] = int(r["slots"])
    # a pipelined window beats the fully-serialized one (the arbitration
    # noise between two deep windows can go either way, so only the
    # serialized endpoint is ordered)
    assert slots[rounds] <= slots[1] and slots[2] <= slots[1]


def test_barrier_program_records_per_phase_durations(sim):
    cp = compile_program(rabenseifner_program(sim.S, 16, 8))
    r = sim.run_program(cp, chunk=16, max_slots=3000)
    assert r["completed"]
    done = np.asarray(r["phase_slots"])
    assert done.shape == (8,) and (done >= 1).all()
    assert int(r["slots"]) == int(done.sum())
    # phase durations mirror the message-size schedule (rs == reversed ag)
    assert list(done) == list(done[::-1])


def test_program_batch_matches_scalar_bitwise(sim):
    cp = compile_program(ring_allreduce_program(sim.S, 8, 16))
    # the compiled schedule arrays are replica-invariant: ONE shared device
    # copy, not an R-fold stack (they ride the vmap with in_axes=None)
    bst = sim.make_program_batch_state(cp, [3, 4])
    assert bst["prog_partner"].shape == (cp.n_phases, sim.S)
    assert bst["phase_done"].shape == (2, cp.n_phases)
    rb = sim.run_program(cp, chunk=16, max_slots=4000, seeds=[3, 4])
    for i, s in enumerate((3, 4)):
        rs = sim.run_program(cp, chunk=16, max_slots=4000, seed=s)
        assert list(rb["phase_slots"][i]) == list(rs["phase_slots"])
        assert int(rb["slots"][i]) == rs["slots"]
        assert bool(rb["completed"][i]) == rs["completed"]


def test_program_endpoint_count_must_match_fabric(sim):
    cp = compile_program(all2all_program(sim.S + 2, 1))
    with pytest.raises(ValueError, match="endpoints"):
        sim.make_program_state(cp)


def test_engine_rejects_degenerate_adversarial_traffic(sim):
    with pytest.raises(ValueError, match="shift"):
        sim.make_state(Traffic("shift", shift=sim.S))
    with pytest.raises(ValueError, match="exceeds burst_load"):
        sim.make_state(Traffic("bursty", load=0.8, burst_load=0.5))
    with pytest.raises(ValueError, match="hot_count"):
        sim.make_state(Traffic("hotspot", hot_count=sim.S + 1))


def test_register_program_builder_end_to_end(sim):
    # the documented extension point: one registration call makes a custom
    # collective valid WorkloadSpec vocabulary, resolves metric=auto to
    # completion, and executes device-resident through run()
    from repro.api import Experiment, NetworkSpec, RouteSpec, run
    from repro.workloads import WorkloadProgram
    from repro.workloads.programs import register_program_builder

    def neighbour_exchange(S, **_kw):
        e = np.arange(S, dtype=np.int64)
        partner = np.where(e % 2 == 0, (e + 1) % S, (e - 1) % S)
        return WorkloadProgram("neighbour_exchange", partner[None, :],
                               np.ones((1, S), np.int32))

    register_program_builder("neighbour_exchange", neighbour_exchange,
                             overwrite=True)
    with pytest.raises(ValueError, match="already registered"):
        register_program_builder("neighbour_exchange", neighbour_exchange)
    with pytest.raises(ValueError, match="already registered"):
        register_program_builder("uniform", neighbour_exchange,
                                 overwrite=True)   # bernoulli name clash

    wl = WorkloadSpec("neighbour_exchange")
    exp = Experiment(
        network=NetworkSpec("mrls", {"n_leaves": 14, "u": 3, "d": 3,
                                     "seed": 0}),
        route=RouteSpec(policy="polarized", max_hops=10, pool=4096),
        workload=wl, max_slots=2000)
    assert exp.resolved_metric() == "completion"
    res = run(exp)
    assert res.completed and len(res.phase_slots) == 1


def test_bursty_traffic_runs_and_respects_load(sim):
    r = sim.run_throughput(Traffic("bursty", load=0.2, burst_len=6.0,
                                   burst_load=0.9), warm=100, measure=300)
    # long-run offered load ~0.2; delivered throughput must be in that
    # neighbourhood (generous band: the Markov modulation is noisy)
    assert 0.05 < r["throughput"] < 0.35


def test_tornado_is_leaf_permutation(sim):
    # tornado's destination map never targets the source leaf (n1 even
    # half-rotation) => zero local fast-path deliveries
    r = sim.run_throughput(Traffic("tornado", load=0.3), warm=50,
                           measure=100)
    assert r["throughput"] > 0.0
    assert r["avg_hops"] >= 1.0
