"""Resilient execution runtime: deterministic backoff, dual-clock fault
counting, checkpoint round-trips of armed engine state, bounded-segment
parity, resumable drivers, the subprocess supervisor, and admission
control."""
import json
import os
import pathlib
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (Experiment, NetworkSpec, RouteSpec, WorkloadSpec,
                       check_admission, estimate_memory, AdmissionError)
from repro.api.admission import (BASELINE_RSS_BYTES, DEFAULT_COMPILE_MULT,
                                 compile_ram_multiplier, predict_peak_rss)
from repro.api.registry import build_network
from repro.checkpointing.checkpoint import Checkpointer
from repro.core.failures import FailureSchedule
from repro.core.routing import build_tables
from repro.runtime.fault_tolerance import (BackoffPolicy, FaultTolerantRunner,
                                           FTConfig)
from repro.runtime.resilient import (ResilientConfig,
                                     run_completion_resumable,
                                     run_program_resumable,
                                     run_window_resumable)
from repro.runtime.supervisor import (AdmissionRefused, Supervisor,
                                      SupervisorConfig)
from repro.simulator.engine import Simulator, Traffic
from repro.workloads import build_collective_program, compile_program

NET = NetworkSpec("mrls", {"n_leaves": 14, "u": 3, "d": 3, "seed": 0})
ROUTE = RouteSpec(policy="polarized", max_hops=10)


@pytest.fixture(scope="module")
def sim():
    topo = build_network(NET)
    s = Simulator(build_tables(topo), ROUTE.to_sim_config(seed=0))
    yield s


@pytest.fixture(scope="module")
def program(sim):
    return compile_program(
        build_collective_program("all2all", sim.S, rounds=2),
        schedule="window")


def _tree_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        assert x.dtype == y.dtype, k
        np.testing.assert_array_equal(x, y, err_msg=k)


# ---------------------------------------------------------------------- #
# backoff policy
# ---------------------------------------------------------------------- #
def test_backoff_deterministic_and_bounded():
    p = BackoffPolicy(base_s=0.5, factor=2.0, cap_s=30.0, jitter=0.1)
    assert p.delay(2, 5) == p.delay(2, 5)          # pure function
    # jitter decorrelates on the lifetime counter, not wall clock
    assert p.delay(2, 5) != p.delay(2, 6)
    for consecutive in (1, 2, 3, 7):
        d = p.delay(consecutive, 1)
        nominal = min(0.5 * 2.0 ** (consecutive - 1), 30.0)
        assert nominal * 0.9 <= d <= nominal * 1.1
    assert p.delay(40, 1) <= 30.0 * 1.1            # capped


def test_backoff_no_jitter_exact():
    p = BackoffPolicy(base_s=1.0, factor=2.0, cap_s=8.0, jitter=0.0)
    assert [p.delay(c, c) for c in (1, 2, 3, 4, 5)] == [1, 2, 4, 8, 8]


# ---------------------------------------------------------------------- #
# dual-clock fault counting
# ---------------------------------------------------------------------- #
def _counting_runner(tmp_path, fail_steps, cfg):
    ck = Checkpointer(str(tmp_path))
    fired = set()

    def hook(step):
        if step in fail_steps and step not in fired:
            fired.add(step)
            raise RuntimeError(f"injected @ {step}")

    slept = []
    r = FaultTolerantRunner(
        lambda s, b: (s + b["x"], {"loss": jnp.float32(1.0)}),
        lambda s: {"x": jnp.float32(s)}, ck, cfg, fault_hook=hook,
        sleep_fn=slept.append)
    return r, slept


def test_runner_scattered_transients_survive(tmp_path):
    # 3 one-off failures with successes in between: over max_consecutive=1
    # if counted on one clock, fine on two
    cfg = FTConfig(ckpt_every=2, max_retries=5, max_consecutive=1)
    r, slept = _counting_runner(tmp_path, {5, 9, 13}, cfg)
    state, step, _ = r.run(jnp.float32(0.0), 0, 16)
    assert step == 16 and float(state) == sum(range(16))
    assert r.total_failures == 3 and r.consecutive_failures == 0
    assert r.restarts == 3                         # back-compat alias
    # every retry was a first consecutive failure; jitter keyed on total
    expect = [cfg.backoff.delay(1, t) for t in (1, 2, 3)]
    assert r.delays == expect and slept == expect


def test_runner_hard_wedge_fails_fast(tmp_path):
    ck = Checkpointer(str(tmp_path))

    def hook(step):
        # wedge AT a checkpoint boundary: restore lands back on the same
        # step, so no intervening success resets the consecutive clock
        if step == 4:
            raise RuntimeError("wedged")           # every attempt

    r = FaultTolerantRunner(
        lambda s, b: (s + 1, {"loss": jnp.float32(1.0)}),
        lambda s: {"x": jnp.float32(s)}, ck,
        FTConfig(ckpt_every=2, max_retries=50, max_consecutive=2),
        fault_hook=hook, sleep_fn=lambda d: None)
    with pytest.raises(RuntimeError, match="wedged"):
        r.run(jnp.float32(0.0), 0, 10)
    assert r.consecutive_failures == 3             # limit + 1, then raise
    assert r.total_failures == 3 < 50


# ---------------------------------------------------------------------- #
# checkpoint round-trips of engine state
# ---------------------------------------------------------------------- #
def test_armed_state_checkpoint_roundtrip(tmp_path):
    # armed simulator: state carries int16 distance tables, uint32 mask
    # words, the free-list ring, and live link_up/fail_drop
    topo = build_network(NET)
    sched = FailureSchedule.random_links(topo, 2, down_slot=3, seed=0)
    s = Simulator(build_tables(topo), ROUTE.to_sim_config(seed=0),
                  failures=sched)
    tr = Traffic("all2all", rounds=2)
    st = s.run_chunk(s.make_state(tr, 0), tr, 8)   # past down_slot
    host = {k: np.asarray(v) for k, v in jax.device_get(st).items()}
    assert host["tbl_dist"].dtype == np.int16
    assert host["tbl_min"].dtype == np.uint32
    assert host["link_up"].dtype == np.bool_

    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"state": host})
    template = {"state": {k: np.zeros_like(v) for k, v in host.items()}}
    tree, meta = ck.restore(template, 1)
    _tree_equal(tree["state"], host)
    s.close()


def test_bfloat16_view_roundtrip(tmp_path):
    # npz cannot store bfloat16 natively; the checkpointer round-trips it
    # through a uint16 view — bits and dtype must both survive
    a = jnp.arange(7, dtype=jnp.bfloat16) * jnp.bfloat16(0.3)
    tree = {"a": a, "b": np.arange(5, dtype=np.uint32)}
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    out, _ = ck.restore({"a": jnp.zeros(7, jnp.bfloat16),
                         "b": np.zeros(5, np.uint32)}, 1)
    assert np.asarray(out["a"]).dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["a"]).view(np.uint16),
        np.asarray(a).view(np.uint16))


# ---------------------------------------------------------------------- #
# bounded segments == unbounded loop, bitwise
# ---------------------------------------------------------------------- #
def test_program_bounded_equals_unbounded(sim, program):
    ref = sim.run_program(program, chunk=8, max_slots=2000, seed=0)
    st, running = None, True
    while running:
        r = sim.run_program(program, chunk=8, max_slots=2000, seed=0,
                            state=st, budget_chunks=2)
        st, running = r["state"], r["running"]
    assert r["slots"] == ref["slots"]
    assert r["completed"] == ref["completed"]
    assert r["pool_stall"] == ref["pool_stall"]
    assert tuple(r["phase_slots"]) == tuple(ref["phase_slots"])
    _tree_equal(jax.device_get(r["state"]), jax.device_get(ref["state"]))


def test_completion_bounded_equals_unbounded(sim):
    tr = Traffic("all2all", rounds=2)
    expected = sim.S * 2
    ref = sim.run_completion(tr, expected, chunk=8, max_slots=2000, seed=0)
    st, done, running = None, None, True
    while running:
        r = sim.run_completion(tr, expected, chunk=8, max_slots=2000,
                               seed=0, state=st, budget_chunks=2,
                               done=done)
        st, done, running = r["state"], r["done"], r["running"]
    assert r["slots"] == ref["slots"]
    assert r["completed"] == ref["completed"]
    assert r["pool_stall"] == ref["pool_stall"]


# ---------------------------------------------------------------------- #
# resumable drivers
# ---------------------------------------------------------------------- #
def test_program_resumable_matches_oneshot(sim, program, tmp_path):
    ref = sim.run_program(program, chunk=2, max_slots=2000, seed=0)
    r = run_program_resumable(sim, program, ckpt=str(tmp_path), chunk=2,
                              max_slots=2000, seed=0,
                              config=ResilientConfig(every=1))
    assert r["resumed_from"] is None and r["segments"] >= 2
    assert r["slots"] == ref["slots"]
    assert r["completed"] == ref["completed"]
    assert r["pool_stall"] == ref["pool_stall"]
    assert tuple(r["phase_slots"]) == tuple(ref["phase_slots"])


def test_program_resume_after_interrupt(sim, program, tmp_path):
    ref = sim.run_program(program, chunk=2, max_slots=2000, seed=0)
    full = run_program_resumable(sim, program, ckpt=str(tmp_path), chunk=2,
                                 max_slots=2000, seed=0,
                                 config=ResilientConfig(every=1, keep=100))
    assert full["segments"] >= 3
    # simulate a kill after segment 1: drop every later snapshot
    for d in pathlib.Path(tmp_path).iterdir():
        if d.name.startswith("step_") and int(d.name[5:]) > 1:
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
    r = run_program_resumable(sim, program, ckpt=str(tmp_path), chunk=2,
                              max_slots=2000, seed=0,
                              config=ResilientConfig(every=1, keep=100))
    assert r["resumed_from"] == 1
    assert r["slots"] == ref["slots"]
    assert r["completed"] == ref["completed"]
    assert r["pool_stall"] == ref["pool_stall"]
    assert tuple(r["phase_slots"]) == tuple(ref["phase_slots"])


def test_resume_fingerprint_mismatch_raises(sim, program, tmp_path):
    run_program_resumable(sim, program, ckpt=str(tmp_path), chunk=8,
                          max_slots=2000, seed=0,
                          config=ResilientConfig(every=2))
    with pytest.raises(ValueError, match="different run configuration"):
        run_program_resumable(sim, program, ckpt=str(tmp_path), chunk=16,
                              max_slots=2000, seed=0,
                              config=ResilientConfig(every=2))


def test_window_resumable_matches_oneshot(sim, tmp_path):
    tr = Traffic("uniform", load=0.5)
    ref = sim.run_throughput(tr, warm=30, measure=50, seed=0)
    r = run_window_resumable(sim, tr, metric="throughput",
                             ckpt=str(tmp_path), warm=30, measure=50,
                             seed=0, config=ResilientConfig(every=7))
    assert r["resumed_from"] is None
    assert r["throughput"] == ref["throughput"]
    assert r["avg_hops"] == ref["avg_hops"]
    assert r["ejected"] == ref["ejected"]
    assert r["pool_stall"] == ref["pool_stall"]


def test_completion_resumable_matches_oneshot(sim, tmp_path):
    tr = Traffic("all2all", rounds=2)
    expected = sim.S * 2
    ref = sim.run_completion(tr, expected, chunk=8, max_slots=2000, seed=0)
    r = run_completion_resumable(sim, tr, expected, ckpt=str(tmp_path),
                                 chunk=8, max_slots=2000, seed=0,
                                 config=ResilientConfig(every=2))
    assert r["slots"] == ref["slots"]
    assert r["completed"] == ref["completed"]
    assert r["pool_stall"] == ref["pool_stall"]


# ---------------------------------------------------------------------- #
# supervisor
# ---------------------------------------------------------------------- #
_PY = sys.executable


def _sup(**kw):
    kw.setdefault("poll_interval_s", 0.05)
    kw.setdefault("backoff", BackoffPolicy(base_s=0.0, jitter=0.0))
    return Supervisor(SupervisorConfig(**kw), sleep_fn=lambda d: None)


def test_supervisor_timeout_kill():
    res = _sup(timeout_s=0.3, max_retries=0).run(
        [_PY, "-c", "import time; time.sleep(30)"])
    assert not res.ok
    assert res.attempts[0].killed == "timeout"
    assert res.attempts[0].wall_s < 5


def test_supervisor_rss_kill():
    res = _sup(rss_budget_bytes=120 << 20, max_retries=0).run(
        [_PY, "-c",
         "b = bytearray(300 * 2**20); import time; time.sleep(30)"])
    assert not res.ok
    assert res.attempts[0].killed == "rss"
    assert res.peak_rss_bytes > 120 << 20


def test_supervisor_injected_kill_then_success():
    res = _sup(inject_kill_s=0.1, max_retries=2).run(
        [_PY, "-c", "import time; time.sleep(1.0)"])
    assert res.ok and res.retries == 1
    assert res.attempts[0].killed == "injected"
    assert res.attempts[1].ok


def test_supervisor_admission_preflight():
    sup = _sup(rss_budget_bytes=100)
    with pytest.raises(AdmissionRefused):
        sup.run([_PY, "-c", "pass"], predicted_bytes=200)


def test_supervisor_retries_exhaust_with_backoff():
    slept = []
    sup = Supervisor(
        SupervisorConfig(max_retries=2, poll_interval_s=0.05,
                         backoff=BackoffPolicy(base_s=0.25, jitter=0.0)),
        sleep_fn=slept.append)
    res = sup.run([_PY, "-c", "raise SystemExit(3)"])
    assert not res.ok and len(res.attempts) == 3
    assert all(a.returncode == 3 for a in res.attempts)
    assert slept == [0.25, 0.5]


# ---------------------------------------------------------------------- #
# admission control
# ---------------------------------------------------------------------- #
def _exp(**kw):
    return Experiment(network=NET, route=ROUTE,
                      workload=WorkloadSpec("uniform", load=0.5), **kw)


def test_admission_admits_within_budget():
    d = check_admission(_exp(), budget_bytes=1 << 40, records={})
    assert d.admitted and d.action == "admit"
    assert d.compile_mult == DEFAULT_COMPILE_MULT
    assert d.predicted_bytes == predict_peak_rss(d.resident_bytes,
                                                 d.compile_mult)


def test_admission_refuses_with_actionable_message():
    with pytest.raises(AdmissionError) as e:
        check_admission(_exp(), budget_bytes=1 << 20, records={})
    msg = str(e.value)
    assert "replicas" in msg and "blocked" in msg
    assert "REPRO_ADMISSION=warn" in msg


def test_admission_warn_mode_admits_over_budget():
    d = check_admission(_exp(), budget_bytes=2 << 20, mode="warn",
                        records={})
    assert d.admitted and d.reason


def test_admission_off_mode():
    d = check_admission(_exp(), mode="off")
    assert d.admitted and d.action == "off"


def test_admission_downgrades_to_blocked_masks():
    est = estimate_memory(_exp())
    assert est["tables"]["mask_layout"] == "dense"
    mult = 50_000.0     # synthetic at-scale record: big enough that the
    records = {"x": {"mrls": {"n_endpoints": 5000,      # masks matter
                              "compile_ram_multiplier": mult}}}
    hi = predict_peak_rss(est["total_bytes"], mult)
    lo = predict_peak_rss(
        est["total_bytes"] - est["tables"]["host_mask_bytes"], mult)
    assert lo < hi
    d = check_admission(_exp(), budget_bytes=(lo + hi) // 2,
                        records=records)
    assert d.admitted and d.action == "downgrade" and d.masks == "blocked"
    assert d.predicted_bytes <= (lo + hi) // 2
    assert d.compile_mult == mult


def test_compile_ram_multiplier_prefers_family_at_scale():
    records = {
        "s": {"mrls": {"n_endpoints": 50, "compile_ram_multiplier": 99.0},
              "fat_tree": {"n_endpoints": 9000,
                           "compile_ram_multiplier": 7.0},
              "dragonfly": {"n_endpoints": 2000,
                            "peak_rss_bytes": BASELINE_RSS_BYTES + 1000,
                            "est_total_bytes": 100}}}
    # sub-1000-endpoint record ignored even for the matching family
    assert compile_ram_multiplier("mrls", records) == 7.0   # largest
    assert compile_ram_multiplier("dragonfly", records) == 10.0
    assert compile_ram_multiplier("mrls", {}) == DEFAULT_COMPILE_MULT


# ---------------------------------------------------------------------- #
# end-to-end kill-resume (subprocess SIGKILL; the CI smoke runs the
# supervised variant — this one aims the kill at a live checkpoint chain)
# ---------------------------------------------------------------------- #
_CHILD_SRC = """
import sys
sys.path.insert(0, {src!r})
from repro.api import Experiment, run_resumable
exp = Experiment.from_json(open({spec!r}).read())
run_resumable(exp, {ckpt!r}, every=1)
"""


@pytest.mark.slow
def test_sigkill_resume_bitwise(tmp_path):
    from repro.api import run, resume
    root = pathlib.Path(__file__).resolve().parents[1]
    spec = root / "examples" / "specs" / "tiny_mrls_a2a.json"
    exp = Experiment.from_json(spec.read_text())
    ref = run(exp)

    ckpt = str(tmp_path / "ckpt")
    src = _CHILD_SRC.format(src=str(root / "src"), spec=str(spec),
                            ckpt=ckpt)
    proc = subprocess.Popen([_PY, "-c", src])
    time.sleep(4.0)                   # inside the run on any CI host
    if proc.poll() is None:
        os.kill(proc.pid, signal.SIGKILL)
    proc.wait()

    got = resume(ckpt)                # finishes (or re-runs) the child
    assert json.loads(got.to_json()) == json.loads(ref.to_json())
