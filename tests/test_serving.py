"""Open-loop serving traffic: arrival processes, the LM bridge, and the
SLO sweep layer (``repro.serving``)."""
import json
import math

import numpy as np
import pytest

from repro.core import mrls, build_tables
from repro.simulator.engine import Simulator, SimConfig, Traffic
from repro.workloads.patterns import (bounded_pareto_mean, check_arrival,
                                      check_pattern)


@pytest.fixture(scope="module")
def tiny():
    t = mrls(14, u=3, d=3, seed=0)
    return Simulator(build_tables(t), SimConfig(policy="polarized",
                                                max_hops=10, pool=4096))


@pytest.fixture(scope="module")
def tiny_starved():
    t = mrls(14, u=3, d=3, seed=0)
    # pool far below the 42 endpoints: constant allocator starvation, so
    # batches drain through the -1 sentinel path while the source keeps
    # queueing — the conservation ledger must still close
    return Simulator(build_tables(t), SimConfig(policy="polarized",
                                                max_hops=10, pool=8))


def _conservation(sim, st):
    """The open-loop ledger: every accepted packet is queued at the
    source, popped-but-uninjected, or was created in the network."""
    arrived = int(st["arrived"])
    backlog = sim.arrival_backlog(st)
    pending = int(np.asarray(st["msg_rem"]).sum())
    created = int(st["created"])
    assert arrived == backlog + pending + created
    in_flight = sim.pool - int(st["fl_len"])
    assert created == int(st["ejected"]) + in_flight


# --------------------------------------------------------------------- #
# config validation (shared registry)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kw", [
    {"process": "uniform"},                               # not an arrival family
    {"load": 0.0},                                        # rate <= 0
    {"load": -0.2},
    {"load": 1.2},                                        # poisson > 1/slot
    {"arr_depth": 0},
    {"process": "pareto", "pareto_alpha": 1.0},           # infinite-mean shape
    {"process": "pareto", "pareto_alpha": 0.5},
    {"process": "pareto", "pareto_cap": 0},
    {"process": "pareto", "load": 3.0, "pareto_alpha": 3.0,
     "pareto_cap": 2},                                    # arrival prob > 1
    {"process": "diurnal", "diurnal_period": 1},          # sub-cycle period
    {"process": "diurnal", "diurnal_amp": 1.5},
    {"process": "diurnal", "diurnal_amp": -0.1},
    {"process": "diurnal", "load": 0.8, "diurnal_amp": 0.5},  # peak > 1
])
def test_check_arrival_rejects_degenerates(kw):
    args = {"process": "poisson", "load": 0.3, **kw}
    with pytest.raises(ValueError):
        check_arrival(args.pop("process"), args.pop("load"), **args)


def test_traffic_and_spec_reject_bad_arrival():
    with pytest.raises(ValueError):
        Traffic("arrival", process="uniform")
    from repro.api.specs import WorkloadSpec
    with pytest.raises(ValueError):
        WorkloadSpec("pareto", load=0.3, pareto_alpha=1.0)
    with pytest.raises(ValueError):
        WorkloadSpec("diurnal", load=0.8, diurnal_amp=0.5)
    # engine rejects arrival family names (they ride in Traffic.process)
    with pytest.raises(ValueError, match="arrival"):
        check_pattern("poisson", engine=True)
    # spec layer accepts them as first-class patterns
    assert check_pattern("poisson") == "arrival"


def test_bounded_pareto_mean_exact():
    assert bounded_pareto_mean(1.5, 1) == 1.0
    # cap=2: X in [1, 2) almost surely, so floor(X) is always 1
    assert bounded_pareto_mean(2.0, 2) == pytest.approx(1.0)
    # cap=3, alpha=1: P(floor=k) = F(k+1) - F(k) with F(x) = (1-1/x)/(2/3)
    # is {1: 3/4, 2: 1/4} -> mean 5/4  (exact discrete hand computation)
    assert bounded_pareto_mean(1.0 + 1e-12, 3) == pytest.approx(1.25,
                                                                abs=1e-6)
    # heavier tail (smaller alpha) and larger cap both raise the mean
    assert bounded_pareto_mean(1.2, 64) > bounded_pareto_mean(1.8, 64)
    assert bounded_pareto_mean(1.5, 256) > bounded_pareto_mean(1.5, 16)
    # mean matches direct Monte-Carlo of the engine's inverse-CDF sampler
    rng = np.random.default_rng(0)
    a, cap = 1.5, 16
    u = rng.random(200_000)
    x = np.floor((1.0 - u * (1.0 - cap ** -a)) ** (-1.0 / a))
    emp = np.clip(x, 1, cap).mean()
    assert bounded_pareto_mean(a, cap) == pytest.approx(emp, rel=0.02)


# --------------------------------------------------------------------- #
# rate calibration (offered load converges to the configured rate)
# --------------------------------------------------------------------- #
def test_poisson_offered_rate_converges(tiny):
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=3, deadline=None)
    @given(seed=st_.integers(0, 5), load=st_.sampled_from([0.3]))
    def prop(seed, load):
        tr = Traffic("arrival", process="poisson", load=load)
        r = tiny.run_serving(tr, warm=40, measure=400, seed=seed)
        # one Bernoulli(load) draw per endpoint-slot: 42*400 samples,
        # std of the mean ~ sqrt(p(1-p)/n) ~ 0.0035 -> 5 sigma
        assert abs(r["offered"] - load) < 0.02
        assert r["delivered"] <= r["offered"] + 0.02
        _conservation(tiny, r["state"])
    prop()


def test_diurnal_offered_rate_converges_over_whole_periods(tiny):
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=3, deadline=None)
    @given(seed=st_.integers(0, 5))
    def prop(seed):
        # measure spans whole modulation periods, over which the integer
        # -slot sine sums to zero: the mean offered rate is exactly load
        tr = Traffic("arrival", process="diurnal", load=0.3,
                     diurnal_amp=0.5, diurnal_period=64)
        r = tiny.run_serving(tr, warm=64, measure=256, seed=seed)
        assert abs(r["offered"] - 0.3) < 0.025
        _conservation(tiny, r["state"])
    prop()


def test_pareto_offered_rate_and_conservation(tiny):
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=3, deadline=None)
    @given(seed=st_.integers(0, 5))
    def prop(seed):
        # heavy-tailed batches: rarer arrivals of mean-calibrated size.
        # batch variance inflates the rate estimator, so the tolerance is
        # looser than poisson's; conservation must stay exact.
        tr = Traffic("arrival", process="pareto", load=0.25,
                     pareto_alpha=1.5, pareto_cap=16)
        r = tiny.run_serving(tr, warm=40, measure=400, seed=seed)
        assert abs(r["offered"] - 0.25) < 0.05
        _conservation(tiny, r["state"])
    prop()


def test_pareto_batches_conserved_through_pool_starvation(tiny_starved):
    from hypothesis import given, settings, strategies as st_

    @settings(max_examples=3, deadline=None)
    @given(seed=st_.integers(0, 5))
    def prop(seed):
        tr = Traffic("arrival", process="pareto", load=0.6,
                     pareto_alpha=1.5, pareto_cap=8, arr_depth=4)
        st = tiny_starved.make_state(tr, seed=seed)
        st = tiny_starved.run_chunk(st, tr, 160)
        assert int(st["pool_stall"]) > 0          # sentinel path exercised
        assert int(st["arr_drop"]) > 0            # FIFO overflow path too
        _conservation(tiny_starved, st)
        # free-list stays duplicate-free under starvation
        free = tiny_starved.free_ids(st)
        assert len(np.unique(free)) == len(free)
    prop()


# --------------------------------------------------------------------- #
# serving metric through the declarative API (p999 / NaN -> None lock)
# --------------------------------------------------------------------- #
def _tiny_exp(**wl):
    from repro.api import Experiment, NetworkSpec, RouteSpec
    from repro.api.specs import WorkloadSpec
    return Experiment(
        network=NetworkSpec("mrls", (("n_leaves", 14), ("u", 3), ("d", 3),
                                     ("seed", 0))),
        route=RouteSpec(policy="polarized", max_hops=10, pool=4096),
        workload=WorkloadSpec(**wl), warm=30, measure=60)


def test_serving_result_lock_p999_and_json(tiny):
    from repro.api import run
    from repro.api.runner import Result, _nan_none
    res = run(_tiny_exp(pattern="poisson", load=0.3))
    assert res.metric == "serving"
    assert set(res.latency) == {"p50", "p99", "p999", "p9999"}
    for v in res.latency.values():              # delivered window -> floats
        assert isinstance(v, float)
    assert res.latency["p50"] <= res.latency["p99"] <= res.latency["p999"]
    assert res.offered is not None and res.throughput is not None
    back = Result.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.latency == res.latency and back.offered == res.offered
    # the empty-window sentinel: NaN percentiles serialize as None
    assert _nan_none(float("nan")) is None
    assert _nan_none(3.0) == 3.0


@pytest.mark.slow
def test_serving_batched_replicas(tiny):
    from repro.api import run
    import dataclasses
    exp = dataclasses.replace(_tiny_exp(pattern="poisson", load=0.3),
                              replicas=2)
    res = run(exp)
    assert len(res.per_replica["offered"]) == 2
    assert len(res.per_replica["p999"]) == 2
    assert res.offered == pytest.approx(
        float(np.mean(res.per_replica["offered"])))


# --------------------------------------------------------------------- #
# LM request-to-traffic bridge
# --------------------------------------------------------------------- #
def test_bridge_program_structure():
    from repro.serving import (lm_decode_program, lm_moe_program,
                               lm_prefill_program)
    S, ranks = 42, 8
    p = lm_prefill_program(S, ranks, 16)
    assert p.partner.shape == (ranks - 1, S)
    assert (p.partner[:, :ranks] == (np.arange(ranks) + 1) % ranks).all()
    assert (p.partner[:, ranks:] == np.arange(ranks, S)).all()  # self-pairs
    assert (p.packets == 16).all()
    d = lm_decode_program(S, ranks, 4)
    assert d.partner.shape == (1, S)
    assert (d.partner[0, :ranks] == (np.arange(ranks) + ranks // 2)
            % ranks).all()
    m = lm_moe_program(S, 4, 7)
    for ph in range(3):
        # shifted exchange: every phase is a permutation without self-pairs
        rp = m.partner[ph, :4]
        assert sorted(rp) == list(range(4)) and (rp != np.arange(4)).all()
    with pytest.raises(ValueError):
        lm_decode_program(S, 1, 4)              # point-to-point needs peers
    with pytest.raises(ValueError):
        lm_prefill_program(4, 8, 4)             # more ranks than endpoints


def test_bridge_shapes_from_model_configs():
    from repro.configs import get_config
    from repro.serving import PACKET_BYTES, request_phase_shape
    dense = get_config("qwen3-1.7b")
    sh = request_phase_shape(dense, "decode", ranks=8)
    assert sh["packets"] == math.ceil(dense.d_model * 2 / PACKET_BYTES)
    sh = request_phase_shape(dense, "prefill", ranks=8, tokens=1024)
    assert sh["bytes_per_phase"] == (1024 // 8) * dense.d_model * 2
    assert sh["n_phases"] == 7
    moe = get_config("qwen3-moe-235b-a22b")
    shm = request_phase_shape(moe, "moe", ranks=8, tokens=64)
    assert shm["packets"] >= 1 and shm["n_phases"] == 7
    with pytest.raises(ValueError):             # dense arch has no MoE leg
        request_phase_shape(dense, "moe", ranks=8)
    with pytest.raises(ValueError):
        request_phase_shape(dense, "train", ranks=8)


@pytest.mark.slow
def test_request_to_spec_runs_to_completion():
    from repro.api import run
    from repro.serving import request_to_spec
    wl = request_to_spec("qwen3-1.7b", "decode", 42, ranks=8)
    assert wl.pattern == "lm_decode" and wl.ranks == 8
    exp = _tiny_exp(pattern=wl.pattern, ranks=wl.ranks,
                    vec_packets=wl.vec_packets)
    import dataclasses
    exp = dataclasses.replace(exp, warm=0, measure=0, max_slots=4000)
    res = run(exp)
    assert res.metric == "completion" and res.completed


# --------------------------------------------------------------------- #
# ServingSpec + sweep + CLI
# --------------------------------------------------------------------- #
def _tiny_serving_spec(**kw):
    from repro.api import NetworkSpec, RouteSpec
    from repro.serving import ServingSpec
    base = dict(
        network=NetworkSpec("mrls", (("n_leaves", 14), ("u", 3), ("d", 3),
                                     ("seed", 0))),
        route=RouteSpec(policy="polarized", max_hops=10, pool=4096),
        process="poisson", loads=(0.3,), warm=20, measure=60,
        name="t-serve")
    return ServingSpec(**{**base, **kw})


def test_serving_spec_round_trip_and_validation():
    spec = _tiny_serving_spec(loads=(0.2, 0.5), model="qwen3-1.7b")
    back = type(spec).from_json(spec.to_json())
    assert back == spec and back.loads == (0.2, 0.5)
    with pytest.raises(ValueError):
        _tiny_serving_spec(loads=())
    with pytest.raises(ValueError):
        _tiny_serving_spec(loads=(1.2,))        # every load is validated
    with pytest.raises(ValueError):
        _tiny_serving_spec(sat_ratio=0.0)
    with pytest.raises(ValueError):
        _tiny_serving_spec(model="qwen3-1.7b", phase="train")


@pytest.mark.slow
def test_serve_sweep_knee_and_cli(tmp_path):
    from repro.serving import serve_sweep
    rec = serve_sweep(_tiny_serving_spec(loads=(0.3, 0.95), measure=80))
    assert [p["load"] for p in rec["points"]] == [0.3, 0.95]
    for p in rec["points"]:
        assert {"offered", "delivered", "p50", "p99", "p999"} <= set(p)
    # 0.95 on the tiny fabric oversubscribes: the knee must be detected
    assert rec["saturation"] is not None
    assert rec["saturation"]["load"] == 0.95
    assert rec["request"] is None

    # CLI round trip on the committed example spec
    from repro.api.cli import main
    out = tmp_path / "slo.json"
    assert main(["serve-sweep", "examples/specs/tiny_serving.json",
                 "--out", str(out)]) == 0
    docs = json.loads(out.read_text())
    assert [d["name"] for d in docs] == ["tiny.serve.poisson",
                                        "tiny.serve.pareto"]
    assert docs[0]["request"]["pattern"] == "lm_decode"
    assert docs[0]["request"]["completed"]


def test_bench_serve_baseline_committed():
    doc = json.loads(open("benchmarks/BENCH_serve.json").read())
    assert "tiny" in doc["overhead"] and doc["overhead"]["tiny"]["ratio"] > 0
    names = [r["name"] for r in doc["sweeps"]]
    # the headline MRLS-vs-Fat-Tree >=1k SLO curves with a visible knee
    assert "serve.1k.mrls.poisson" in names
    assert "serve.1k.fat_tree.poisson" in names
    for r in doc["sweeps"]:
        assert r["saturation"] is not None, r["name"]
        tail = [p["p999"] for p in r["points"]]
        assert tail == sorted(tail) or max(tail) > 2 * tail[0]
