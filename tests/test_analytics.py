"""Analytics: Eq. 1-3, Appendix A recurrences and thresholds."""
import numpy as np
import pytest

from repro.core import (mrls, build_tables, exact_metrics,
                        mrls_expected_A, prob_dstar_leq, dstar_thresholds,
                        mrls_design, theta)


def test_theta_formula():
    assert theta(M=100, S=50, A=2.0) == 2.0


def test_expected_A_matches_exact():
    """Appendix A estimate vs measured A on real instances."""
    for (n1, u, d, seed) in [(614, 18, 18, 1), (972, 24, 12, 0),
                             (200, 8, 8, 3)]:
        t = mrls(n1, u, d, seed=seed)
        m = exact_metrics(t)
        est = mrls_expected_A(n1, t.meta["n_spines"], u, u + d)
        assert abs(est - m.A) / m.A < 0.05, (est, m.A)


def test_theta_100k_table2():
    """Θ estimates for the 100K configs (Table 2 column Θ)."""
    cases = [(18, 18, 0.527), (24, 12, 1.048), (27, 9, 1.561)]
    for u, d, want in cases:
        n1 = 104976 // d
        n2 = u * n1 // 36
        A = mrls_expected_A(n1, n2, u, 36)
        got = 2.0 * (u / d) / A
        assert abs(got - want) / want < 0.05, (u, got, want)


def test_dstar_thresholds_fig3():
    """Fig. 3 boundaries: D* 3->4 near 2K endpoints, 4->5 near 30K,
    and >= 100M endpoints supported at D=6 (D* <= 7)."""
    th = dstar_thresholds(36, 1.0, k_max=7)
    assert 1e3 < th[3] < 3e3
    assert 2e4 < th[4] < 5e4
    assert th[7] > 1e8


def test_threshold_probability_matches_measured_diameter():
    """P[D* <= k] should separate instances measured above/below."""
    n1, u, d = 96, 18, 18            # ~1.7K endpoints, at the D*=3 boundary
    R = u + d
    n2 = u * n1 // R
    p3 = prob_dstar_leq(n1, n2, u, R, 3)
    assert 0.01 < p3 < 0.99          # genuinely in the transition window
    measured = []
    for seed in range(10):
        t = mrls(n1, u, d, seed=seed)
        tb = build_tables(t, full=True)
        measured.append(tb.diameter_star <= 3)
    frac = np.mean(measured)
    assert abs(frac - p3) < 0.45     # coarse agreement (10 samples)


def test_mrls_design_divisibility():
    for S in (1000, 11052, 104976, 1_000_000):
        for f in (1.0, 1.4, 2.0, 3.0):
            n1, n2, u, d = mrls_design(S, 36, f)
            assert (u * n1) % 36 == 0
            tol = 0.10 if S <= 2000 else 0.02   # granularity ~ R*d endpoints
            assert abs(n1 * d - S) / S < tol    # fine-grain scalability
