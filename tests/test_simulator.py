"""Simulator: conservation, saturation behaviour, collectives, latency."""
import numpy as np
import pytest

from repro.core import mrls, oft, fat_tree, build_tables
from repro.core.collectives import rabenseifner_phases
from repro.simulator.engine import (Simulator, SimConfig, Traffic,
                                    percentiles)


@pytest.fixture(scope="module")
def tiny():
    t = mrls(14, u=3, d=3, seed=0)
    return Simulator(build_tables(t), SimConfig(policy="polarized",
                                                max_hops=10, pool=4096))


def test_packet_conservation(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=0.8), warm=100,
                            measure=150)
    st = r["state"]
    in_flight = tiny.pool - int(st["fl_len"])      # pool slots not free
    assert int(st["created"]) == int(st["ejected"]) + in_flight


def test_throughput_tracks_offered_below_saturation(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=0.25), warm=150,
                            measure=300)
    assert abs(r["throughput"] - 0.25) < 0.03


def test_saturation_below_capacity_limit(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=1.0), warm=200,
                            measure=300)
    assert 0.45 < r["throughput"] <= 0.90   # Θ = 0.867 for this instance


@pytest.mark.slow
def test_polarized_beats_minimal_under_rsp():
    t = oft(5)
    tb = build_tables(t)
    pol = Simulator(tb, SimConfig(policy="polarized", max_hops=6, pool=16384))
    mini = Simulator(tb, SimConfig(policy="minimal_adaptive", max_hops=6,
                                   pool=16384))
    tr = Traffic("rsp", load=1.0)
    rp = pol.run_throughput(tr, warm=250, measure=250)
    rm = mini.run_throughput(tr, warm=250, measure=250)
    assert rp["throughput"] > 1.5 * rm["throughput"]   # paper: deroutes win


def test_all2all_completes(tiny):
    rounds = 6
    S = tiny.S
    r = tiny.run_completion(Traffic("all2all", rounds=rounds),
                            expected=S * rounds, max_slots=4000)
    assert r["completed"]
    assert r["slots"] >= rounds          # at least one slot per round


@pytest.mark.slow
def test_rabenseifner_phases_on_sim():
    t = mrls(14, u=3, d=3, seed=0)
    sim = Simulator(build_tables(t), SimConfig(policy="polarized",
                                               max_hops=10, pool=4096))
    n = 32                                # ranks = endpoints subset (2^5)
    phases = rabenseifner_phases(n, vec_packets=8)
    total_slots = 0
    st = None
    for ph in phases:
        tr = Traffic("phase", phase_packets=ph["packets"])
        state = sim.make_state(tr)
        partner = np.arange(sim.S, dtype=np.int32)   # self = no-op beyond n
        partner[:n] = ph["partner"]
        state["partner"] = np.asarray(partner)
        # every endpoint delivers its whole message (self-partnered ones via
        # the local fast path), so completion is all S*packets deliveries
        expected = sim.S * ph["packets"]
        r = sim.run_completion(tr, expected=expected, max_slots=3000,
                               state=state)
        assert r["completed"]
        # NIC injects 1 packet/slot, so a phase can't beat its packet count
        assert r["slots"] >= ph["packets"]
        total_slots += r["slots"]
    assert total_slots > 0


def test_percentiles_pinned_on_hand_built_histogram():
    # bin index IS the latency in slots: 50 packets at 2 slots, 49 at 10,
    # 1 at 30 -> p50 = 2 (cum hits exactly 50 there), p99 = 10, p9999 = 30.
    hist = np.zeros(64, np.int64)
    hist[2], hist[10], hist[30] = 50, 49, 1
    p = percentiles(hist, (0.5, 0.99, 0.9999))
    assert p["p0.5"] == 2
    assert p["p0.99"] == 10
    assert p["p0.9999"] == 30
    # empty window -> NaN, not a crash
    assert np.isnan(percentiles(np.zeros(8, np.int64), (0.5,))["p0.5"])
    # uniformly float-typed: completed bins are floats, empty windows NaN
    # floats — downstream aggregation never sees an int/float mix
    assert all(type(v) is float for v in p.values())


def test_avg_hops_excludes_warmup_window(tiny):
    # run once with a warmup and once measuring from slot 0: the windowed
    # avg_hops must equal the manual (h1-h0)/(e1-e0) over the same window.
    tr = Traffic("uniform", load=0.5)
    st = tiny.make_state(tr, seed=3)
    st = tiny.run_chunk(st, tr, 100)
    e0, h0 = int(st["ejected"]), int(st["hop_sum"])
    st = tiny.run_chunk(st, tr, 150)
    e1, h1 = int(st["ejected"]), int(st["hop_sum"])
    r = tiny.run_throughput(tr, warm=100, measure=150, seed=3)
    assert r["avg_hops"] == pytest.approx((h1 - h0) / max(e1 - e0, 1))
    assert r["avg_hops"] != pytest.approx(h1 / max(e1, 1))  # old cumulative


def test_pool_overflow_routes_to_sentinel_not_alias():
    # pool (8) far smaller than endpoints (42): overflow injectors must
    # stall (pool_stall), never alias two endpoints onto one packet id —
    # aliasing shows up as a packet-conservation violation.
    t = mrls(14, u=3, d=3, seed=0)
    sim = Simulator(build_tables(t), SimConfig(policy="polarized",
                                               max_hops=10, pool=8))
    r = sim.run_throughput(Traffic("uniform", load=1.0), warm=50, measure=100)
    st = r["state"]
    in_flight = sim.pool - int(st["fl_len"])
    assert int(st["created"]) == int(st["ejected"]) + in_flight
    assert r["pool_stall"] > 0          # starvation is visible, not silent


def test_completion_slot_is_exact_not_chunk_granular(tiny):
    rounds, chunk = 4, 64
    S = tiny.S
    tr = Traffic("all2all", rounds=rounds)
    r = tiny.run_completion(tr, expected=S * rounds, chunk=chunk,
                            max_slots=4000, seed=5)
    assert r["completed"]
    # emulate the old host-loop: advance in whole chunks, stop at the first
    # chunk boundary where the program has completed
    st = tiny.make_state(tr, seed=5)
    while int(st["slot"]) < 4000:
        st = tiny.run_chunk(st, tr, chunk)
        if int(st["ejected"]) >= S * rounds:
            break
    old_slots = int(st["slot"])
    assert r["slots"] <= old_slots < r["slots"] + chunk


@pytest.mark.slow
def test_batched_state_matches_scalar_runs(tiny):
    tr = Traffic("uniform", load=0.5)
    seeds = [0, 1, 2, 3]
    rb = tiny.run_throughput_batch(tr, seeds, warm=30, measure=60)
    for i, s in enumerate(seeds):
        rs = tiny.run_throughput(tr, warm=30, measure=60, seed=s)
        assert rs["throughput"] == rb["throughput"][i]   # bitwise
        assert rs["avg_hops"] == rb["avg_hops"][i]
        assert rs["ejected"] == rb["ejected"][i]


def test_latency_percentiles_reasonable():
    t = fat_tree(8, 1)
    sim = Simulator(build_tables(t), SimConfig(policy="minimal_adaptive",
                                               max_hops=4, pool=8192))
    r = sim.run_latency(Traffic("mice_elephant", load=0.4), warm=150,
                        measure=400)
    assert 2 <= r["p0.5"] <= 40
    assert r["p0.5"] <= r["p0.99"] <= r["p0.9999"]


# ---------------------------------------------------------------------- #
# PRNG seed-stream derivation
# ---------------------------------------------------------------------- #
def test_seed_streams_do_not_collide():
    # the old derivation PRNGKey(cfg.seed + (seed << 16)) collided
    # (cfg.seed=65536, seed=0) with (cfg.seed=0, seed=1); fold_in keeps
    # the (config-seed, run-seed) pairs on distinct streams
    t = mrls(14, u=3, d=3, seed=0)
    tb = build_tables(t)
    tr = Traffic("uniform", load=0.5)
    sim_a = Simulator(tb, SimConfig(policy="polarized", max_hops=10,
                                    pool=4096, seed=65536))
    sim_b = Simulator(tb, SimConfig(policy="polarized", max_hops=10,
                                    pool=4096, seed=0))
    key_a = np.asarray(sim_a.make_state(tr, seed=0)["key"])
    key_b = np.asarray(sim_b.make_state(tr, seed=1)["key"])
    assert not np.array_equal(key_a, key_b)
    # and distinct run seeds on one simulator stay distinct
    k1 = np.asarray(sim_b.make_state(tr, seed=1)["key"])
    k2 = np.asarray(sim_b.make_state(tr, seed=2)["key"])
    assert not np.array_equal(k1, k2)
    sim_a.close(clear=False)
    sim_b.close()


# ---------------------------------------------------------------------- #
# pool / free-list invariants
# ---------------------------------------------------------------------- #
def _queued_pids(st):
    """Every packet id currently sitting in an input/output/NIC queue."""
    def window(buf, head, ln):
        cap = buf.shape[1]
        idx = (head[:, None] + np.arange(cap)[None, :]) % cap
        vals = np.take_along_axis(buf, idx, 1)
        return vals[np.arange(cap)[None, :] < ln[:, None]]
    out = []
    for b, h, ln in (("qbuf", "qhead", "qlen"),
                     ("oq_buf", "oq_head", "oq_len"),
                     ("eq_buf", "eq_head", "eq_len")):
        out.append(window(np.asarray(st[b]), np.asarray(st[h]),
                          np.asarray(st[ln])))
    return np.concatenate(out)


def _check_freelist_invariants(sim, st):
    free = sim.free_ids(st)
    queued = _queued_pids(st)
    assert len(free) == int(st["fl_len"])
    assert len(np.unique(free)) == len(free), "duplicate id in free-list"
    # no packet id is simultaneously free and enqueued
    assert not np.intersect1d(free, queued).size
    # every in-flight packet sits in exactly one queue slot
    assert len(np.unique(queued)) == len(queued), "pid enqueued twice"
    in_flight = sim.pool - int(st["fl_len"])
    assert len(queued) == in_flight
    assert int(st["created"]) == int(st["ejected"]) + in_flight


@pytest.fixture(scope="module")
def tiny_starved():
    t = mrls(14, u=3, d=3, seed=0)
    # pool (8) far below the 42 endpoints: constant pool_stall pressure,
    # exercising the -1 sentinel path of the allocator
    return Simulator(build_tables(t), SimConfig(policy="polarized",
                                                max_hops=10, pool=8))


def test_freelist_invariants_under_load(tiny):
    from hypothesis import given, settings, strategies as st_
    @settings(max_examples=6, deadline=None)
    @given(seed=st_.integers(0, 7), load=st_.sampled_from([0.4, 1.0]))
    def prop(seed, load):
        tr = Traffic("uniform", load=load)
        st = tiny.make_state(tr, seed=seed)
        st = tiny.run_chunk(st, tr, 80)
        _check_freelist_invariants(tiny, st)
    prop()


def test_freelist_survives_pool_starvation(tiny_starved):
    from hypothesis import given, settings, strategies as st_
    @settings(max_examples=4, deadline=None)
    @given(seed=st_.integers(0, 7))
    def prop(seed):
        tr = Traffic("uniform", load=1.0)
        st = tiny_starved.make_state(tr, seed=seed)
        st = tiny_starved.run_chunk(st, tr, 120)
        assert int(st["pool_stall"]) > 0       # sentinel path exercised
        _check_freelist_invariants(tiny_starved, st)
    prop()
