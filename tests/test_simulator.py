"""Simulator: conservation, saturation behaviour, collectives, latency."""
import numpy as np
import pytest

from repro.core import mrls, oft, fat_tree, build_tables
from repro.core.collectives import rabenseifner_phases
from repro.simulator.engine import Simulator, SimConfig, Traffic


@pytest.fixture(scope="module")
def tiny():
    t = mrls(14, u=3, d=3, seed=0)
    return Simulator(build_tables(t), SimConfig(policy="polarized",
                                                max_hops=10, pool=4096))


def test_packet_conservation(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=0.8), warm=100,
                            measure=150)
    st = r["state"]
    in_flight = int((~np.asarray(st["p_free"])).sum())
    assert int(st["created"]) == int(st["ejected"]) + in_flight


def test_throughput_tracks_offered_below_saturation(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=0.25), warm=150,
                            measure=300)
    assert abs(r["throughput"] - 0.25) < 0.03


def test_saturation_below_capacity_limit(tiny):
    r = tiny.run_throughput(Traffic("uniform", load=1.0), warm=200,
                            measure=300)
    assert 0.45 < r["throughput"] <= 0.90   # Θ = 0.867 for this instance


def test_polarized_beats_minimal_under_rsp():
    t = oft(5)
    tb = build_tables(t)
    pol = Simulator(tb, SimConfig(policy="polarized", max_hops=6, pool=16384))
    mini = Simulator(tb, SimConfig(policy="minimal_adaptive", max_hops=6,
                                   pool=16384))
    tr = Traffic("rsp", load=1.0)
    rp = pol.run_throughput(tr, warm=250, measure=250)
    rm = mini.run_throughput(tr, warm=250, measure=250)
    assert rp["throughput"] > 1.5 * rm["throughput"]   # paper: deroutes win


def test_all2all_completes(tiny):
    rounds = 6
    S = tiny.S
    r = tiny.run_completion(Traffic("all2all", rounds=rounds),
                            expected=S * rounds, max_slots=4000)
    assert r["completed"]
    assert r["slots"] >= rounds          # at least one slot per round


def test_rabenseifner_phases_on_sim():
    t = mrls(14, u=3, d=3, seed=0)
    sim = Simulator(build_tables(t), SimConfig(policy="polarized",
                                               max_hops=10, pool=4096))
    n = 32                                # ranks = endpoints subset (2^5)
    phases = rabenseifner_phases(n, vec_packets=8)
    total_slots = 0
    st = None
    for ph in phases:
        tr = Traffic("phase", phase_packets=ph["packets"])
        state = sim.make_state(tr)
        partner = np.arange(sim.S, dtype=np.int32)   # self = no-op beyond n
        partner[:n] = ph["partner"]
        state["partner"] = np.asarray(partner)
        expected = int((partner[:n] != np.arange(n)).sum()) * ph["packets"]
        r = sim.run_completion(tr, expected=expected, max_slots=3000,
                               state=state)
        assert r["completed"]
        total_slots += r["slots"]
    assert total_slots > 0


def test_latency_percentiles_reasonable():
    t = fat_tree(8, 1)
    sim = Simulator(build_tables(t), SimConfig(policy="minimal_adaptive",
                                               max_hops=4, pool=8192))
    r = sim.run_latency(Traffic("mice_elephant", load=0.4), warm=150,
                        measure=400)
    assert 2 <= r["p0.5"] <= 40
    assert r["p0.5"] <= r["p0.99"] <= r["p0.9999"]
