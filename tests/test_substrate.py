"""Substrate: optimizer, data pipeline, checkpointing, fault tolerance,
gradient compression, sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpointing.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.common import ParamSpec, init_params
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt,
                               warmup_cosine, global_norm, opt_specs)
from repro.optim.compression import compress, decompress, compressed_psum
from repro.runtime.fault_tolerance import (FaultTolerantRunner, FTConfig,
                                           StragglerDetector)
from repro.parallel.sharding import Sharder


# ---------------------------------------------------------------------- #
# optimizer
# ---------------------------------------------------------------------- #
def test_adamw_minimizes_quadratic():
    specs = {"w": ParamSpec((8, 8), (None, None), "float32")}
    params = init_params(specs, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = init_opt(specs, opt)
    target = jnp.ones((8, 8))

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, m = adamw_update(params, g, state, opt)
        return params, state, loss

    losses = []
    for _ in range(60):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < 0.01 * losses[0]


def test_grad_clip_bounds_update():
    opt = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    specs = {"w": ParamSpec((4,), (None,), "float32")}
    params = {"w": jnp.zeros(4)}
    state = init_opt(specs, opt)
    huge = {"w": jnp.full(4, 1e6)}
    p2, s2, m = adamw_update(params, huge, state, opt)
    assert float(m["grad_norm"]) > 1e5
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_warmup_cosine_schedule():
    f = warmup_cosine(10, 100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) <= 0.11


def test_bf16_state_dtype():
    specs = {"w": ParamSpec((4, 4), (None, None))}
    opt = AdamWConfig(state_dtype="bfloat16")
    st_ = init_opt(specs, opt)
    assert st_["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------- #
# compression
# ---------------------------------------------------------------------- #
def test_compression_error_feedback_unbiased():
    """EF accumulates: sum of decompressed q over steps -> sum of g."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    ef = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, s, ef = compress(g, ef)
        total = total + decompress(q, s)
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g),
                               atol=0.02)


def test_compressed_psum_single_device(mesh):
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16,)), jnp.float32)
    ef = jnp.zeros_like(x)
    with jax.set_mesh(mesh):
        out, ef2 = compressed_psum(x, ef, mesh, axis="pod")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.05)


# ---------------------------------------------------------------------- #
# data pipeline
# ---------------------------------------------------------------------- #
def test_data_deterministic_and_learnable():
    cfg = DataConfig(vocab=101, seq=16, global_batch=4, seed=3)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch_at(7), ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert b1["tokens"].max() < 101
    b3 = ds.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_stream_prefetch():
    ds = SyntheticLM(DataConfig(vocab=50, seq=8, global_batch=2))
    it = ds.stream(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch_at(5)["tokens"])
    next(it); next(it)


# ---------------------------------------------------------------------- #
# checkpointing
# ---------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(10, tree, {"note": "x"})
    out, meta = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert meta["step"] == 10 and meta["note"] == "x"


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(4)}
    ck.save_async(7, tree)
    ck.wait()
    out, meta = ck.restore(tree)
    assert meta["step"] == 7


def test_checkpoint_elastic_reshard(tmp_path, mesh):
    """Restore onto explicit shardings (elastic path)."""
    sh = Sharder(mesh)
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((8, 16))}
    ck.save(1, tree)
    shardings = {"w": sh.sharding(("dp", "tp"), (8, 16))}
    out, _ = ck.restore(tree, shardings=shardings)
    assert out["w"].sharding == shardings["w"]


# ---------------------------------------------------------------------- #
# fault tolerance
# ---------------------------------------------------------------------- #
def test_runner_recovers_from_injected_fault(tmp_path):
    ck = Checkpointer(str(tmp_path))
    calls = {"n": 0}

    def batch_at(step):
        return {"x": jnp.float32(step)}

    def step_fn(state, batch):
        return state + batch["x"], {"loss": jnp.float32(1.0)}

    def fault_hook(step):
        if step == 7 and not calls.get("crashed"):   # fail once at step 7
            calls["crashed"] = True
            raise RuntimeError("injected node failure")

    r = FaultTolerantRunner(step_fn, batch_at, ck,
                            FTConfig(ckpt_every=5, max_retries=2),
                            fault_hook=fault_hook)
    state, step, hist = r.run(jnp.float32(0.0), 0, 12)
    assert step == 12
    assert r.restarts == 1
    # exact replay: sum of 0..11 regardless of the crash
    assert float(state) == sum(range(12))


def test_runner_recovers_from_nan(tmp_path):
    ck = Checkpointer(str(tmp_path))
    poisoned = {"on": True}

    def step_fn(state, batch):
        if poisoned["on"] and int(batch["x"]) == 6:
            poisoned["on"] = False
            return state, {"loss": jnp.float32(np.nan)}
        return state + 1, {"loss": jnp.float32(0.5)}

    r = FaultTolerantRunner(step_fn, lambda s: {"x": jnp.int32(s)}, ck,
                            FTConfig(ckpt_every=3, max_retries=2))
    state, step, _ = r.run(jnp.float32(0), 0, 10)
    assert step == 10 and float(state) == 10


def test_straggler_detector():
    det = StragglerDetector(FTConfig(straggler_z=3.0))
    for s in range(20):
        det.observe(s, 0.1 + 0.001 * (s % 3))
    assert not det.flagged
    det.observe(20, 5.0)
    assert len(det.flagged) == 1


# ---------------------------------------------------------------------- #
# sharding rules
# ---------------------------------------------------------------------- #
def test_sharder_divisibility_drop(mesh):
    sh = Sharder(mesh)
    s = sh.sharding(("dp", "tp"), (7, 13))   # nothing divides on 1-dev mesh
    assert s is not None


def test_sharder_resolution(mesh):
    sh = Sharder(mesh)
    spec = sh.pspec(("dp", None, "tp"))
    assert spec[1] is None
