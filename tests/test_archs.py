"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill<->decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, ARCHS, reduced, SHAPES, supports
from repro.models.common import init_params, count_params
from repro.models.model import (build_specs, forward_train, loss_fn, prefill,
                                decode_step, plan)

# per-arch train/decode smokes are minutes of model-side compute with no
# simulator coverage — long-tail by construction, so the whole module
# rides the nightly full lane
pytestmark = pytest.mark.slow

B, S = 2, 64


def _batch(cfg, key, seq=S):
    tokens = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_ctx_tokens:
        batch["ctx"] = jax.random.normal(
            key, (B, cfg.n_ctx_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, mesh, sharder):
    cfg = reduced(REGISTRY[arch])
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    with jax.set_mesh(mesh):
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, sharder)))(params)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0   # ~uniform at init
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "hymba-1.5b",
                                  "falcon-mamba-7b", "deepseek-v3-671b",
                                  "seamless-m4t-medium"])
def test_decode_matches_forward(arch, mesh, sharder):
    """Greedy decode logits at position t must match the training forward
    logits at position t (same params, same prefix)."""
    cfg = reduced(REGISTRY[arch])
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    with jax.set_mesh(mesh):
        full = jax.jit(lambda p, b: forward_train(p, b, cfg, sharder))(
            params, batch)
        pre_batch = dict(batch)
        pre_batch["tokens"] = batch["tokens"][:, : S - 1]
        pre_batch.pop("labels")
        lg, cache = jax.jit(lambda p, b: prefill(p, b, cfg, sharder))(
            params, pre_batch)
        # prefill last-token logits == forward logits at S-2
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, S - 2], np.float32), rtol=0.15, atol=0.15)
        # decode one step with token S-1 == forward logits at S-1
        lg2, _ = jax.jit(lambda p, c, t: decode_step(
            p, c, t, jnp.int32(S - 1), cfg, sharder))(
            params, cache, batch["tokens"][:, S - 1:])
        np.testing.assert_allclose(
            np.asarray(lg2[:, 0], np.float32),
            np.asarray(full[:, S - 1], np.float32), rtol=0.15, atol=0.15)


def test_full_config_param_counts():
    """FULL configs match their advertised sizes (no allocation)."""
    expect = {
        "nemotron-4-15b": 15.6e9, "qwen3-1.7b": 2.0e9,
        "starcoder2-15b": 16.0e9, "command-r-plus-104b": 107e9,
        "hymba-1.5b": 1.7e9, "qwen3-moe-235b-a22b": 235e9,
        "deepseek-v3-671b": 671e9, "llama-3.2-vision-90b": 87.7e9,
        "seamless-m4t-medium": 0.88e9, "falcon-mamba-7b": 7.3e9,
    }
    for arch, want in expect.items():
        n = REGISTRY[arch].param_count()
        assert abs(n - want) / want < 0.05, (arch, n)


def test_shape_cell_skips():
    """long_500k only for sub-quadratic archs (DESIGN.md skip table)."""
    runs = {a: supports(REGISTRY[a], "long_500k")[0] for a in ARCHS}
    assert runs["falcon-mamba-7b"] and runs["hymba-1.5b"]
    assert sum(runs.values()) == 2


def test_plan_layer_counts():
    for arch in ARCHS:
        cfg = REGISTRY[arch]
        groups = plan(cfg)
        n = sum(g.n for g in groups)
        if cfg.enc_dec:
            assert n == cfg.n_layers + cfg.enc_layers
        elif cfg.family == "vlm":
            assert n * cfg.cross_every == cfg.n_layers
        else:
            assert n == cfg.n_layers
