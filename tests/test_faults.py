"""Fault injection + degraded-mode routing (ISSUE 7).

Four layers under test:

* **Schedule spec** — :class:`FailureSchedule` JSON round-trips, validates
  against the topology, and its seeded random ladders are deterministic.
* **Delta rebuilds** — :meth:`RoutingTables.apply_failures` must agree
  with a from-scratch rebuild on the pruned topology for every affected
  leaf row (distances exactly; masks bitwise under the live-port words,
  since masks stay packed against the static adjacency by design), and
  restoring every failed element must return the tables to the pristine
  state *bitwise*.
* **Live engine** — the static no-op branch keeps zero-failure runs
  bitwise on the committed goldens; an armed-but-all-up schedule is
  value-identical to pristine; ``run_resilience`` applies transitions on
  slot boundaries, frees packets under the ``drop`` policy, and always
  restores pristine tables; pristine ``degraded`` routing is bitwise
  ``minimal_adaptive``.
* **Driver + runtime satellites** — the ``resilience`` metric flows
  through ``run()``, ``degrade_sweep`` emits retention curves, the
  straggler detector's variance EMA uses the pre-update residual, and
  ``schedule_fault_hook`` drives schedule transitions from the
  fault-tolerant runner's step clock.
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core import (FailureEvent, FailureSchedule, UNREACHABLE,
                        build_tables, canonical_link_ids, mrls)
from repro.api import (Experiment, NetworkSpec, RouteSpec, WorkloadSpec,
                       degrade_sweep, run)
from repro.api.registry import build_network
from repro.simulator.engine import SimConfig, Simulator, Traffic

TOPO = mrls(n_leaves=14, u=3, d=3, seed=0)
GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "engine_parity.json")
    .read_text())
MASK_LAYOUTS = ("dense", pytest.param("blocked", marks=pytest.mark.slow))


def _link_events(topo, k, *, down_slot=0, seed=0):
    return FailureSchedule.random_links(topo, k, down_slot=down_slot,
                                        seed=seed).events


# ---------------------------------------------------------------------- #
# schedule spec layer
# ---------------------------------------------------------------------- #
def test_event_validation():
    FailureEvent("link", 3, 10)                       # transient failure ok
    FailureEvent("switch", 0, 0, up_slot=5)
    with pytest.raises(ValueError, match="kind"):
        FailureEvent("cable", 0, 0)
    with pytest.raises(ValueError, match="id"):
        FailureEvent("link", -1, 0)
    with pytest.raises(ValueError, match="up_slot"):
        FailureEvent("link", 0, 10, up_slot=10)


def test_schedule_json_round_trip():
    sched = FailureSchedule(
        events=(FailureEvent("link", 7, 5, up_slot=40),
                FailureEvent("switch", 14, 12)),
        policy="drop")
    back = FailureSchedule.from_json(sched.to_json())
    assert back == sched
    # permanent failures omit up_slot from the JSON
    d = sched.to_dict()
    assert "up_slot" not in d["events"][1]


def test_network_spec_failures_round_trip():
    sched = FailureSchedule(events=_link_events(TOPO, 2, down_slot=9))
    net = NetworkSpec("mrls", {"n_leaves": 14, "u": 3, "d": 3, "seed": 0},
                      failures=sched)
    back = NetworkSpec.from_dict(json.loads(json.dumps(net.to_dict())))
    assert back == net
    assert back.failures == sched
    # no schedule -> no key in the dict (older specs parse unchanged)
    bare = dataclasses.replace(net, failures=None)
    assert "failures" not in bare.to_dict()


def test_schedule_validate():
    n, p = TOPO.n_switches, TOPO.max_ports
    good = FailureSchedule(events=_link_events(TOPO, 1))
    assert good.validate(TOPO) is good
    with pytest.raises(ValueError, match="link"):
        FailureSchedule(events=(FailureEvent("link", n * p, 0),)) \
            .validate(TOPO)
    # an unconnected port slot is not a link
    dead = int(np.nonzero(TOPO.nbrs.reshape(-1) < 0)[0][0])
    with pytest.raises(ValueError, match="link"):
        FailureSchedule(events=(FailureEvent("link", dead, 0),)) \
            .validate(TOPO)
    leaf = int(TOPO.leaf_ids[0])
    with pytest.raises(ValueError, match="leaf"):
        FailureSchedule(events=(FailureEvent("switch", leaf, 0),)) \
            .validate(TOPO)


def test_random_links_deterministic_and_canonical():
    canon = set(int(i) for i in canonical_link_ids(TOPO))
    a = FailureSchedule.random_links(TOPO, 5, down_slot=3, seed=11)
    b = FailureSchedule.random_links(TOPO, 5, down_slot=3, seed=11)
    c = FailureSchedule.random_links(TOPO, 5, down_slot=3, seed=12)
    assert a == b and a != c
    assert len(a) == 5
    assert all(ev.kind == "link" and ev.id in canon for ev in a.events)
    assert len({ev.id for ev in a.events}) == 5        # no repeats


def test_random_ladder_slots():
    sched = FailureSchedule.random_ladder(TOPO, 3, start_slot=10,
                                          step_slots=7, seed=2)
    assert [ev.down_slot for ev in sched.events] == [10, 17, 24]


def test_transitions_grouped_and_sorted():
    sched = FailureSchedule(events=(
        FailureEvent("link", 3, 20, up_slot=50),
        FailureEvent("link", 9, 20),
        FailureEvent("switch", 15, 35)))
    trans = sched.transitions()
    assert [t[0] for t in trans] == [20, 35, 50]
    assert len(trans[0][1]) == 2 and not trans[0][2]   # two downs at 20
    assert not trans[2][1] and len(trans[2][2]) == 1   # one up at 50


# ---------------------------------------------------------------------- #
# delta rebuilds vs full rebuild on the pruned topology
# ---------------------------------------------------------------------- #
def _dead_arrays(topo, events):
    n, p = topo.n_switches, topo.max_ports
    dead_ports = np.zeros((n, p), bool)
    sw_up = np.ones(n, bool)
    for ev in events:
        if ev.kind == "switch":
            sw_up[ev.id] = False
            continue
        c, pt = divmod(ev.id, p)
        dead_ports[c, pt] = True
        dead_ports[int(topo.nbrs[c, pt]), int(topo.nbr_port[c, pt])] = True
    return dead_ports, sw_up


def _pruned(topo, dead_ports, sw_up):
    valid = topo.nbrs >= 0
    nbr_safe = np.where(valid, topo.nbrs, 0)
    eff = topo.nbrs.copy()
    eff[dead_ports] = -1
    eff[~sw_up] = -1
    eff[valid & ~sw_up[nbr_safe]] = -1
    effp = np.where(eff >= 0, topo.nbr_port, -1)
    return dataclasses.replace(topo, nbrs=eff, nbr_port=effp)


def _port_words(live):
    """[N, P] bool -> [N, W] uint32 in _pack_mask_block bit order."""
    n, p = live.shape
    w = (p + 31) // 32
    words = np.zeros((n, w), np.uint32)
    for j in range(p):
        words[:, j // 32] |= live[:, j].astype(np.uint32) << np.uint32(j % 32)
    return words


def _assert_matches_pruned(tables, topo, events):
    dead_ports, sw_up = _dead_arrays(topo, events)
    ref = build_tables(_pruned(topo, dead_ports, sw_up), masks="dense")
    ref_dist = np.where(ref.dist_leaf < 0, UNREACHABLE,
                        ref.dist_leaf).astype(np.int16)
    np.testing.assert_array_equal(tables.dist_leaf, ref_dist)
    # masks agree wherever a live port exists (dead-port bits are
    # intentionally retained -- the engine's up-mask excludes them)
    valid = topo.nbrs >= 0
    nbr_safe = np.where(valid, topo.nbrs, 0)
    live = valid & ~dead_ports & sw_up[:, None] & sw_up[nbr_safe]
    lw = _port_words(live)[None]
    np.testing.assert_array_equal(tables.min_mask & lw, ref.min_mask & lw)
    np.testing.assert_array_equal(tables.away_mask & lw,
                                  ref.away_mask & lw)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_matches_full_rebuild_and_restores(seed):
    tables = build_tables(TOPO, masks="dense")
    pristine = (tables.dist_leaf.copy(), tables.min_mask.copy(),
                tables.away_mask.copy())
    events = _link_events(TOPO, 4, seed=seed)
    delta = tables.apply_failures(down=events)
    assert 0 < delta.n_affected <= TOPO.n_leaves
    assert delta.link_up.sum() == (TOPO.nbrs >= 0).sum() - 2 * len(events)
    _assert_matches_pruned(tables, TOPO, events)
    # restore every link -> pristine, bitwise
    d2 = tables.apply_failures(up=events)
    assert d2.link_up.sum() == (TOPO.nbrs >= 0).sum()
    np.testing.assert_array_equal(tables.dist_leaf, pristine[0])
    np.testing.assert_array_equal(tables.min_mask, pristine[1])
    np.testing.assert_array_equal(tables.away_mask, pristine[2])


def test_switch_failure_recomputes_every_leaf():
    tables = build_tables(TOPO, masks="dense")
    spine = int(np.nonzero(~TOPO.is_leaf)[0][0])
    ev = FailureEvent("switch", spine, 0)
    delta = tables.apply_failures(down=(ev,))
    assert delta.n_affected == TOPO.n_leaves
    assert not delta.switch_up[spine]
    _assert_matches_pruned(tables, TOPO, (ev,))
    tables.apply_failures(up=(ev,))
    ref = build_tables(TOPO, masks="dense")
    np.testing.assert_array_equal(tables.dist_leaf, ref.dist_leaf)


def test_duplicate_and_noop_events_are_safe():
    tables = build_tables(TOPO, masks="dense")
    ev = _link_events(TOPO, 1, seed=3)
    tables.apply_failures(down=ev)
    again = tables.apply_failures(down=ev)             # already dead
    assert again.n_affected == 0
    tables.apply_failures(up=ev)
    noop = tables.apply_failures(up=ev)                # already up
    assert noop.n_affected == 0
    assert noop.link_up.sum() == (TOPO.nbrs >= 0).sum()


def test_blocked_layout_delta_keeps_streamed_blocks_consistent():
    dense = build_tables(TOPO, masks="dense")
    blocked = build_tables(TOPO, masks="blocked", leaf_block=4)
    events = _link_events(TOPO, 3, seed=5)
    dd = dense.apply_failures(down=events)
    bd = blocked.apply_failures(down=events)
    np.testing.assert_array_equal(bd.dist_rows, dd.dist_rows)
    np.testing.assert_array_equal(bd.min_rows, dd.min_rows)
    np.testing.assert_array_equal(blocked.dist_leaf, dense.dist_leaf)
    # streamed blocks repack from the mutated distances
    got = np.concatenate([b for _, _, b, _ in blocked.mask_blocks()])
    np.testing.assert_array_equal(got, dense.min_mask)


# ---------------------------------------------------------------------- #
# live engine: zero-failure parity, degraded policy, resilience runs
# ---------------------------------------------------------------------- #
@pytest.fixture(scope="module", params=MASK_LAYOUTS)
def golden_tables(request):
    return build_tables(mrls(**GOLDEN["fabric"]), masks=request.param)


@pytest.mark.parametrize(
    "policy", ["polarized",
               pytest.param("minimal_adaptive", marks=pytest.mark.slow)])
def test_empty_schedule_replays_golden_bitwise(golden_tables, policy):
    """An empty FailureSchedule must leave the engine on the static
    no-failure branch: the committed golden replays bitwise."""
    gp = GOLDEN["policies"][policy]
    cfg = SimConfig(policy=policy, max_hops=10, pool=4096)
    with Simulator(golden_tables, cfg, failures=FailureSchedule()) as sim:
        assert not sim.has_failures
        thr = sim.run_throughput(Traffic("uniform", load=0.7),
                                 warm=GOLDEN["warm"],
                                 measure=GOLDEN["measure"], seed=0)
    assert thr["throughput"] == gp["throughput"]      # bitwise, no approx
    assert thr["avg_hops"] == gp["avg_hops"]
    assert thr["ejected"] == gp["ejected"]
    assert thr["pool_stall"] == gp["pool_stall"]


@pytest.mark.slow
def test_empty_schedule_replays_collective_golden_bitwise():
    from repro.workloads import compile_program, rabenseifner_program
    coll = json.loads(
        (pathlib.Path(__file__).parent / "golden" /
         "collective_parity.json").read_text())
    gp = coll["policies"]["polarized"]
    tb = build_tables(mrls(**coll["fabric"]))
    cfg = SimConfig(policy="polarized", max_hops=10, pool=4096)
    with Simulator(tb, cfg, failures=FailureSchedule()) as sim:
        cp = compile_program(
            rabenseifner_program(sim.S, coll["ranks"], coll["vec_packets"]),
            schedule="barrier")
        r = sim.run_program(cp, chunk=coll["chunk"],
                            max_slots=coll["max_slots"], seed=coll["seed"])
    assert int(r["slots"]) == gp["slots"]
    assert [int(s) for s in r["phase_slots"]] == gp["phase_slots"]
    assert int(r["pool_stall"]) == gp["pool_stall"]


def test_degraded_pristine_is_bitwise_minimal_adaptive():
    tb = build_tables(TOPO)
    tr = Traffic("uniform", load=0.7)
    out = {}
    for pol in ("minimal_adaptive", "degraded"):
        with Simulator(tb, SimConfig(policy=pol, max_hops=10,
                                     pool=4096)) as sim:
            out[pol] = sim.run_throughput(tr, warm=30, measure=60, seed=0)
    assert out["degraded"]["throughput"] == \
        out["minimal_adaptive"]["throughput"]
    assert out["degraded"]["ejected"] == out["minimal_adaptive"]["ejected"]
    assert out["degraded"]["avg_hops"] == \
        out["minimal_adaptive"]["avg_hops"]


def test_armed_future_schedule_is_value_identical():
    """Arming a schedule whose first event lies beyond the run moves the
    tables into the state but must not change any result value (the
    failure branches consume no extra PRNG keys by design)."""
    tb = build_tables(TOPO)
    tr = Traffic("uniform", load=0.7)
    sched = FailureSchedule(events=_link_events(TOPO, 2, down_slot=10_000))
    cfg = SimConfig(policy="polarized", max_hops=10, pool=4096)
    with Simulator(tb, cfg) as sim:
        ref = sim.run_throughput(tr, warm=30, measure=60, seed=0)
    with Simulator(tb, cfg, failures=sched) as sim:
        assert sim.has_failures
        got = sim.run_throughput(tr, warm=30, measure=60, seed=0)
    for k in ("throughput", "avg_hops", "ejected", "pool_stall"):
        assert got[k] == ref[k], k


def test_run_resilience_end_to_end_and_restores_tables():
    tb = build_tables(TOPO)
    pristine = tb.dist_leaf.copy()
    sched = FailureSchedule(events=tuple(
        dataclasses.replace(ev, down_slot=20, up_slot=60)
        for ev in _link_events(TOPO, 5, seed=7)))
    cfg = SimConfig(policy="degraded", max_hops=12, pool=4096)
    with Simulator(tb, cfg, failures=sched) as sim:
        r = sim.run_resilience(Traffic("uniform", load=0.5),
                               warm=40, measure=80, seed=0)
    assert 0.0 < r["throughput"] <= 1.0
    assert r["ejected"] > 0
    assert r["fail_drop"] == 0                        # requeue never drops
    assert r["p0.5"] > 0
    # transient failure window fully unwound: tables pristine again
    np.testing.assert_array_equal(tb.dist_leaf, pristine)
    assert not tb.dead_ports.any()


def test_drop_policy_frees_stranded_packets():
    tb = build_tables(TOPO)
    # the failure lands inside the measure window -- counters report the
    # windowed delta, so a warm-phase drop would read as zero
    sched = FailureSchedule(events=_link_events(TOPO, 10, down_slot=30),
                            policy="drop")
    cfg = SimConfig(policy="degraded", max_hops=12, pool=4096)
    with Simulator(tb, cfg, failures=sched) as sim:
        r = sim.run_resilience(Traffic("uniform", load=0.9),
                               warm=20, measure=60, seed=0)
    assert r["fail_drop"] > 0
    assert 0.0 < r["throughput"] <= 1.0


def test_failure_apis_require_armed_simulator():
    tb = build_tables(TOPO)
    with Simulator(tb, SimConfig(policy="polarized", pool=4096)) as sim:
        st = sim.make_state(Traffic("uniform", load=0.5), 0)
        delta = tb.apply_failures()
        with pytest.raises(RuntimeError, match="failure schedule"):
            sim.update_tables(st, delta)
        with pytest.raises(ValueError, match="FailureSchedule"):
            sim.run_resilience(Traffic("uniform", load=0.5))


# ---------------------------------------------------------------------- #
# driver layer: resilience metric + degradation sweep
# ---------------------------------------------------------------------- #
NET = NetworkSpec("mrls", {"n_leaves": 14, "u": 3, "d": 3, "seed": 0})
DEGRADED = RouteSpec(policy="degraded", max_hops=12, pool=4096)


def test_resilience_metric_through_run():
    topo = build_network(NET)
    sched = FailureSchedule.random_links(topo, 3, down_slot=10, seed=1)
    exp = Experiment(network=dataclasses.replace(NET, failures=sched),
                     route=DEGRADED,
                     workload=WorkloadSpec("uniform", load=0.5),
                     warm=30, measure=60, seed=0)
    assert exp.resolved_metric() == "resilience"
    res = run(exp)
    assert res.metric == "resilience"
    assert 0.0 < res.throughput <= 1.0
    assert res.fail_drop == 0
    assert res.latency["p50"] is not None
    back = Result_round_trip(res)
    assert back.fail_drop == res.fail_drop


def Result_round_trip(res):
    from repro.api import Result
    return Result.from_dict(json.loads(json.dumps(res.to_dict())))


def test_degrade_sweep_retention_curve():
    base = Experiment(network=NET, route=DEGRADED,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=30, measure=60, seed=0)
    from repro.api import DegradeSpec
    rec = degrade_sweep(DegradeSpec(base=base, rates=(0.0, 0.10),
                                    fail_seed=4))
    assert rec["n_links"] == len(canonical_link_ids(build_network(NET)))
    assert [p["rate"] for p in rec["points"]] == [0.0, 0.10]
    assert rec["points"][0]["n_links_down"] == 0
    assert rec["points"][0]["retention"] == 1.0
    assert rec["points"][1]["n_links_down"] > 0
    for p in rec["points"]:
        assert 0.0 < p["delivered"] <= 1.0
        assert p["retention"] > 0.0


@pytest.mark.slow
def test_cli_degrade_smoke(tmp_path, capsys):
    from repro.api.cli import main
    spec = tmp_path / "degrade.json"
    base = Experiment(network=NET, route=DEGRADED,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=30, measure=60, seed=0)
    spec.write_text(json.dumps({"base": base.to_dict(),
                                "rates": [0.0, 0.05]}))
    out = tmp_path / "faults.json"
    assert main(["degrade", str(spec), "--out", str(out)]) == 0
    records = json.loads(out.read_text())
    assert len(records) == 1 and len(records[0]["points"]) == 2
    assert "retention=" in capsys.readouterr().out


# ---------------------------------------------------------------------- #
# runtime satellites: straggler EMA fix + schedule-driven fault hook
# ---------------------------------------------------------------------- #
def test_straggler_warmup_boundary():
    from repro.runtime.fault_tolerance import FTConfig, StragglerDetector

    W = StragglerDetector.WARMUP
    det = StragglerDetector(FTConfig())
    for i in range(W - 1):
        assert det.observe(i, 1.0) is False
    # n == WARMUP: still inside warmup, a huge step must NOT flag
    assert det.observe(W - 1, 100.0) is False
    assert det.n == W and det.flagged == []
    # n == WARMUP + 1: first eligible observation
    det2 = StragglerDetector(FTConfig())
    for i in range(W):
        det2.observe(i, 1.0)
    assert det2.observe(W, 100.0) is True
    assert det2.flagged == [(W, 100.0)]


def test_straggler_variance_uses_preupdate_residual():
    from repro.runtime.fault_tolerance import FTConfig, StragglerDetector

    det = StragglerDetector(FTConfig(ema=0.9))
    det.observe(0, 1.0)                               # seeds mean only
    det.observe(1, 2.0)
    # resid vs the PRE-update mean: (2.0 - 1.0)^2 * 0.1 = 0.1; the old
    # post-update residual gave (2.0 - 1.1)^2 * 0.1 = 0.081
    assert det.mean == pytest.approx(1.1)
    assert det.var == pytest.approx(0.1)
    # constant inputs keep variance at zero
    det3 = StragglerDetector(FTConfig(ema=0.9))
    for i in range(10):
        det3.observe(i, 3.0)
    assert det3.var == 0.0 and det3.mean == 3.0


def test_schedule_fault_hook_applies_transitions_on_step_clock():
    import jax
    from repro.runtime.fault_tolerance import schedule_fault_hook

    tb = build_tables(TOPO)
    events = _link_events(TOPO, 2, down_slot=3, seed=6)
    sched = FailureSchedule(events=events)
    cfg = SimConfig(policy="degraded", max_hops=12, pool=4096)
    with Simulator(tb, cfg, failures=sched) as sim:
        tr = Traffic("uniform", load=0.5)
        holder = [sim.make_state(tr, 0)]
        full = int(np.asarray(jax.device_get(holder[0]["link_up"])).sum())
        hook = schedule_fault_hook(sim, holder, slots_per_step=2)
        hook(0)                                       # boundary 2 < slot 3
        assert int(jax.device_get(holder[0]["link_up"]).sum()) == full
        hook(1)                                       # boundary 4 >= slot 3
        assert (int(jax.device_get(holder[0]["link_up"]).sum())
                == full - 2 * len(events))
        holder[0] = sim.run_chunk(holder[0], tr, 8)   # still runs
    tb.apply_failures(up=events)                      # leave tables clean

    with Simulator(tb, SimConfig(policy="polarized", pool=4096)) as sim:
        with pytest.raises(ValueError, match="FailureSchedule"):
            schedule_fault_hook(sim, [None])
