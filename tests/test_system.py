"""End-to-end behaviour: training converges, serving generates, the fabric
planner consumes dry-run records, HLO stats account loop trip counts."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import build_training
from repro.launch.serve import ServeSession
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.parallel.sharding import Sharder


@pytest.mark.slow
def test_train_loss_decreases(tmp_path, mesh, sharder):
    """~50 steps on the structured synthetic stream must reduce loss."""
    cfg = reduced(REGISTRY["qwen3-1.7b"])
    steps = 50
    opt = AdamWConfig(lr=1e-3, schedule=warmup_cosine(5, steps))
    data = SyntheticLM(DataConfig(cfg.vocab, seq=64, global_batch=4), sharder)
    with jax.set_mesh(mesh):
        state, runner, ckpt = build_training(
            cfg, sharder, opt, str(tmp_path), data)
        state, step, hist = runner.run(state, 0, steps)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert step == steps
    assert last < first - 0.3, (first, last)


def test_train_survives_mid_run_fault(tmp_path, mesh, sharder):
    cfg = reduced(REGISTRY["qwen3-1.7b"])
    opt = AdamWConfig(lr=1e-3)
    data = SyntheticLM(DataConfig(cfg.vocab, seq=32, global_batch=2), sharder)
    crashed = {"done": False}

    def fault_hook(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated preemption")

    from repro.runtime.fault_tolerance import FTConfig
    with jax.set_mesh(mesh):
        state, runner, ckpt = build_training(
            cfg, sharder, opt, str(tmp_path), data,
            ft=FTConfig(ckpt_every=5, max_retries=2),
            fault_hook=fault_hook)
        state, step, hist = runner.run(state, 0, 20)
    assert step == 20 and runner.restarts == 1


def test_serve_generates(mesh, sharder):
    cfg = reduced(REGISTRY["qwen3-1.7b"])
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 16), dtype=np.int32)
    with jax.set_mesh(mesh):
        sess = ServeSession(cfg, sharder)
        toks = sess.generate(prompts, max_new=4)
        toks2 = sess.generate(prompts, max_new=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()
    np.testing.assert_array_equal(toks, toks2)      # deterministic greedy


def test_fabric_planner_prefers_mrls_for_all2all():
    from repro.fabric.planner import plan_pod_axis
    rec = {"per_device": {"collective_bytes": {
        "all-to-all": 5e9, "all-reduce": 1e8}}}
    plan = plan_pod_axis(rec, n_pod_endpoints=512, compute_s=0.01)
    assert plan.recommended_fabric == "mrls"
    assert plan.compress_gradients


def test_hlo_stats_counts_loop_trips():
    """A 10-iteration scanned matmul must be counted 10x (the XLA
    cost_analysis undercount this module exists to fix)."""
    from repro.launch import hlo_stats

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    stats = hlo_stats.analyze(comp.as_text())
    want = 10 * 2 * 128 * 256 * 256
    assert abs(stats["flops"] - want) / want < 0.01
    ca = hlo_stats.cost_analysis_dict(comp)
    assert ca["flops"] < want / 5         # XLA counts the body once


def test_dryrun_json_schema():
    """Any completed dry-run cells must carry the roofline fields."""
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run results yet")
    n = 0
    for name in sorted(os.listdir(d)):
        rec = json.load(open(os.path.join(d, name)))
        if rec.get("status") != "ok":
            continue
        n += 1
        r = rec["roofline"]
        assert set(r) >= {"compute_s", "memory_s", "collective_s",
                          "dominant", "bound_s"}
        assert rec["per_device"]["flops"] > 0
    if n == 0:
        pytest.skip("no ok cells yet")
