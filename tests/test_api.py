"""repro.api: registry coverage, spec serialization, run()/sweep() parity."""
import json

import numpy as np
import pytest

from repro.api import (
    Experiment, NetworkSpec, Result, RouteSpec, SimulatorCache, WorkloadSpec,
    build_network, expand_axes, open_simulator, register_topology, run,
    sweep, topology_families,
)
from repro.core import build_tables, mrls
from repro.simulator.engine import SimConfig, Simulator, Traffic

TINY = NetworkSpec("mrls", {"n_leaves": 14, "u": 3, "d": 3, "seed": 0})
ROUTE = RouteSpec(policy="polarized", max_hops=10, pool=4096)

# one buildable spec per registered family (tiny instances)
FAMILY_SPECS = {
    "mrls": TINY,
    "fat_tree": NetworkSpec("fat_tree", {"radix": 4, "h": 1}),
    "oft": NetworkSpec("oft", {"q": 2}),
    "dragonfly": NetworkSpec("dragonfly", {"a": 2, "p": 1, "h": 1}),
    "dragonfly_plus": NetworkSpec("dragonfly_plus", {
        "n_groups": 3, "leaves_per_group": 2, "spines_per_group": 2,
        "p": 2, "global_per_spine": 1}),
    "rfc": NetworkSpec("rfc", {"n_leaves": 6, "u": 4, "d": 2, "seed": 0}),
}


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
def test_registry_lists_all_six_families():
    assert set(FAMILY_SPECS) <= set(topology_families())


@pytest.mark.parametrize("family", sorted(FAMILY_SPECS))
def test_registry_builds_every_family(family):
    topo = build_network(FAMILY_SPECS[family])
    topo.validate()
    assert topo.n_endpoints > 0


def test_registry_unknown_family():
    with pytest.raises(KeyError, match="unknown topology family"):
        build_network(NetworkSpec("torus", {}))


def test_register_topology_roundtrip():
    register_topology("tiny_mrls_alias", mrls, overwrite=True)
    topo = build_network(NetworkSpec("tiny_mrls_alias",
                                     {"n_leaves": 14, "u": 3, "d": 3}))
    assert topo.n_leaves == 14
    register_topology("mrls", mrls)      # same builder: idempotent no-op
    with pytest.raises(ValueError, match="already registered"):
        register_topology("mrls", lambda **kw: None)   # conflicting builder


# ---------------------------------------------------------------------- #
# spec serialization
# ---------------------------------------------------------------------- #
def test_experiment_json_roundtrip_lossless():
    exp = Experiment(
        network=TINY, route=ROUTE,
        workload=WorkloadSpec("mice_elephant", load=0.4, elephant_frac=0.2),
        name="rt", metric="latency", seed=3, warm=10, measure=20,
        chunk=8, max_slots=123,
    )
    again = Experiment.from_json(exp.to_json())
    assert again == exp
    assert hash(again) == hash(exp)
    # dict form is plain-JSON (no tuples) and stable under a second trip
    d = json.loads(exp.to_json())
    assert d["network"]["params"] == {"n_leaves": 14, "u": 3, "d": 3,
                                      "seed": 0}
    assert Experiment.from_dict(d) == exp


def test_latency_result_uniformly_float_json_roundtrip():
    # Result.latency values are uniformly float (None for empty windows) —
    # never a mix of int and float — and survive a JSON round trip intact
    exp = Experiment(network=TINY, route=ROUTE, metric="latency",
                     warm=10, measure=20)
    res = Result(experiment=exp, metric="latency",
                 latency={"p50": 12.0, "p99": 30.0, "p9999": None})
    again = Result.from_json(res.to_json())
    assert again == res
    assert all(v is None or type(v) is float
               for v in again.latency.values())


def test_latency_run_emits_floats():
    exp = Experiment(network=TINY, route=ROUTE, metric="latency",
                     workload=WorkloadSpec("uniform", load=0.5),
                     warm=30, measure=60)
    res = run(exp)
    assert res.latency is not None
    assert all(v is None or type(v) is float for v in res.latency.values())
    again = Result.from_json(res.to_json())
    assert again.latency == res.latency


def test_route_spec_backend_round_trips_and_reaches_sim_config():
    r = RouteSpec(policy="polarized", backend="pallas")
    assert RouteSpec.from_dict(r.to_dict()) == r
    assert r.to_sim_config().backend == "pallas"
    assert RouteSpec().to_sim_config().backend == "xla"


def test_network_spec_param_order_insensitive():
    a = NetworkSpec("mrls", {"u": 3, "n_leaves": 14, "d": 3})
    b = NetworkSpec("mrls", {"d": 3, "u": 3, "n_leaves": 14})
    assert a == b and hash(a) == hash(b)


def test_workload_rejects_unknown_pattern():
    with pytest.raises(ValueError, match="unknown pattern"):
        WorkloadSpec("phase")


def test_workload_all2all_requires_rounds():
    with pytest.raises(ValueError, match="rounds > 0"):
        WorkloadSpec("all2all")


def test_workload_allreduce_requires_pow2_ranks():
    with pytest.raises(ValueError, match="power of two"):
        WorkloadSpec("allreduce", ranks=12)
    assert WorkloadSpec("allreduce", ranks=16).ranks == 16


def test_network_spec_rejects_nested_non_scalars():
    with pytest.raises(TypeError, match="JSON scalar"):
        NetworkSpec("mrls", {"m": [[1, 2], {"a": 1}]})
    nested = NetworkSpec("mrls", {"m": [1, 2]})
    hash(nested)                              # lists frozen recursively


def test_experiment_override_paths():
    exp = Experiment(network=TINY)
    assert exp.override("seed", 7).seed == 7
    assert exp.override("workload.load", 0.3).workload.load == 0.3
    assert exp.override("route.policy", "ksp").route.policy == "ksp"
    assert exp.override("network.params.u", 6).network.param_dict()["u"] == 6


# ---------------------------------------------------------------------- #
# run() parity with the hand-wired Simulator path
# ---------------------------------------------------------------------- #
def test_run_matches_handwired_simulator():
    exp = Experiment(network=TINY, route=ROUTE,
                     workload=WorkloadSpec("uniform", load=0.5),
                     warm=60, measure=100)
    res = run(exp)

    sim = Simulator(build_tables(mrls(14, u=3, d=3, seed=0)),
                    SimConfig(policy="polarized", max_hops=10, pool=4096))
    with sim:
        ref = sim.run_throughput(Traffic("uniform", load=0.5),
                                 warm=60, measure=100)
    assert res.throughput == pytest.approx(ref["throughput"])
    assert res.avg_hops == pytest.approx(ref["avg_hops"])
    assert res.ejected == int(ref["ejected"])


def test_run_allreduce_first_class():
    exp = Experiment(network=TINY, route=ROUTE,
                     workload=WorkloadSpec("allreduce", ranks=16,
                                           vec_packets=8),
                     max_slots=3000)
    res = run(exp)
    assert res.metric == "completion"
    assert res.completed
    assert res.slots == sum(res.phase_slots)
    assert len(res.phase_slots) == 2 * 4          # log2(16) each direction
    # completion counts ALL deliveries (incl. self-partnered local ones), so
    # no phase can finish faster than its per-endpoint packet count
    from repro.core.collectives import rabenseifner_phases
    assert all(s >= ph["packets"] for s, ph in
               zip(res.phase_slots, rabenseifner_phases(16, 8)))
    # result record JSON round-trips
    again = Result.from_json(res.to_json())
    assert again == res


def test_run_result_metric_auto():
    a2a = Experiment(network=TINY, route=ROUTE,
                     workload=WorkloadSpec("all2all", rounds=2),
                     max_slots=2000)
    assert a2a.resolved_metric() == "completion"
    res = run(a2a)
    assert res.completed and res.slots >= 2


# ---------------------------------------------------------------------- #
# sweep
# ---------------------------------------------------------------------- #
@pytest.mark.slow
def test_sweep_one_result_per_grid_point():
    base = Experiment(network=TINY, route=ROUTE,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=20, measure=40)
    axes = {"workload.load": [0.2, 0.4], "seed": [0, 1, 2]}
    results = sweep(base, axes)
    assert len(results) == 6
    got = {(r.experiment.workload.load, r.experiment.seed) for r in results}
    assert got == {(l, s) for l in (0.2, 0.4) for s in (0, 1, 2)}
    assert all(r.throughput is not None for r in results)


@pytest.mark.slow
def test_sweep_reuses_simulators_per_fabric():
    base = Experiment(network=TINY, route=ROUTE,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=10, measure=20)
    cache = SimulatorCache()
    sweep(base, {"workload.load": [0.2, 0.4], "seed": [0, 1]}, cache=cache)
    assert len(cache) == 1                 # one fabric -> one simulator
    sweep(base, {"route.policy": ["polarized", "ksp"]}, cache=cache)
    assert len(cache) == 2                 # new policy -> one more
    cache.close()
    assert len(cache) == 0


def test_expand_axes_fabric_outermost():
    base = Experiment(network=TINY, route=ROUTE)
    grid = expand_axes(base, {"seed": [0, 1],
                              "route.policy": ["polarized", "ksp"]})
    # fabric axis must vary slowest so consecutive points share simulators
    policies = [e.route.policy for e in grid]
    assert policies == ["polarized", "polarized", "ksp", "ksp"]


def test_expand_axes_seed_varies_fastest():
    # seed innermost regardless of insertion order, so run_all can fold
    # each seed-only stretch into one batched run
    base = Experiment(network=TINY, route=ROUTE)
    grid = expand_axes(base, {"seed": [0, 1], "workload.load": [0.2, 0.4]})
    coords = [(e.workload.load, e.seed) for e in grid]
    assert coords == [(0.2, 0), (0.2, 1), (0.4, 0), (0.4, 1)]


def test_expand_axes_relabels_named_base():
    base = Experiment(network=TINY, route=ROUTE, name="fig.base")
    grid = expand_axes(base, {"route.policy": ["polarized", "ksp"]})
    names = [e.label() for e in grid]
    assert names == ["fig.base[route.policy=polarized]",
                     "fig.base[route.policy=ksp]"]


# ---------------------------------------------------------------------- #
# batched replicas: vmapped runs must match scalar runs bitwise
# ---------------------------------------------------------------------- #
FT = NetworkSpec("fat_tree", {"radix": 4, "h": 1})
FT_ROUTE = RouteSpec(policy="minimal_adaptive", max_hops=4, pool=4096)


@pytest.mark.parametrize("net,route", [(TINY, ROUTE), (FT, FT_ROUTE)],
                         ids=["mrls", "fat_tree"])
@pytest.mark.slow
def test_batched_throughput_parity_with_scalar(net, route):
    base = dict(network=net, route=route,
                workload=WorkloadSpec("uniform", load=0.5),
                warm=30, measure=60)
    with SimulatorCache() as cache:
        res = run(Experiment(replicas=4, seed=1, **base), cache=cache)
        assert res.replica_seeds == (1, 2, 3, 4)
        for i, s in enumerate(res.replica_seeds):
            ref = run(Experiment(seed=s, **base), cache=cache)
            # bitwise, not approx: replica i IS the scalar run with seed s
            assert res.per_replica["throughput"][i] == ref.throughput
            assert res.per_replica["avg_hops"][i] == ref.avg_hops
            assert res.per_replica["ejected"][i] == ref.ejected
    agg = res.aggregates["throughput"]
    assert agg["min"] <= res.throughput <= agg["max"]
    assert res.throughput == pytest.approx(
        np.mean(res.per_replica["throughput"]))


@pytest.mark.parametrize("net,route", [(TINY, ROUTE), (FT, FT_ROUTE)],
                         ids=["mrls", "fat_tree"])
def test_batched_completion_parity_and_exact_slots(net, route):
    base = dict(network=net, route=route,
                workload=WorkloadSpec("all2all", rounds=3),
                chunk=64, max_slots=4000)
    with SimulatorCache() as cache:
        res = run(Experiment(replicas=4, **base), cache=cache)
        assert res.completed
        sim = cache.get(net, route)
        for i, s in enumerate(res.replica_seeds):
            ref = run(Experiment(seed=s, **base), cache=cache)
            assert res.per_replica["slots"][i] == ref.slots      # bitwise
            assert res.per_replica["completed"][i] == ref.completed
            # exact completion slot <= the old chunk-granular loop's value
            tr = Traffic("all2all", rounds=3)
            st = sim.make_state(tr, seed=s)
            while int(st["slot"]) < 4000:
                st = sim.run_chunk(st, tr, 64)
                if int(st["ejected"]) >= sim.S * 3:
                    break
            old_chunk_granular = int(st["slot"])
            assert ref.slots <= old_chunk_granular < ref.slots + 64


def test_batched_allreduce_parity_with_scalar():
    base = dict(network=TINY, route=ROUTE,
                workload=WorkloadSpec("allreduce", ranks=16, vec_packets=8),
                max_slots=3000)
    with SimulatorCache() as cache:
        res = run(Experiment(replicas=2, **base), cache=cache)
        assert res.completed and res.metric == "completion"
        for i, s in enumerate(res.replica_seeds):
            ref = run(Experiment(seed=s, **base), cache=cache)
            assert res.per_replica["slots"][i] == ref.slots
            assert res.per_replica["phase_slots"][i] == ref.phase_slots


def test_batched_collective_result_json_roundtrip_and_aggregates():
    # a batched (replicas=R) collective Result carries per-replica
    # phase_slots tuples + slots aggregates, and survives a JSON round
    # trip losslessly
    res = run(Experiment(network=TINY, route=ROUTE,
                         workload=WorkloadSpec("allreduce", ranks=16,
                                               vec_packets=8),
                         max_slots=3000, replicas=3, seed=2))
    assert res.replica_seeds == (2, 3, 4)
    rows = res.per_replica["phase_slots"]
    assert len(rows) == 3 and all(len(row) == 8 for row in rows)
    assert all(isinstance(v, int) for row in rows for v in row)
    # scalar conveniences are across-replica means; phase_slots means are
    # per-phase columns
    assert set(res.aggregates) >= {"slots", "pool_stall"}
    assert res.slots == pytest.approx(res.aggregates["slots"]["mean"])
    assert res.phase_slots == tuple(
        pytest.approx(np.mean([row[i] for row in rows]))
        for i in range(8))
    per_rep_totals = [sum(row) for row in rows]
    assert list(res.per_replica["slots"]) == per_rep_totals
    again = Result.from_json(res.to_json())
    assert again == res
    assert again.per_replica["phase_slots"] == rows


@pytest.mark.slow
def test_run_new_collectives_end_to_end():
    with SimulatorCache() as cache:
        for wl in (WorkloadSpec("ring_allreduce", ranks=8, vec_packets=16),
                   WorkloadSpec("rd_allreduce", ranks=16, vec_packets=8),
                   WorkloadSpec("all2all", rounds=3, schedule="window",
                                window=3),
                   WorkloadSpec("allreduce", ranks=16, vec_packets=8,
                                schedule="window", window=4)):
            res = run(Experiment(network=TINY, route=ROUTE, workload=wl,
                                 max_slots=4000), cache=cache)
            assert res.metric == "completion" and res.completed
            assert res.slots >= 1 and res.phase_slots is not None
            assert Result.from_json(res.to_json()) == res


@pytest.mark.slow
def test_run_adversarial_bernoulli_end_to_end():
    with SimulatorCache() as cache:
        for wl in (WorkloadSpec("tornado", load=0.3),
                   WorkloadSpec("shift", load=0.3, shift=5),
                   WorkloadSpec("hotspot", load=0.3, hot_frac=0.3,
                                hot_count=2),
                   WorkloadSpec("bursty", load=0.2, burst_len=6.0,
                                burst_load=0.8)):
            res = run(Experiment(network=TINY, route=ROUTE, workload=wl,
                                 warm=20, measure=40), cache=cache)
            assert res.metric == "throughput"
            assert res.throughput is not None and res.throughput > 0


def test_batched_result_json_roundtrip():
    res = run(Experiment(network=TINY, route=ROUTE,
                         workload=WorkloadSpec("uniform", load=0.5),
                         warm=20, measure=40, replicas=3))
    assert res.replica_seeds == (0, 1, 2)
    assert set(res.aggregates) >= {"throughput", "avg_hops", "ejected"}
    again = Result.from_json(res.to_json())
    assert again == res


def test_replicas_validation_and_seeds():
    with pytest.raises(ValueError, match="replicas"):
        Experiment(network=TINY, replicas=0)
    exp = Experiment(network=TINY, seed=5, replicas=3)
    assert exp.replica_seeds() == (5, 6, 7)
    assert Experiment.from_json(exp.to_json()) == exp


@pytest.mark.slow
def test_sweep_folds_seed_axis_same_results():
    base = Experiment(network=TINY, route=ROUTE,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=20, measure=40)
    axes = {"workload.load": [0.2, 0.4], "seed": [0, 1, 2]}
    folded = sweep(base, axes)
    scalar = sweep(base, axes, fold_seeds=False)
    assert len(folded) == 6
    assert folded == scalar       # fold is an optimization, not a semantic


# ---------------------------------------------------------------------- #
# lifetime
# ---------------------------------------------------------------------- #
def test_simulator_context_manager_closes():
    with open_simulator(TINY, ROUTE) as sim:
        r = sim.run_throughput(Traffic("uniform", load=0.3),
                               warm=10, measure=20)
        assert 0 <= r["throughput"] <= 1.5
    assert sim.closed
    with pytest.raises(RuntimeError, match="closed"):
        sim.make_state(Traffic("uniform", load=0.3))


# ---------------------------------------------------------------------- #
# CLI
# ---------------------------------------------------------------------- #
def test_cli_run_spec_json(tmp_path, capsys):
    from repro.api.cli import main

    exp = Experiment(network=TINY, route=ROUTE,
                     workload=WorkloadSpec("uniform", load=0.5),
                     name="cli.tiny", warm=20, measure=40)
    spec = tmp_path / "spec.json"
    spec.write_text(exp.to_json())
    out = tmp_path / "results.json"
    assert main(["run", str(spec), "--out", str(out)]) == 0
    assert "cli.tiny" in capsys.readouterr().out
    records = json.loads(out.read_text())
    assert len(records) == 1
    res = Result.from_dict(records[0])
    assert res.experiment == exp and res.throughput is not None


def test_cli_run_replicas_flag(tmp_path, capsys):
    from repro.api.cli import main

    exp = Experiment(network=TINY, route=ROUTE,
                     workload=WorkloadSpec("uniform", load=0.5),
                     name="cli.batched", warm=20, measure=40)
    spec = tmp_path / "spec.json"
    spec.write_text(exp.to_json())
    out = tmp_path / "results.json"
    assert main(["run", str(spec), "--replicas", "2",
                 "--out", str(out)]) == 0
    assert "replicas=2" in capsys.readouterr().out
    res = Result.from_dict(json.loads(out.read_text())[0])
    assert res.experiment.replicas == 2
    assert len(res.per_replica["throughput"]) == 2


@pytest.mark.slow
def test_cli_sweep_spec_json(tmp_path):
    from repro.api.cli import main

    base = Experiment(network=TINY, route=ROUTE,
                      workload=WorkloadSpec("uniform", load=0.5),
                      warm=10, measure=20)
    doc = {"base": json.loads(base.to_json()),
           "axes": {"workload.load": [0.2, 0.5]}}
    spec = tmp_path / "sweep.json"
    spec.write_text(json.dumps(doc))
    out = tmp_path / "results.json"
    assert main(["sweep", str(spec), "--out", str(out)]) == 0
    loads = [r["experiment"]["workload"]["load"]
             for r in json.loads(out.read_text())]
    assert loads == [0.2, 0.5]


# ---------------------------------------------------------------------- #
# memory estimator (ISSUE 5)
# ---------------------------------------------------------------------- #
def test_estimate_memory_exact_table_and_state_bytes():
    from repro.api import estimate_memory

    est = estimate_memory(TINY, ROUTE)
    tb = build_tables(build_network(TINY), masks="dense")
    assert est["tables"]["dist_leaf_bytes"] == tb.dist_leaf.nbytes
    # polarized holds both device masks; dense layout retains both numpy
    # twins on the host
    assert est["tables"]["device_mask_bytes"] == (tb.min_mask.nbytes
                                                 + tb.away_mask.nbytes)
    assert est["tables"]["host_mask_bytes"] == (tb.min_mask.nbytes
                                                + tb.away_mask.nbytes)
    assert est["tables"]["mask_layout"] == "dense"
    # state estimate == the real state's array bytes, exactly
    with Simulator(tb, ROUTE.to_sim_config()) as sim:
        st = sim.make_state(Traffic("uniform", load=0.5), 0)
        counted = ("qbuf", "qhead", "qlen", "oq_buf", "oq_head", "oq_len",
                   "eq_buf", "eq_head", "eq_len", "fl_buf", "p_sd",
                   "p_mid", "p_bh", "msg_rem", "msg_dst", "prog",
                   "lat_hist")
        actual = sum(np.asarray(st[k]).nbytes for k in counted)
    assert est["state_bytes_per_replica"] == actual
    assert est["dims"]["n_endpoints"] == 42
    assert est["peak_bytes"] > est["total_bytes"] > 0


def test_estimate_memory_from_experiment_and_replicas():
    from repro.api import estimate_memory

    exp = Experiment(network=TINY, route=ROUTE, replicas=4)
    est = estimate_memory(exp)
    est1 = estimate_memory(TINY, ROUTE, replicas=1)
    assert est["replicas"] == 4
    assert (est["total_bytes"] - est1["total_bytes"]
            == 3 * est1["state_bytes_per_replica"])
    # minimal policies hold one device mask, not two
    est_min = estimate_memory(TINY, RouteSpec(policy="minimal_adaptive",
                                              pool=4096))
    assert (est_min["tables"]["device_mask_bytes"] * 2
            == est["tables"]["device_mask_bytes"])


def test_estimate_memory_prices_failure_schedule_state():
    """With a non-empty FailureSchedule the tables move into the state
    (plus live up-masks and the drop counter); the estimator's add-on
    must match the real armed state's extra array bytes exactly."""
    import dataclasses
    from repro.api import estimate_memory, FailureSchedule

    topo = build_network(TINY)
    sched = FailureSchedule.random_links(topo, 2, down_slot=10, seed=0)
    tiny_f = dataclasses.replace(TINY, failures=sched)
    est = estimate_memory(tiny_f, ROUTE)
    est0 = estimate_memory(TINY, ROUTE)
    assert est0["failures"] == {"armed": False,
                                "state_bytes_per_replica": 0}
    assert est["failures"]["armed"]
    add_on = est["failures"]["state_bytes_per_replica"]
    assert (est["state_bytes_per_replica"]
            == est0["state_bytes_per_replica"] + add_on)

    tb = build_tables(topo, masks="dense")
    with Simulator(tb, ROUTE.to_sim_config(), failures=sched) as sim:
        st = sim.make_state(Traffic("uniform", load=0.5), 0)
        extra = ("tbl_min", "tbl_away", "tbl_dist", "link_up", "switch_up",
                 "fail_drop")
        assert set(extra) <= set(st)
        actual = sum(np.asarray(st[k]).nbytes for k in extra)
    assert add_on == actual


def test_estimate_memory_resolves_blocked_layout_at_scale():
    """Above DENSE_MASK_LIMIT the estimator predicts the blocked layout
    and zero retained host-mask bytes — priced analytically, no tables
    are ever built."""
    from repro.api import estimate_memory
    from repro.core import routing as routing_mod

    old = routing_mod.DENSE_MASK_LIMIT
    try:
        routing_mod.DENSE_MASK_LIMIT = 64
        est = estimate_memory(TINY, ROUTE)
    finally:
        routing_mod.DENSE_MASK_LIMIT = old
    assert est["tables"]["mask_layout"] == "blocked"
    assert est["tables"]["host_mask_bytes"] == 0


def test_cli_estimate_spec_json(tmp_path, capsys):
    from repro.api.cli import main

    exp = Experiment(network=TINY, route=ROUTE, name="est.tiny")
    spec = tmp_path / "spec.json"
    spec.write_text(exp.to_json())
    out = tmp_path / "est.json"
    assert main(["estimate", str(spec), "--replicas", "3",
                 "--out", str(out)]) == 0
    assert "est.tiny" in capsys.readouterr().out
    rec = json.loads(out.read_text())[0]
    assert rec["name"] == "est.tiny"
    assert rec["replicas"] == 3
    assert rec["total_bytes"] > 0
