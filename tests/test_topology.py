"""Topology builders: paper Table 2 parameters + structural invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (mrls, oft, fat_tree, dragonfly, dragonfly_plus, rfc,
                        jellyfish, exact_metrics, build_tables)


def test_mrls_table2_11k():
    t = mrls(614, u=18, d=18, seed=1)
    m = exact_metrics(t)
    assert m.S == 11052
    assert abs(m.cost_links - 1.0) < 1e-9
    assert abs(m.cost_switches - 0.083) < 1e-3
    assert m.D == 4                      # paper: diameter 4
    assert abs(m.theta - 0.748) < 0.02   # paper: Θ = 0.748


def test_mrls_cost2_11664():
    t = mrls(972, u=24, d=12, seed=0)
    m = exact_metrics(t)
    assert m.S == 11664
    assert abs(m.cost_links - 2.0) < 1e-9
    assert abs(m.theta - 1.420) < 0.05   # paper: Θ = 1.420


def test_oft_q17_matches_paper():
    t = oft(17)
    m = exact_metrics(t, full=True)
    assert m.S == 11052
    assert m.D == 2 and m.D_star == 3    # paper: D=2, D*=3
    assert abs(m.theta - 1.0) < 1e-6
    assert abs(m.cost_links - 1.0) < 1e-9


def test_fat_tree_full():
    t = fat_tree(36, 2)
    m = exact_metrics(t)
    assert m.S == 11664                  # 2 (R/2)^{h+1}
    assert m.D == 4
    assert abs(m.cost_links - 2.0) < 1e-9
    assert abs(m.cost_switches - 0.139) < 1e-3


@pytest.mark.slow
def test_fat_tree_depopulated_100k():
    t = fat_tree(36, 3, a1=18)           # 50% populated 4-level FT
    m = exact_metrics(t)
    assert m.S == 104976
    assert m.D == 6
    assert abs(m.cost_links - 3.0) < 1e-9
    assert abs(m.cost_switches - 0.222) < 1e-3


def test_dragonfly_paper_size():
    t = dragonfly(a=16, p=8, h=8)
    m = exact_metrics(t)
    assert m.S == 16512                  # paper: DF(32, 16512), 129 groups
    assert t.meta["g"] == 129
    assert m.D <= 3
    assert abs(m.cost_links - 1.4375) < 0.01   # ~1.5 in the paper


def test_dragonfly_plus_paper_size():
    t = dragonfly_plus(65, 16, 16, 16, 16)
    m = exact_metrics(t)
    assert m.S == 16640                  # paper: DF+(32, 16640), 65 groups
    assert m.D == 3                      # leaf-spine-spine-leaf


def test_rfc_is_updown_connected():
    t = rfc(64, u=12, d=12, seed=0)
    tb = build_tables(t)
    assert tb.diameter_leaf <= 2


@settings(max_examples=15, deadline=None)
@given(n1=st.integers(8, 80), u=st.integers(3, 12), d=st.integers(2, 8),
       seed=st.integers(0, 10))
def test_mrls_structure_property(n1, u, d, seed):
    R = u + d
    if (u * n1) % R or (u * n1) // R < 2:
        return
    t = mrls(n1, u, d, seed=seed)
    t.validate()                          # reciprocity etc.
    deg = t.degrees
    assert (deg[t.is_leaf] == u).all()    # leaves: exactly u uplinks
    assert (deg[~t.is_leaf] == R).all()   # spines: full radix
    assert t.n_endpoints == n1 * d


@settings(max_examples=8, deadline=None)
@given(q=st.sampled_from([2, 3, 5, 7, 11]))
def test_oft_property(q):
    t = oft(q)
    t.validate()
    m = q * q + q + 1
    assert t.n_leaves == 2 * m
    assert (t.degrees[t.is_leaf] == q + 1).all()
    assert (t.degrees[~t.is_leaf] == 2 * (q + 1)).all()
    tb = build_tables(t)
    assert tb.diameter_leaf == 2          # any two leaves share a spine


# ---------------------------------------------------------------------- #
# jellyfish (random regular graph)
# ---------------------------------------------------------------------- #
def test_jellyfish_basic_structure():
    t = jellyfish(32, r=6, d=4, seed=0)
    t.validate()
    assert t.n_switches == 32
    assert t.n_endpoints == 32 * 4
    assert (t.degrees == 6).all()         # r-regular, every switch a leaf
    assert t.is_leaf.all()
    assert t.meta["R"] == 10


def test_jellyfish_deterministic_and_seed_sensitive():
    a = jellyfish(24, r=5, d=3, seed=7)
    b = jellyfish(24, r=5, d=3, seed=7)
    c = jellyfish(24, r=5, d=3, seed=8)
    assert np.array_equal(a.nbrs, b.nbrs)
    assert not np.array_equal(a.nbrs, c.nbrs)


def test_jellyfish_complete_graph_case():
    # r == n-1: only K_n is r-regular and simple; built directly
    t = jellyfish(9, r=8, d=4, seed=0)
    t.validate()
    assert (t.degrees == 8).all()
    tb = build_tables(t)
    assert tb.diameter_leaf == 1


def test_jellyfish_validation():
    with pytest.raises(ValueError):
        jellyfish(8, r=1, d=4)            # r < 2
    with pytest.raises(ValueError):
        jellyfish(8, r=8, d=4)            # r >= n
    with pytest.raises(ValueError):
        jellyfish(7, r=3, d=4)            # odd stub population
    with pytest.raises(ValueError):
        jellyfish(8, r=4, d=0)            # no endpoint ports


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 64), r=st.integers(3, 8), d=st.integers(1, 6),
       seed=st.integers(0, 10))
def test_jellyfish_structure_property(n, r, d, seed):
    if r >= n or (n * r) % 2:
        return
    try:
        t = jellyfish(n, r, d, seed=seed)
    except ValueError:
        return                            # too dense to repair: allowed
    t.validate()                          # simple + reciprocal
    assert (t.degrees == r).all()         # exact r-regularity
    assert t.n_endpoints == n * d
    tb = build_tables(t)
    assert tb.diameter_leaf < np.iinfo(tb.dist_leaf.dtype).max
    assert (tb.dist_leaf[np.eye(n, dtype=bool)] == 0).all()
    # connected: every leaf reaches every leaf
    assert (tb.dist_leaf < n).all()


def test_jellyfish_estimate_memory_exact():
    from repro.api import estimate_memory
    from repro.api.registry import build_network
    from repro.api.specs import NetworkSpec, RouteSpec
    from repro.simulator.engine import Simulator, Traffic

    net = NetworkSpec("jellyfish", {"n_switches": 16, "r": 4, "d": 2,
                                    "seed": 3})
    route = RouteSpec(policy="polarized", pool=4096)
    est = estimate_memory(net, route)
    tb = build_tables(build_network(net), masks="dense")
    with Simulator(tb, route.to_sim_config()) as sim:
        st_ = sim.make_state(Traffic("uniform", load=0.5), 0)
        counted = ("qbuf", "qhead", "qlen", "oq_buf", "oq_head", "oq_len",
                   "eq_buf", "eq_head", "eq_len", "fl_buf", "p_sd",
                   "p_mid", "p_bh", "msg_rem", "msg_dst", "prog",
                   "lat_hist")
        actual = sum(np.asarray(st_[k]).nbytes for k in counted)
    assert est["state_bytes_per_replica"] == actual


def test_jellyfish_e2e_all2all():
    from repro.api import Experiment, NetworkSpec, WorkloadSpec, run

    exp = Experiment(
        network=NetworkSpec("jellyfish", {"n_switches": 12, "r": 4,
                                          "d": 2, "seed": 1}),
        workload=WorkloadSpec("all2all", rounds=2),
        name="jf_a2a", max_slots=4000)
    res = run(exp)
    assert res.metric == "completion"
    assert res.completed
    assert res.slots and res.slots > 0
