import os
import sys

# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # container without hypothesis: use deterministic shim
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_compat import install as _install_hypothesis

    _install_hypothesis()

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.parallel.sharding import Sharder  # noqa: E402


@pytest.fixture(scope="session")
def mesh():
    return make_test_mesh()


@pytest.fixture(scope="session")
def sharder(mesh):
    return Sharder(mesh)
