"""switch_arb Pallas kernel vs pure-jnp oracle: interpret-mode equality on
random inputs (exact — the kernel is integer/float-deterministic), plus the
flat-requester adapter round trip."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.switch_arb.kernel import switch_arbitrate, vc_prearb
from repro.kernels.switch_arb.ops import (switch_arbitrate_flat,
                                          switch_arbitrate_op, vc_prearb_op)
from repro.kernels.switch_arb.ref import switch_arbitrate_ref, vc_prearb_ref


def _random_case(rng, n, r, p):
    occ = jnp.asarray(rng.integers(0, 12, (n, r, p)), jnp.int32)
    deroute = jnp.asarray(rng.integers(0, 2, (n, r, p)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (n, r, p)), jnp.int32)
    tie = jnp.asarray(rng.random((n, r, p)), jnp.float32)
    route = jnp.asarray(rng.integers(0, 2, (n, r)), jnp.int32)
    rnd = jnp.asarray(rng.integers(0, 256, (n, r)), jnp.int32)
    lo = jnp.arange(n * r, dtype=jnp.int32).reshape(n, r)
    return occ, deroute, mask, tie, route, rnd, lo


@pytest.mark.parametrize("n,r,p,block_n", [
    (8, 18, 12, 8),
    (5, 9, 7, 2),        # ragged: N % block_n != 0, odd R/P -> padding path
    (16, 8, 128, 8),     # lane-aligned already
    (3, 33, 40, 4),
])
def test_arbitrate_kernel_matches_ref_exactly(n, r, p, block_n):
    rng = np.random.default_rng(n * 1000 + r)
    args = _random_case(rng, n, r, p)
    ref_port, ref_win, ref_seg = switch_arbitrate_ref(*args, penalty=8.0)
    k_port, k_win, k_seg = switch_arbitrate(*args, penalty=8.0,
                                            block_n=block_n, interpret=True)
    np.testing.assert_array_equal(np.asarray(k_port), np.asarray(ref_port))
    np.testing.assert_array_equal(np.asarray(k_win), np.asarray(ref_win))
    np.testing.assert_array_equal(np.asarray(k_seg), np.asarray(ref_seg))


def test_arbitrate_grants_unique_per_output_port():
    rng = np.random.default_rng(7)
    args = _random_case(rng, 6, 20, 10)
    port, win, seg = switch_arbitrate_ref(*args, penalty=8.0)
    port, win = np.asarray(port), np.asarray(win).astype(bool)
    for n in range(6):
        granted = port[n][win[n]]
        assert len(granted) == len(set(granted.tolist())), \
            "two grants on one output port"
    # seg is -1 exactly on ports with no grant
    seg = np.asarray(seg)
    for n in range(6):
        assert set(np.nonzero(seg[n] >= 0)[0]) == set(port[n][win[n]])


@pytest.mark.parametrize("n,p,v", [(8, 12, 4), (5, 7, 3), (9, 16, 8)])
def test_vc_prearb_kernel_matches_ref_exactly(n, p, v):
    rng = np.random.default_rng(n)
    qlen = jnp.asarray(rng.integers(0, 3, (n, p, v)), jnp.int32)
    rand = jnp.asarray(rng.random((n, p, v)), jnp.float32)
    ref_sel, ref_has = vc_prearb_ref(qlen, rand)
    k_sel, k_has = vc_prearb(qlen, rand, block_n=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(k_sel), np.asarray(ref_sel))
    np.testing.assert_array_equal(np.asarray(k_has), np.asarray(ref_has))


def test_ops_dispatch_ref_and_kernel_agree():
    rng = np.random.default_rng(3)
    args = _random_case(rng, 4, 10, 6)
    for use_ref in (True, False):
        port, win, seg = switch_arbitrate_op(*args, penalty=4.0,
                                             use_ref=use_ref, interpret=True)
        assert win.dtype == bool
    sel, has = vc_prearb_op(jnp.asarray(rng.integers(0, 2, (4, 6, 4)),
                                        jnp.int32),
                            jnp.asarray(rng.random((4, 6, 4)), jnp.float32),
                            use_ref=True)
    assert has.dtype == bool


def test_flat_adapter_round_trips_the_dense_layout():
    # 3 switches, r_max 4, 2 "endpoint" rows left unoccupied on switch 2
    rng = np.random.default_rng(11)
    n, r_max, p = 3, 4, 5
    row_of = jnp.asarray(np.array([0, 1, 2, 4, 5, 6, 8, 9, 3, 7],
                                  np.int32))      # injective, < n * r_max
    nr = int(row_of.shape[0])
    occ = jnp.asarray(rng.integers(0, 5, (nr, p)), jnp.int32)
    deroute = jnp.zeros((nr, p), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (nr, p)), jnp.int32)
    tie = jnp.asarray(rng.random((nr, p)), jnp.float32)
    route = jnp.ones((nr,), jnp.int32)
    rnd = jnp.asarray(rng.integers(0, 256, (nr,)), jnp.int32)
    lo = jnp.arange(nr, dtype=jnp.int32)
    port, win, seg = switch_arbitrate_flat(
        occ, deroute, mask, tie, route, rnd, lo, penalty=8.0,
        row_of=row_of, n_switches=n, r_max=r_max, use_ref=True)
    assert port.shape == (nr,) and win.shape == (nr,)
    assert seg.shape == (n * p,)
    # winners' lo bits recover the flat requester index through seg
    seg = np.asarray(seg)
    win = np.asarray(win)
    port = np.asarray(port)
    for i in np.nonzero(win)[0]:
        # reconstruct this winner's switch from the dense row map
        sw = int(row_of[i]) // r_max
        assert seg[sw * p + int(port[i])] & ((1 << 23) - 1) == i
